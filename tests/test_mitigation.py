"""Mitigation-lab tests: traced routing policies (bit-identity vs legacy
host-side assignment, conservation under re-pathing, adaptive/flowlet
never worse than the worst static policy), candidate spaces and bounds,
single-compile batched search, Pareto scoring, and the gradient tier."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bench, congestion as cong
from repro.core.fabric import simulator as sim, systems
from repro.core.fabric.routing import (POLICY_ADAPTIVE, POLICY_ECMP,
                                       POLICY_FIXED, POLICY_FLOWLET,
                                       POLICY_NSLB)
from repro.core.mitigation import score, search
from repro.core.mitigation.search import Candidate, PanelCell

RUN_KW = dict(chunk=512, max_chunks=40, stride=8)


def _outputs(geom, params, n_iters=8):
    out = sim.run_cell(geom, params, jnp.asarray(n_iters, jnp.int32),
                       **RUN_KW)
    return {k: np.asarray(v) for k, v in out.items()}


def _nanjing_cell(static_mode: str):
    """An 8-node leaf-spine AlltoAll-vs-AlltoAll cell whose host-side
    static assignment uses ``static_mode``."""
    sysp = systems.get_system("nanjing_ecmp")
    topo = sysp.make_topology(8)
    vidx, aidx = cong.interleaved_split(8)
    nodes = np.arange(8)
    flows = cong.build_flowset(topo, nodes[vidx], nodes[aidx], "alltoall",
                               "alltoall", 4 << 20,
                               routing_mode=static_mode, k_max=sysp.k_max,
                               policy_tables=True)
    geom = sim.make_geometry(topo, flows)
    params = sim.make_params(sysp.cc, dt=2e-6,
                             bytes_per_iter=flows.bytes_per_iter,
                             host_caps=flows.host_caps,
                             env=cong.steady().params())
    return geom, params


# --------------------------------------------------------------------------
# Traced policies == legacy host-side assignment, bit for bit
# --------------------------------------------------------------------------


@pytest.mark.parametrize("mode,policy", [("deterministic", POLICY_FIXED),
                                         ("ecmp", POLICY_ECMP),
                                         ("nslb", POLICY_NSLB)])
def test_traced_policy_matches_legacy_static(mode, policy):
    """POLICY_FIXED on a geometry whose fixed_choice was host-assigned
    with ``mode`` must equal the traced twin policy on a geometry built
    with any other static mode (the tables are per-flow data now)."""
    geom_legacy, params = _nanjing_cell(mode)
    legacy = _outputs(geom_legacy,
                      dataclasses.replace(
                          params, policy=jnp.asarray(POLICY_FIXED,
                                                     jnp.int32)))
    geom_det, params_det = _nanjing_cell("deterministic")
    traced = _outputs(geom_det,
                      dataclasses.replace(
                          params_det, policy=jnp.asarray(policy,
                                                         jnp.int32)))
    for k in ("t_done", "it", "qd_acc", "t", "trace", "chunks", "fbytes"):
        assert np.array_equal(legacy[k], traced[k]), (mode, k)


def test_policies_actually_differ():
    """The sanity inverse: on the collision-prone leaf spine, the traced
    policies must NOT all coincide (otherwise the switch is wired to one
    table and the bit-identity test proves nothing)."""
    geom, params = _nanjing_cell("deterministic")
    times = {}
    for pol in (POLICY_FIXED, POLICY_NSLB, POLICY_ADAPTIVE):
        out = _outputs(geom, dataclasses.replace(
            params, policy=jnp.asarray(pol, jnp.int32)))
        times[pol] = float(out["t_done"][0][:4].sum())
    assert times[POLICY_NSLB] < times[POLICY_FIXED], times
    assert len({round(t, 9) for t in times.values()}) > 1


# --------------------------------------------------------------------------
# Conservation + never-worse-than-worst-static under re-pathing
# --------------------------------------------------------------------------


def test_flow_conservation_under_repathing():
    """Flowlet re-pathing must preserve the per-step conservation
    invariants: service capped by effective capacity, achieved rate
    never above injection, NIC caps respected — and with a bursty
    envelope the idle-gap trigger must actually re-path some flow."""
    sysp = systems.get_system("nanjing_ecmp")
    topo = sysp.make_topology(8)
    vidx, aidx = cong.interleaved_split(8)
    nodes = np.arange(8)
    flows = cong.build_flowset(topo, nodes[vidx], nodes[aidx], "alltoall",
                               "alltoall", 1 << 20,
                               routing_mode="deterministic",
                               k_max=sysp.k_max)
    geom = sim.make_geometry(topo, flows)
    params = sim.make_params(sysp.cc, dt=2e-6,
                             bytes_per_iter=flows.bytes_per_iter,
                             host_caps=flows.host_caps,
                             env=cong.bursty(0.5e-3, 0.5e-3).params(),
                             policy=POLICY_FLOWLET, flowlet_gap_s=20e-6)
    step = jax.jit(sim.step_debug)
    state = sim.init_state(geom, params)
    # herd every flow onto candidate 0: the idle-gap trigger must then
    # spread them (with hysteresis, a balanced start never re-paths —
    # that is the point of the anchor)
    state["rc"] = jnp.zeros_like(state["rc"])
    rc0 = np.asarray(state["rc"]).copy()
    repathed = False
    src_cap = np.zeros(geom.n_src)
    np.maximum.at(src_cap, np.asarray(geom.src_id),
                  np.asarray(params.host_caps))
    for _ in range(600):
        state, _, aux = step(geom, params, state)
        served = np.asarray(aux["served_stage_max"])
        caps_eff = np.asarray(aux["caps_eff"])
        assert (served[: geom.L]
                <= caps_eff[: geom.L] * (1 + 1e-3) + 1.0).all()
        inj = np.asarray(aux["inject"])
        assert (np.asarray(aux["achieved"]) <= inj * (1 + 1e-5) + 1.0).all()
        src_load = np.zeros(geom.n_src)
        np.add.at(src_load, np.asarray(geom.src_id), inj)
        assert (src_load <= src_cap * (1 + 1e-3) + 1.0).all()
        if not np.array_equal(np.asarray(state["rc"]), rc0):
            repathed = True
    assert repathed, "flowlet policy never re-pathed under a bursty envelope"


def test_adaptive_and_flowlet_not_worse_than_worst_static():
    """Steady-state property: the dynamic policies may not lose to the
    WORST static assignment (deterministic herds every flow onto one
    uplink — a dynamic policy that cannot beat that is broken)."""
    geom, params = _nanjing_cell("deterministic")
    t_victim = {}
    for pol in (POLICY_FIXED, POLICY_ECMP, POLICY_NSLB, POLICY_ADAPTIVE,
                POLICY_FLOWLET):
        out = _outputs(geom, dataclasses.replace(
            params, policy=jnp.asarray(pol, jnp.int32),
            flowlet_gap_s=jnp.asarray(100e-6, jnp.float32)), n_iters=6)
        done = int(out["it"][0])
        assert done >= 1, pol
        t_victim[pol] = float(out["t_done"][0][min(done, 6) - 1]) \
            / min(done, 6)
    worst_static = max(t_victim[POLICY_FIXED], t_victim[POLICY_ECMP],
                       t_victim[POLICY_NSLB])
    assert t_victim[POLICY_ADAPTIVE] <= worst_static * 1.05, t_victim
    assert t_victim[POLICY_FLOWLET] <= worst_static * 1.05, t_victim


# --------------------------------------------------------------------------
# Candidate spaces, bounds, Pareto scoring
# --------------------------------------------------------------------------


def test_knob_bounds_enforced():
    with pytest.raises(ValueError):
        search.CCSpace.of(md=(0.1,))  # below lower bound
    with pytest.raises(KeyError):
        search.CCSpace.of(nonsense=(1.0,))
    with pytest.raises(ValueError):
        search.RoutingSpace(policies=(POLICY_FLOWLET,),
                            flowlet_gaps_s=(1.0,))  # 1 s gap out of range
    with pytest.raises(KeyError):
        search.gradient_refine(None, None, ["kind"])  # int knob


def test_expand_cartesian_and_flowlet_gap_axis():
    cands = search.expand(
        search.CCSpace.of(md=(0.5, 0.8), rai_frac=(0.02,)),
        search.RoutingSpace(policies=(POLICY_NSLB, POLICY_FLOWLET),
                            flowlet_gaps_s=(50e-6, 200e-6)))
    # nslb: 1 gap (collapsed) x 2 cc; flowlet: 2 gaps x 2 cc
    assert len(cands) == 2 + 4
    labels = {c.label() for c in cands}
    assert len(labels) == len(cands)


def test_pareto_frontier_and_winner_guard():
    mk = lambda n, rmin, aggr, jain, rel: score.CandidateScore(
        candidate=n, ratio_min=rmin, ratio_mean=rmin, aggr_gbps=aggr,
        jain=jain, t_base_worst_rel=rel)
    dominated = mk("dominated", 0.5, 10.0, 0.9, 1.0)
    balanced = mk("balanced", 0.9, 80.0, 0.95, 1.0)  # best aggr goodput
    throttler = mk("throttler", 0.95, 1.0, 1.0, 1.0)  # starves aggressors
    taxed = mk("taxed", 0.99, 60.0, 0.99, 1.3)  # slows the baseline 30%
    front = score.pareto_frontier([dominated, balanced, throttler, taxed])
    names = [s.candidate for s in front]
    assert "dominated" not in names
    assert {"balanced", "throttler", "taxed"} <= set(names)
    win = score.pick_winner([dominated, balanced, throttler, taxed])
    assert win.candidate == "throttler"  # taxed fails the baseline guard


# --------------------------------------------------------------------------
# Batched search: mixed policies + heterogeneous cells, one compile
# --------------------------------------------------------------------------


def test_run_candidates_single_compile_mixed_policies():
    panel = [
        PanelCell("leafspine", systems.get_system("nanjing_ecmp"), 8,
                  "alltoall", "alltoall", 2 << 20, cong.steady()),
        PanelCell("single_switch", systems.get_system("haicgu_ib"), 8,
                  "ring_allgather", "incast", 2 << 20,
                  cong.bursty(2e-3, 2e-3)),
    ]
    cands = [search.default_candidate(),
             Candidate(policy=POLICY_NSLB, name="nslb"),
             Candidate(policy=POLICY_FLOWLET, flowlet_gap_s=100e-6,
                       name="flowlet"),
             Candidate(cc=(("md", 0.8),), name="gentle")]
    before = sim.trace_count("run_cells_hetero")
    runs = search.run_candidates(panel, cands, n_iters=6, warmup=1,
                                 max_steps=40_000, chunk=512)
    assert sim.trace_count("run_cells_hetero") - before <= 1
    assert len(runs) == len(panel) * len(cands)
    for r in runs:
        assert 0.0 < r.ratio <= 1.2, r
        assert 0.0 < r.jain <= 1.0 + 1e-6, r
        assert r.victim_bytes > 0, r
    # the traced-policy engine must separate nslb from the ecmp default
    # on the collision-prone leaf spine
    by = {(r.cell, r.candidate): r for r in runs}
    assert by[("leafspine", "nslb")].ratio \
        > by[("leafspine", "default")].ratio + 0.05


def test_simulated_times_matches_run_point():
    """autotune's table tier (a 1-candidate panel) must agree with the
    legacy run_point path — padding and candidate plumbing are inert."""
    sysp = systems.get_system("nanjing_nslb")
    t_u, t_c = search.simulated_times("nanjing_nslb", 8, "alltoall",
                                      "alltoall", 4 << 20, cong.steady(),
                                      n_iters=10, warmup=2)
    r = bench.run_point(sysp, 8, "alltoall", "alltoall", 4 << 20,
                        cong.steady(), n_iters=10, warmup=2)
    assert np.isclose(t_u, r.t_uncongested_s, rtol=1e-5)
    assert np.isclose(t_c, r.t_congested_s, rtol=1e-5)


# --------------------------------------------------------------------------
# Gradient tier
# --------------------------------------------------------------------------


def test_gradient_refine_descends():
    """Victim slowdown is differentiable through the fluid scan: the
    refined objective must not be worse than the starting point, knobs
    stay inside their bounds, and the history is finite."""
    case = bench.build_case(systems.get_system("haicgu_ce8850"), 6,
                            "ring_allgather", "incast")
    dt = bench.choose_dt(case.topo, case.n_victims, 4 << 20, case.lat())
    params = case.cell_params(4 << 20, cong.steady(), dt)
    out = search.gradient_refine(case.geom, params, ["md", "rai_frac"],
                                 steps=4, n_steps=300)
    assert np.isfinite(out["history"]).all(), out["history"]
    assert out["objective"] <= out["history"][0] + 1e-6
    from repro.core.fabric.cc import SEARCH_BOUNDS
    for k, v in out["knobs"].items():
        lo, hi = SEARCH_BOUNDS[k]
        assert lo <= v <= hi, (k, v)


def test_dnf_cells_excluded_from_scores():
    """DNF cells (zero completed iterations, NaN ratio) must be counted
    and excluded from the Pareto axes — never averaged in — and a
    full-panel-DNF candidate can neither enter the frontier nor win."""
    def run(cand, cell, ratio, dnf=False):
        return search.CellRun(
            cell=cell, candidate=cand,
            t_uncongested_s=float("nan") if dnf else 1.0,
            t_congested_s=float("nan") if dnf else 1.0 / ratio,
            ratio=float("nan") if dnf else ratio,
            victim_bytes=1e9, aggr_bytes=1e9, sim_time_s=1.0,
            jain=1.0, dnf=dnf)

    runs = [run("default", "a", 0.9), run("default", "b", 0.8),
            run("good", "a", 0.95), run("good", "b", 0.85, dnf=True),
            run("broken", "a", 0.0, dnf=True),
            run("broken", "b", 0.0, dnf=True)]
    scores = {s.candidate: s for s in score.aggregate(runs)}
    assert scores["good"].n_dnf == 1
    assert np.isclose(scores["good"].ratio_min, 0.95)  # DNF cell excluded
    assert scores["broken"].n_dnf == 2
    assert np.isnan(scores["broken"].ratio_min)

    front = score.pareto_frontier(list(scores.values()))
    assert "broken" not in {s.candidate for s in front}
    assert score.pick_winner(list(scores.values())).candidate != "broken"


def test_simulated_times_agent_aware_cache():
    """The lru table is keyed on the Candidate too (ISSUE 10 bugfix):
    re-evaluating a cached (system, scale, candidate) point is a pure
    table hit — zero new traces, bit-identical times — and a non-default
    candidate at the same point is its own entry, never the stale
    default-config time."""
    search._times_table.cache_clear()
    cand = Candidate(policy=POLICY_ECMP, cc=(("md", 0.3),))
    args = ("nanjing_nslb", 8, "alltoall", "alltoall", float(4 << 20),
            cong.steady())
    t_def = search.simulated_times(*args, n_iters=6, warmup=2)
    t_c1 = search.simulated_times(*args, candidate=cand, n_iters=6,
                                  warmup=2)
    before = sim.trace_count("run_cells_hetero")
    t_def2 = search.simulated_times(*args, n_iters=6, warmup=2)
    t_c2 = search.simulated_times(*args, candidate=cand, n_iters=6,
                                  warmup=2)
    assert sim.trace_count("run_cells_hetero") == before
    info = search.simulated_times_cache_info()
    assert info.hits >= 2 and info.currsize >= 2
    assert t_def2 == t_def and t_c2 == t_c1
    # the candidate actually keys the table: congested times differ
    assert t_c1[1] != t_def[1]
