"""hlo_stats parser tests: FLOPs/byte counting on real lowered modules,
while-loop trip-count multipliers, collective wire-byte attribution,
dryrun artifact contract (smoke cell generated in a tmpdir fixture)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_stats import analyze, wire_bytes


def _lower_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_counted():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    text = _lower_text(lambda x, y: x @ y, a, b)
    stats = analyze(text, 1)
    want = 2 * 128 * 256 * 64
    assert abs(stats["flops"] - want) / want < 0.01, stats["flops"]


def test_scan_multiplies_flops():
    """A matmul inside lax.scan must count trip_count times."""
    w = jnp.zeros((64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)
    trips = 12

    def fn(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=trips)
        return out

    stats = analyze(_lower_text(fn, x, w), 1)
    want = 2 * 8 * 64 * 64 * trips
    # XLA may hoist/fuse a bit; require within 2x but at least trips/2 visits
    assert stats["flops"] >= want * 0.5, (stats["flops"], want)
    assert stats["flops"] <= want * 2.0, (stats["flops"], want)


def test_memory_bytes_scale_with_tensor_size():
    x = jnp.zeros((1024, 1024), jnp.float32)
    y = jnp.zeros((32, 32), jnp.float32)
    big = analyze(_lower_text(lambda a: a * 2 + 1, x), 1)
    small = analyze(_lower_text(lambda a: a * 2 + 1, y), 1)
    assert big["hbm_bytes"] > 100 * small["hbm_bytes"]
    # elementwise op reads + writes ~2x4MiB
    assert 0.5 * 8e6 < big["hbm_bytes"] < 4 * 8e6


def test_wire_bytes_formulas():
    # ring algorithms on g ranks
    assert wire_bytes("all-gather", 256, 1024, 4) == 0.75 * 1024
    assert wire_bytes("reduce-scatter", 1024, 256, 4) == 0.75 * 1024
    assert wire_bytes("all-reduce", 1024, 1024, 4) == 2 * 0.75 * 1024
    assert wire_bytes("all-to-all", 1024, 1024, 4) == 0.75 * 1024
    assert wire_bytes("collective-permute", 512, 512, 4) == 512
    assert wire_bytes("all-reduce", 1024, 1024, 1) == 0.0


def test_collectives_detected_in_sharded_module():
    """Lower a psum under shard_map on a 1-device mesh — the collective op
    must appear in the parse (group size 1 -> zero wire bytes)."""
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import PartitionSpec as P

    def fn(x):
        return jax.shard_map(lambda v: jax.lax.psum(v, "x"), mesh=mesh,
                             in_specs=P("x"), out_specs=P())(x)

    text = _lower_text(fn, jnp.zeros((8, 16), jnp.float32))
    stats = analyze(text, 1)
    assert stats["collectives"]["total"]["count"] >= 1
    assert stats["collectives"]["total"]["wire_bytes"] == 0.0


@pytest.fixture(scope="module")
def dryrun_smoke_cell(tmp_path_factory):
    """A real dryrun artifact generated into a tmpdir via the --smoke
    path (reduced config, shrunken shape, host mesh — identical JSON
    layout, seconds instead of the full 512-device sweep). Skips with
    instructions only when the dryrun toolchain itself cannot run on
    this machine."""
    import json
    import subprocess
    import sys

    arch, shape, mesh = "yi-6b", "train_4k", "single"
    tmp = tmp_path_factory.mktemp("dryrun")
    env = dict(os.environ, REPRO_DRYRUN_DIR=str(tmp))
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--smoke"]
    try:
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=600)
    except subprocess.TimeoutExpired:
        pytest.skip("dryrun --smoke timed out on this machine; run "
                    "`PYTHONPATH=src python -m repro.launch.dryrun --all` "
                    "manually to produce the artifact grid")
    if r.returncode != 0:
        err_lines = (r.stderr or "").strip().splitlines()
        pytest.skip(
            "dryrun --smoke failed on this machine (missing toolchain?): "
            f"{err_lines[-1] if err_lines else '?'} — "
            "run `PYTHONPATH=src python -m repro.launch.dryrun --all` "
            "once the toolchain is available")
    path = os.path.join(str(tmp), f"{arch}__{shape}__{mesh}__smoke.json")
    with open(path) as f:
        return json.load(f)


def test_dryrun_smoke_artifact_consistent(dryrun_smoke_cell):
    """The minimal (tmpdir-generated) dryrun artifact asserts the full
    cell contract: ok status, positive roofline terms, a bottleneck
    pick, memory accounting, and HLO stats — no artifacts/ checkout
    needed."""
    cell = dryrun_smoke_cell
    assert cell["status"] == "ok" and cell.get("smoke") is True
    assert cell["n_devices"] >= 1
    r = cell["roofline"]
    assert r["compute_s"] > 0 and r["memory_s"] > 0
    assert r["collective_s"] >= 0
    assert r["bottleneck"] in ("compute_s", "memory_s", "collective_s")
    mem = cell["memory"]
    assert mem["peak_per_device_bytes"] > 0
    assert mem["temp_bytes"] >= 0
    hlo = cell["hlo"]
    assert hlo["flops_per_device"] > 0 and hlo["hbm_bytes_per_device"] > 0
    assert "total" in hlo["collectives"]
    # train cells carry the MODEL_FLOPS accounting
    mf = cell["model_flops"]
    assert 0 < mf["n_active_params"] <= mf["n_params"]
    assert mf["model_flops_per_device"] > 0


def test_dryrun_artifacts_complete_and_consistent():
    """Every (arch x shape x mesh) artifact exists; ok cells carry roofline
    terms; skip cells are exactly the documented long_500k skips.
    (Full-grid check: skips with instructions when the artifact grid has
    not been generated in this checkout — the smoke-cell test above
    covers the artifact contract either way.)"""
    import json

    from repro.configs import all_arch_names, get_config
    from repro.configs.base import SHAPES

    art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
    if not os.path.isdir(art):
        pytest.skip(
            "artifacts/dryrun not generated in this checkout — run "
            "`PYTHONPATH=src python -m repro.launch.dryrun --all` to "
            "produce the (arch x shape x mesh) dryrun grid first")
    n_ok = n_skip = 0
    for arch in all_arch_names():
        cfg = get_config(arch)
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                path = os.path.join(art, f"{arch}__{shape}__{mesh}.json")
                assert os.path.exists(path), path
                with open(path) as f:
                    cell = json.load(f)
                applicable = shape in [s.name for s in cfg.shapes()]
                if applicable:
                    assert cell["status"] == "ok", (arch, shape, mesh)
                    assert cell["n_devices"] == (512 if mesh == "multi"
                                                 else 256)
                    r = cell["roofline"]
                    assert r["compute_s"] > 0 and r["memory_s"] > 0
                    assert r["bottleneck"] in ("compute_s", "memory_s",
                                               "collective_s")
                    n_ok += 1
                else:
                    assert cell["status"] == "skip", (arch, shape, mesh)
                    n_skip += 1
    assert n_ok == 64 and n_skip == 16  # 32 cells x 2 meshes; 8 skips x 2
