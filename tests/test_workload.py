"""Fleet workload replay: seeded generation, streaming-metric exactness,
padding inertness, compile sharing (ISSUE 8, DESIGN.md §15).

The two load-bearing contracts pinned here:

* **Exactness** — the in-scan streaming histograms reproduce, bin for
  bin, the post-hoc histogram of the materialized step_debug samples
  (same bin_index formula, same weights), across routing policies and dt
  ladders; the streaming Welford merge matches the post-hoc weighted
  mean/std to fp32 tolerance. The streaming path may lose within-bin
  resolution, never samples.
* **Invariance** — lowering a seed alone or inside a 1024-lane vmap is
  bit-identical, and padding a template to a larger geometry bucket
  leaves every real-lane metric bit-identical (pad flows/jobs are inert
  in the accumulators, same contract as geometry pads).
"""
import dataclasses
import functools

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bench, envelopes, metrics as met
from repro.core import workload as wl
from repro.core.fabric import simulator as sim
from repro.core.fabric.routing import (POLICY_ADAPTIVE, POLICY_ECMP,
                                       POLICY_FIXED, splitmix64,
                                       splitmix64_hilo)


@functools.lru_cache(maxsize=None)
def _template():
    """One small shared template (topology binding is host-expensive)."""
    spec = wl.WorkloadSpec(
        system="cresco8", n_nodes=8, short_slots=8, arrivals_mean=4.0,
        horizon_s=1.5e-4, tenant_bytes=float(1 << 18),
        short_bytes_median=float(64 << 10), tenant_stagger_s=20e-6)
    return wl.build_template(spec)


# --------------------------------------------------------------------------
# splitmix64 limb emulation + envelope hash pins (satellite: telegraph
# envelope now uses the pinned splitmix64 stream, not an ad-hoc LCG)
# --------------------------------------------------------------------------


def test_splitmix64_hilo_matches_uint64_reference():
    x = np.concatenate([np.arange(512, dtype=np.uint64),
                        np.uint64(1) << np.arange(64, dtype=np.uint64),
                        np.array([0xDEADBEEFCAFEBABE, 2**64 - 1],
                                 np.uint64)])
    ref = splitmix64(x)
    hi, lo = splitmix64_hilo((x >> np.uint64(32)).astype(np.uint32),
                             x.astype(np.uint32))
    got = (hi.astype(np.uint64) << np.uint64(32)) | lo.astype(np.uint64)
    np.testing.assert_array_equal(got, ref)


def test_splitmix64_hilo_traced_matches_host():
    import jax
    import jax.numpy as jnp

    x = np.arange(257, dtype=np.uint64) * np.uint64(0x9E3779B97F4A7C15)
    hi, lo = splitmix64_hilo((x >> np.uint64(32)).astype(np.uint32),
                             x.astype(np.uint32))
    jhi, jlo = jax.jit(lambda h, l: splitmix64_hilo(h, l, xp=jnp))(
        (x >> np.uint64(32)).astype(np.uint32), x.astype(np.uint32))
    np.testing.assert_array_equal(np.asarray(jhi), hi)
    np.testing.assert_array_equal(np.asarray(jlo), lo)


def test_random_envelope_pinned_vectors():
    """Re-pinned telegraph vectors (seed -> on/off pattern) — a hash
    change is an intentional, visible event, not silent drift."""
    t = np.array([0.0, 0.0005, 0.003, 0.0101, 0.25])
    for seed, want in ((3, [0, 0, 0, 0, 0]), (1, [0, 0, 1, 0, 0])):
        prof = envelopes.random_onoff(0.002, 0.006, seed=seed)
        np.testing.assert_array_equal(envelopes.envelope_np(
            prof.params(), t), np.asarray(want, np.float64))
        traced = [float(envelopes.envelope_at(prof.params(), ti))
                  for ti in t]
        np.testing.assert_array_equal(np.asarray(traced), want)


def test_random_envelope_duty_cycle_and_determinism():
    prof = envelopes.random_onoff(0.002, 0.006, seed=9)
    t = np.arange(40_000) * 1e-4
    v1 = envelopes.envelope_np(prof.params(), t)
    v2 = envelopes.envelope_np(prof.params(), t)
    np.testing.assert_array_equal(v1, v2)
    assert abs(v1.mean() - 0.25) < 0.04
    # distinct seeds give distinct telegraph patterns
    v3 = envelopes.envelope_np(
        envelopes.random_onoff(0.002, 0.006, seed=10).params(), t)
    assert (v1 != v3).any()


# --------------------------------------------------------------------------
# Workload generation: reproducible, batch-invariant, inert idle slots
# --------------------------------------------------------------------------


def test_lower_seed_reproducible_and_batch_invariant():
    t = _template()
    p_one = wl.lower_seed(t, 3)
    p_again = wl.lower_seed(t, 3)
    p_batch = wl.lower_seeds(t, np.arange(1024))
    for f in ("bytes_per_iter", "flow_start", "fct_mask", "kind"):
        one = np.asarray(getattr(p_one, f))
        np.testing.assert_array_equal(one, np.asarray(getattr(p_again, f)))
        np.testing.assert_array_equal(
            one, np.asarray(getattr(p_batch, f))[3],
            err_msg=f"{f}: seed 3 alone != lane 3 of the 1024-seed vmap")
    # different seeds actually vary the draw
    bpi = np.asarray(p_batch.bytes_per_iter)
    assert (bpi[0] != bpi[1]).any()


def test_lowered_params_structure():
    t = _template()
    p = wl.lower_seed(t, 0)
    bpi = np.asarray(p.bytes_per_iter)
    # inactive short slots carry exactly 0 bytes (inert-flow contract)
    shorts = bpi[t.short_idx]
    assert ((shorts == 0.0) | (shorts > 0.0)).all()
    assert (np.asarray(p.fct_mask)[t.short_idx] == 1.0).all()
    # short arrivals land inside the horizon; tenants inside the stagger
    fs = np.asarray(p.flow_start)
    assert (fs[t.short_idx] >= 0).all()
    assert (fs[t.short_idx] <= t.spec.horizon_s).all()
    tenant_rows = np.asarray(t.job_is_tenant)[t.flow_job] > 0
    assert (fs[tenant_rows] <= t.spec.tenant_stagger_s).all()
    # every flow's CC kind comes from the declared mix
    assert set(np.unique(np.asarray(p.kind))) <= set(t.mix_kinds.tolist())
    # per-job kind: all flows of one job share a kind
    fj = t.flow_job
    kinds = np.asarray(p.kind)
    for j in range(t.n_jobs):
        m = fj == j
        if m.any():
            assert len(np.unique(kinds[m])) == 1, f"job {j} mixed kinds"


# --------------------------------------------------------------------------
# Streaming metrics == post-hoc metrics (the exactness contract)
# --------------------------------------------------------------------------


def _posthoc_replay(params, n_steps):
    """Materialize per-step samples via step_debug and fold them post-hoc
    — the oracle the streaming carry must reproduce."""
    import jax

    t = _template()
    geom = t.geom
    step_j = jax.jit(lambda p, s: sim.step_debug(geom, p, s))
    state = sim.init_state(geom, params, metrics=True)
    fct_mask = np.asarray(params.fct_mask, np.float64)
    ideal = np.asarray(params.bytes_per_iter, np.float64) \
        / np.maximum(np.asarray(params.host_caps, np.float64), 1.0)
    qd_x, qd_w, fct_x, fct_w, sl_x, sl_w = [], [], [], [], [], []
    for _ in range(n_steps):
        prev_armed = np.asarray(state["armed_t"], np.float64)
        state, _, aux = step_j(params, state)
        t_new = float(np.asarray(state["t"]))
        qd_x.append(np.asarray(aux["qdel"], np.float64))
        qd_w.append(np.asarray(aux["active"], np.float64))
        done = np.asarray(aux["done"], np.float64)
        fct = t_new - prev_armed
        fct_x.append(fct)
        fct_w.append(done * fct_mask)
        sl_x.append(fct / np.maximum(ideal, 1e-9))
        sl_w.append(done)
    return state, (np.concatenate(qd_x), np.concatenate(qd_w),
                   np.concatenate(fct_x), np.concatenate(fct_w),
                   np.concatenate(sl_x), np.concatenate(sl_w))


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       dt_mult=st.sampled_from([1.0, 0.5, 2.0]),
       policy=st.sampled_from([POLICY_FIXED, POLICY_ECMP, POLICY_ADAPTIVE]))
def test_streaming_metrics_match_posthoc(seed, dt_mult, policy):
    import jax.numpy as jnp

    t = _template()
    params = wl.lower_seed(t, seed)
    params = dataclasses.replace(
        params,
        dt=jnp.asarray(t.dt * dt_mult, jnp.float32),
        policy=jnp.asarray(policy, np.asarray(params.policy).dtype))
    n_steps = 192
    state, (qd_x, qd_w, fct_x, fct_w, sl_x, sl_w) = \
        _posthoc_replay(params, n_steps)

    # histograms: EXACT, bin for bin (same bin_index, same weights)
    np.testing.assert_array_equal(np.asarray(state["h_qd"]),
                                  met.np_hist(qd_x, qd_w))
    np.testing.assert_array_equal(np.asarray(state["h_fct"]),
                                  met.np_hist(fct_x, fct_w))
    assert float(np.asarray(state["h_qd"]).sum()) == qd_w.sum()

    # Welford: counts exact, moments to fp32 tolerance
    fj = t.flow_job
    J = t.n_jobs
    wn, wmean, wstd = met.welford_finalize(
        np.asarray(state["wn"]), np.asarray(state["wmean"]),
        np.asarray(state["wm2"]))
    for j in range(J):
        m = fj == j
        w = sl_w.reshape(n_steps, -1)[:, m].ravel()
        x = sl_x.reshape(n_steps, -1)[:, m].ravel()
        assert wn[j] == w.sum(), f"job {j} completion count"
        if w.sum() > 0:
            mean = (w * x).sum() / w.sum()
            var = (w * (x - mean) ** 2).sum() / w.sum()
            np.testing.assert_allclose(wmean[j], mean, rtol=1e-4,
                                       atol=1e-9)
            np.testing.assert_allclose(wstd[j], np.sqrt(var), rtol=1e-3,
                                       atol=1e-7)


def test_percentiles_of_known_samples():
    rng = np.random.default_rng(0)
    x = 10.0 ** rng.uniform(-6, -2, 20_000)
    h = met.np_hist(x)
    got = met.percentiles(h, (0.5, 0.99))
    width = 10.0 ** (1.0 / met.BINS_PER_DECADE)
    for q in (0.5, 0.99):
        exact = np.quantile(x, q)
        assert got[q] / exact < width and exact / got[q] < width, \
            (q, got[q], exact)
    # empty histogram -> NaN, not a crash
    assert np.isnan(met.percentiles(np.zeros(met.NBINS), (0.5,))[0.5])


# --------------------------------------------------------------------------
# Replay engine integration: padding inertness, compile sharing,
# metrics-off bit parity
# --------------------------------------------------------------------------

_REPLAY_KW = dict(chunk=64, max_chunks=3, stride=8, with_trace=False)


def _run_at_dims(t, dims, seeds, metrics=True):
    tp = wl.pad_template(t, dims)  # geom is already padded to dims
    geoms = sim.stack_geometries([tp.geom])
    params = sim.stack_params([wl.lower_seeds(tp, seeds)])
    return sim.run_cells_hetero(
        geoms, params, np.int32(sim.TDONE_SLOTS),
        metrics=metrics, **_REPLAY_KW), tp


def test_padding_inert_for_streaming_metrics():
    """Inflating every bucket dimension must leave each real lane's
    histograms, Welford accumulators and delivered bytes bit-identical
    (pad flows never contribute a sample)."""
    t = _template()
    seeds = np.arange(4)
    dims0 = sim.geometry_dims(t.geom)
    dims1 = dataclasses.replace(
        dims0, n_links=dims0.n_links + 16, n_flows=dims0.n_flows + 32,
        n_jobs=dims0.n_jobs + 3, n_sw=dims0.n_sw + 2,
        n_src=dims0.n_src + 2)
    out0, _ = _run_at_dims(t, dims0, seeds)
    out1, _ = _run_at_dims(t, dims1, seeds)
    F, J = dims0.n_flows, dims0.n_jobs
    np.testing.assert_array_equal(np.asarray(out0["t"]),
                                  np.asarray(out1["t"]))
    np.testing.assert_array_equal(np.asarray(out0["h_qd"]),
                                  np.asarray(out1["h_qd"]))
    np.testing.assert_array_equal(np.asarray(out0["h_fct"]),
                                  np.asarray(out1["h_fct"]))
    np.testing.assert_array_equal(np.asarray(out0["fbytes"]),
                                  np.asarray(out1["fbytes"])[..., :F])
    for k in ("wn", "wmean", "wm2"):
        np.testing.assert_array_equal(np.asarray(out0[k]),
                                      np.asarray(out1[k])[..., :J])
    # pad lanes contributed nothing
    assert np.asarray(out1["fbytes"])[..., F:].sum() == 0.0
    assert np.asarray(out1["wn"])[..., J:].sum() == 0.0


def test_replay_one_compile_per_bucket_and_metrics_off_parity():
    t = _template()
    seeds = np.arange(5)  # B=5: unique shape -> fresh compile
    dims = sim.geometry_dims(t.geom)
    before = sim.trace_count("run_cells_hetero")
    out_m, _ = _run_at_dims(t, dims, seeds, metrics=True)
    out_m2, _ = _run_at_dims(t, dims, seeds, metrics=True)
    assert sim.trace_count("run_cells_hetero") - before == 1, \
        "same bucket + same seed-batch shape must share one compile"
    out_p, _ = _run_at_dims(t, dims, seeds, metrics=False)
    # metrics accumulation is observation, not dynamics: engine outputs
    # are bit-identical with the carry on or off
    for k in ("t", "it", "fbytes", "qd_acc"):
        if k in out_p:
            np.testing.assert_array_equal(np.asarray(out_m[k]),
                                          np.asarray(out_p[k]),
                                          err_msg=f"{k} differs")
    for k in ("h_qd", "h_fct", "wn", "wmean", "wm2"):
        assert k in out_m and k not in out_p
    # repeated identical replay is bit-reproducible
    np.testing.assert_array_equal(np.asarray(out_m["h_qd"]),
                                  np.asarray(out_m2["h_qd"]))


def test_1024_seed_replay_single_compile():
    """The acceptance-scale batch: 1024 seeds share ONE compile per
    geometry bucket, and the metric carry stays O(B x NBINS) — no
    buffer scales with the step budget."""
    t = _template()
    tp = wl.pad_template(t, sim.geometry_dims(t.geom))
    geoms = sim.stack_geometries([tp.geom])
    params = sim.stack_params([wl.lower_seeds(tp, np.arange(1024))])
    before = sim.trace_count("run_cells_hetero")
    out = sim.run_cells_hetero(geoms, params, np.int32(sim.TDONE_SLOTS),
                               chunk=16, max_chunks=1, stride=8,
                               metrics=True, with_trace=False)
    assert sim.trace_count("run_cells_hetero") - before == 1
    assert np.asarray(out["h_qd"]).shape == (1, 1024, met.NBINS)
    assert np.asarray(out["h_fct"]).shape == (1, 1024, met.NBINS)
    # with_trace=False collapses the trace buffer to a single slot
    assert np.asarray(out["trace"]).shape[-1] == 1


def test_run_replay_end_to_end_summary():
    t = _template()
    out, padded = wl.run_replay([t], np.arange(4), chunk=64, metrics=True)
    (s,) = wl.summarize_replay(out, padded)
    assert s["system"] == "cresco8" and s["n_nodes"] == 8
    assert s["qdelay_samples"] > 0
    # quantile monotonicity on the aggregate histograms
    qd = s["qdelay_s"]
    assert qd["0.999"] >= qd["0.99"] >= qd["0.5"] or np.isnan(qd["0.5"])
    # per-job summaries exist for every real job, none for pads
    names = set(s["jobs"])
    assert "shorts" in names
    assert any(n.startswith("tenant0") for n in names)
    assert not any(n == "_pad" for n in names)


def test_short_slots_one_shot_and_horizon():
    """A drained short slot never re-arms (SHORT_GAP_NEVER): running the
    replay twice as long never increases a slot's delivered bytes beyond
    drawn + one Euler-step quantum."""
    t = _template()
    seeds = np.arange(3)
    out, (tp,) = wl.run_replay([t], seeds, chunk=64, metrics=False)
    fb = np.asarray(out["fbytes"])[0]
    drawn = np.asarray(wl.lower_seeds(tp, seeds).bytes_per_iter)
    quantum = tp.host_caps * tp.dt
    excess = fb[:, tp.short_idx] - drawn[:, tp.short_idx] \
        - quantum[tp.short_idx][None, :]
    assert (excess <= 1.0).all(), float(excess.max())
    # inactive slots (0 drawn bytes) delivered exactly nothing
    idle = drawn[:, tp.short_idx] == 0.0
    assert (fb[:, tp.short_idx][idle] == 0.0).all()


# --------------------------------------------------------------------------
# bounded-Pareto short-flow size mix (ISSUE 10 satellite)
# --------------------------------------------------------------------------

_PAR_MIN, _PAR_MAX = float(32 << 10), float(8 << 20)


def _pareto_template(frac, alpha=1.3):
    t = _template()
    return dataclasses.replace(t, spec=dataclasses.replace(
        t.spec, short_pareto_frac=frac, short_pareto_alpha=alpha,
        short_pareto_min=_PAR_MIN, short_pareto_max=_PAR_MAX))


def test_pareto_mix_draw_inertness_and_bounds():
    """frac=1 swaps every active short size for a bounded-Pareto draw —
    and nothing else: activation, arrival times, CC kinds and staggers
    ride the untouched legacy key split, and every drawn size lands
    exactly inside [xm, xM] (inverse-CDF construction)."""
    t, tp = _template(), _pareto_template(1.0)
    p0, p1 = wl.lower_seed(t, 7), wl.lower_seed(tp, 7)
    for f in ("flow_start", "fct_mask", "kind"):
        np.testing.assert_array_equal(
            np.asarray(getattr(p0, f)), np.asarray(getattr(p1, f)),
            err_msg=f"{f} perturbed by the Pareto mix")
    bpi0 = np.asarray(p0.bytes_per_iter)
    bpi1 = np.asarray(p1.bytes_per_iter)
    sidx = np.asarray(t.short_idx)
    other = np.ones(len(bpi0), bool)
    other[sidx] = False
    np.testing.assert_array_equal(bpi0[other], bpi1[other])
    act = bpi1[sidx] > 0
    assert act.any()
    # same slots fire (activation stream untouched), idle slots stay 0
    np.testing.assert_array_equal(bpi0[sidx] > 0, act)
    np.testing.assert_array_equal(bpi1[sidx][~act], 0.0)
    # f32 lowering of exact-bound draws: one ulp of slack
    assert (bpi1[sidx][act] >= np.float32(_PAR_MIN) * (1 - 1e-6)).all()
    assert (bpi1[sidx][act] <= np.float32(_PAR_MAX) * (1 + 1e-6)).all()


def test_pareto_mix_conserves_unmixed_draws():
    """Partial mixing is a per-slot where(): the non-heavy slots keep
    their lognormal draw bit-for-bit (drawn-bytes conservation), the
    heavy slots are bounded-Pareto draws."""
    t, tm = _template(), _pareto_template(0.5)
    sidx = np.asarray(t.short_idx)
    s0 = np.asarray(wl.lower_seed(t, 11).bytes_per_iter)[sidx]
    sm = np.asarray(wl.lower_seed(tm, 11).bytes_per_iter)[sidx]
    active = s0 > 0
    assert active.any()
    same = (sm == s0) & active
    changed = (sm != s0) & active
    assert same.any() and changed.any(), (int(same.sum()),
                                          int(changed.sum()))
    assert (sm[changed] >= np.float32(_PAR_MIN) * (1 - 1e-6)).all()
    assert (sm[changed] <= np.float32(_PAR_MAX) * (1 + 1e-6)).all()


def test_pareto_mix_batch_invariant():
    tm = _pareto_template(0.35)
    p_one = wl.lower_seed(tm, 3)
    p_batch = wl.lower_seeds(tm, np.arange(8))
    for f in ("bytes_per_iter", "flow_start", "fct_mask", "kind"):
        np.testing.assert_array_equal(
            np.asarray(getattr(p_one, f)),
            np.asarray(getattr(p_batch, f))[3],
            err_msg=f"{f}: seed 3 alone != lane 3 under the Pareto mix")


def test_pareto_spec_validation():
    spec = _template().spec
    with pytest.raises(ValueError):
        dataclasses.replace(spec, short_pareto_frac=1.5)
    with pytest.raises(ValueError):
        dataclasses.replace(spec, short_pareto_frac=0.5,
                            short_pareto_min=2.0, short_pareto_max=1.0)
    with pytest.raises(ValueError):
        dataclasses.replace(spec, short_pareto_frac=0.5,
                            short_pareto_alpha=0.0)
