"""Per-kernel allclose tests: sweep shapes/dtypes in interpret=True mode and
assert against the pure-jnp oracles in kernels/ref.py (brief deliverable (c)).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref


def _rand(rng, shape, dtype):
    x = jax.random.normal(rng, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

FA_SHAPES = [
    # (B, Sq, Skv, H, KH, D)
    (1, 128, 128, 4, 4, 32),     # MHA square
    (2, 64, 64, 8, 2, 16),       # GQA 4:1
    (1, 96, 96, 4, 1, 64),       # MQA, non-multiple of block
    (1, 256, 256, 2, 2, 128),    # multi kv-block
]


@pytest.mark.parametrize("shape", FA_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(shape, dtype, causal):
    B, Sq, Skv, H, KH, D = shape
    rng = jax.random.PRNGKey(hash(shape) & 0xFFFF)
    kq, kk, kv = jax.random.split(rng, 3)
    q = _rand(kq, (B, Sq, H, D), dtype)
    k = _rand(kk, (B, Skv, KH, D), dtype)
    v = _rand(kv, (B, Skv, KH, D), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_flash_attention_matches_xla_path():
    """The Pallas kernel and the XLA-native flash path used by the models
    implement the same algorithm; they must agree."""
    from repro.models.layers import flash_attention_xla

    rng = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(rng, 3)
    q = _rand(kq, (2, 64, 4, 32), jnp.float32)
    k = _rand(kk, (2, 64, 2, 32), jnp.float32)
    v = _rand(kv, (2, 64, 2, 32), jnp.float32)
    a = ops.flash_attention(q, k, v, causal=True, block_q=32, block_kv=32)
    b = flash_attention_xla(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# selective scan
# --------------------------------------------------------------------------

SSM_SHAPES = [
    (1, 8, 64, 8),    # (B, T, Di, N)
    (2, 16, 128, 16),
    (1, 32, 96, 4),   # Di not a block multiple
]


@pytest.mark.parametrize("shape", SSM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan(shape, dtype):
    B, T, Di, N = shape
    rng = jax.random.PRNGKey(hash(shape) & 0xFFFF)
    ka, kb, kh = jax.random.split(rng, 3)
    # decay coefficients in (0, 1) like exp(dt*A)
    dA = jax.nn.sigmoid(jax.random.normal(ka, (B, T, Di, N))).astype(dtype)
    dBx = _rand(kb, (B, T, Di, N), dtype)
    h0 = _rand(kh, (B, Di, N), jnp.float32)
    hs, hT = ops.ssm_scan(dA, dBx, h0, block_d=64)
    hs_r, hT_r = ref.ssm_scan(dA, dBx, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_r), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_r), **_tol(dtype))


@pytest.mark.parametrize("shape", [(1, 8, 64, 8), (2, 12, 96, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_selective_scan(shape, dtype):
    """The fused kernel (dA on the fly + in-kernel C contraction — the
    §Perf F-series deploy path) must match the composed oracle."""
    B, T, Di, N = shape
    rng = jax.random.PRNGKey(hash(shape) & 0xFFF)
    kd, ka, kb, kc, kx, kh = jax.random.split(rng, 6)
    dt = jax.nn.softplus(jax.random.normal(kd, (B, T, Di))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ka, (Di, N))).astype(jnp.float32)
    Bc = _rand(kb, (B, T, N), dtype)
    Cc = _rand(kc, (B, T, N), dtype)
    x = _rand(kx, (B, T, Di), dtype)
    h0 = _rand(kh, (B, Di, N), jnp.float32)
    y, hT = ops.fused_selective_scan(dt, A, Bc, Cc, x, h0, block_d=32)
    y_r, hT_r = ref.fused_selective_scan(dt, A, Bc, Cc, x, h0)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else _tol(dtype)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r), **tol)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_r), **tol)


# --------------------------------------------------------------------------
# int8 quant / dequant
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape,block", [((4, 512), 256), ((1, 256), 256),
                                         ((8, 1024), 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_roundtrip(shape, block, dtype):
    rng = jax.random.PRNGKey(11)
    x = (_rand(rng, shape, dtype).astype(jnp.float32) * 3.0)
    q, s = ops.quantize_int8(x, block=block)
    q_r, s_r = ref.quantize_int8(x, block=block)
    # codes may differ by 1 on exact .5 rounding ties (fp associativity)
    dq = np.abs(np.asarray(q, np.int32) - np.asarray(q_r, np.int32))
    assert dq.max() <= 1 and (dq > 0).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_r), rtol=1e-6)
    back = ops.dequantize_int8(q, s, block=block)
    back_r = ref.dequantize_int8(q_r, s_r, block=block)
    # where codes agree dequant is exact; tie rows differ by <= one step
    step = float(np.asarray(s).max())
    np.testing.assert_allclose(np.asarray(back), np.asarray(back_r),
                               rtol=0, atol=step + 1e-6)
    # quantization error bounded by scale/2 per element
    err = np.abs(np.asarray(back) - np.asarray(x, np.float32))
    bound = np.repeat(np.asarray(s), shape[1] // s.shape[1], axis=1) * 0.5
    assert (err <= bound + 1e-6).all()


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 6), nb=st.integers(1, 4),
       scale_exp=st.integers(-3, 3), seed=st.integers(0, 2 ** 16))
def test_quant_roundtrip_property(rows, nb, scale_exp, seed):
    """Property: |x - dq(q(x))| <= scale/2, any magnitude, any shape."""
    block = 128
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(rows, nb * block) * 10.0 ** scale_exp,
                    jnp.float32)
    q, s = ref.quantize_int8(x, block=block)
    back = ref.dequantize_int8(q, s, block=block)
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.repeat(np.asarray(s), block, axis=1) * 0.5 + 1e-9
    assert (err <= bound).all()
    assert np.asarray(q).min() >= -127 and np.asarray(q).max() <= 127


# --------------------------------------------------------------------------
# fused ring-reduce accumulate
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(128, 512), (64, 384), (300, 640)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("scale", [1.0, 0.25])
def test_fused_accumulate(shape, dtype, scale):
    rng = jax.random.PRNGKey(5)
    k1, k2 = jax.random.split(rng)
    acc = _rand(k1, shape, dtype)
    x = _rand(k2, shape, dtype)
    got = ops.fused_accumulate(acc, x, scale=scale)
    want = ref.fused_accumulate(acc, x, scale=scale)
    assert got.dtype == acc.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_fused_accumulate_fp32_accumulation():
    """bf16 inputs must accumulate in fp32 (the kernel's whole point)."""
    acc = jnp.full((8, 128), 256.0, jnp.bfloat16)
    x = jnp.full((8, 128), 1.0, jnp.bfloat16)  # 256+1 not representable in bf16
    out = ops.fused_accumulate(acc, x, scale=1.0)
    # fp32 accumulate then round-to-nearest-bf16 gives 258 (256 rounds down)
    want = ref.fused_accumulate(acc, x, scale=1.0)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(want, np.float32))


# --------------------------------------------------------------------------
# fused fabric step core (kernels/fabric_step.py vs kernels/ref.py)
# --------------------------------------------------------------------------

# Contract (DESIGN.md §13): the Pallas kernel replaces XLA scatter-adds
# with one-hot matmul segment-sums, which may accumulate a segment in a
# different order — parity is fp32-allclose (the atol is ~1 byte/s on
# ~1e9 B/s magnitudes), and bit-exact whenever each segment has at most
# one contributor (single summand => no reassociation).
FS_TOL = dict(rtol=2e-4, atol=1.0)


def _core_case(rng, F, H, L, n_src, n_sw):
    return dict(
        plinks=rng.randint(0, L + 1, size=(F, H)).astype(np.int32),
        inject=(rng.rand(F) * 1e9).astype(np.float32),
        src_id=rng.randint(0, n_src, size=F).astype(np.int32),
        host_caps=((rng.rand(F) + 0.5) * 1e9).astype(np.float32),
        q=(rng.rand(L + 1) * 1e6).astype(np.float32),
        caps_finite=((rng.rand(L + 1) + 0.1) * 1e9).astype(np.float32),
        src_sw=rng.randint(0, n_sw, size=L + 1).astype(np.int32),
        dst_sw=rng.randint(0, n_sw, size=L + 1).astype(np.int32))


def _run_core(fn, case, n_src, n_sw, with_aux, qmax=2e6):
    occ = case["q"] / np.float32(qmax)
    return fn(case["plinks"], case["inject"], case["src_id"],
              case["host_caps"], case["q"], occ, case["caps_finite"],
              case["src_sw"], case["dst_sw"], jnp.float32(2e-6),
              jnp.float32(qmax), jnp.float32(0.6), jnp.float32(0.7),
              jnp.float32(0.05), n_src=n_src, n_sw=n_sw, with_aux=with_aux)


def _assert_core_match(got, want, tol=FS_TOL, msg=""):
    for k in want:
        if want[k] is None:
            assert got[k] is None, k
            continue
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   err_msg=f"{msg}{k}", **tol)


FS_SHAPES = [
    # (F, H, L, n_src, n_sw) — incl. non-multiples of the 128/256 blocks
    (7, 3, 13, 4, 5),
    (130, 5, 300, 33, 17),
    (256, 4, 255, 8, 8),
    (1, 1, 2, 1, 2),
]


@pytest.mark.parametrize("shape", FS_SHAPES)
@pytest.mark.parametrize("with_aux", [False, True])
def test_fabric_step_core(shape, with_aux):
    F, H, L, n_src, n_sw = shape
    rng = np.random.RandomState(hash(shape) & 0xFFFF)
    case = _core_case(rng, F, H, L, n_src, n_sw)
    want = _run_core(ref.fabric_step_core, case, n_src, n_sw, with_aux)
    got = _run_core(ops.fabric_step_core, case, n_src, n_sw, with_aux)
    _assert_core_match(got, want)


def test_fabric_step_core_bit_exact_disjoint():
    """With at most one contributor per (link, hop), per source, and per
    switch, every one-hot contraction sums a single nonzero term — the
    kernel must then be BIT-identical to the scatter reference."""
    F, H = 6, 3
    L = F * H + 4  # room for distinct links per (flow, hop)
    n_src, n_sw = F + 1, L + 2
    rng = np.random.RandomState(0)
    case = _core_case(rng, F, H, L, n_src, n_sw)
    case["plinks"] = np.arange(F * H, dtype=np.int32).reshape(F, H)
    case["src_id"] = np.arange(F, dtype=np.int32)
    case["src_sw"] = np.arange(1, L + 2, dtype=np.int32)
    case["dst_sw"] = np.roll(np.arange(1, L + 2, dtype=np.int32), 1)
    want = _run_core(ref.fabric_step_core, case, n_src, n_sw, True)
    got = _run_core(ops.fabric_step_core, case, n_src, n_sw, True)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]), err_msg=k)


@settings(max_examples=15, deadline=None)
@given(F=st.integers(1, 70), H=st.integers(1, 5), L=st.integers(1, 120),
       n_src=st.integers(1, 12), n_sw=st.integers(2, 10),
       seed=st.integers(0, 2 ** 16))
def test_fabric_step_core_property(F, H, L, n_src, n_sw, seed):
    """Property: kernel == oracle on random geometries of any shape."""
    rng = np.random.RandomState(seed)
    case = _core_case(rng, F, H, L, n_src, n_sw)
    want = _run_core(ref.fabric_step_core, case, n_src, n_sw, True)
    got = _run_core(ops.fabric_step_core, case, n_src, n_sw, True)
    _assert_core_match(got, want)


# ---- engine-level parity: the backend switch routes the whole step ----

def _engine_cell(coll, policy, n_nodes=8):
    from repro.core import congestion as cong
    from repro.core.fabric import cc as cc_lib, simulator as sim
    from repro.core.fabric import topology as topo_lib

    topo = topo_lib.leaf_spine(n_nodes)
    vidx, aidx = cong.interleaved_split(n_nodes)
    nodes = np.arange(n_nodes)
    flows = cong.build_flowset(topo, nodes[vidx], nodes[aidx], coll,
                               "incast", 1 << 20, phased=True)
    geom = sim.make_geometry(topo, flows)
    p = sim.make_params(cc_lib.dcqcn(), dt=2e-6,
                        bytes_per_iter=flows.bytes_per_iter,
                        host_caps=flows.host_caps,
                        env=cong.steady().params(), policy=policy,
                        flowlet_gap_s=50e-6)
    return geom, p


@pytest.mark.parametrize("policy", list(range(5)))
def test_fabric_step_engine_parity_policies(policy):
    """Lock-step step_debug parity (state AND aux observers) between the
    ref and pallas backends under every traced routing policy."""
    import jax
    from repro.core.fabric import simulator as sim

    geom, p = _engine_cell("ring_allreduce", policy)
    s_ref = jax.jit(lambda s: sim.step_debug(geom, p, s, backend="ref"))
    s_pal = jax.jit(lambda s: sim.step_debug(geom, p, s, backend="pallas"))
    state = sim.init_state(geom, p)
    for i in range(25):
        nr, gr, ar = s_ref(state)
        npal, gpal, apal = s_pal(state)
        np.testing.assert_allclose(np.asarray(gpal), np.asarray(gr),
                                   err_msg=f"goodput step {i}", **FS_TOL)
        for k in nr:
            np.testing.assert_allclose(np.asarray(npal[k]),
                                       np.asarray(nr[k]),
                                       err_msg=f"state {i} {k}", **FS_TOL)
        for k in ar:
            np.testing.assert_allclose(np.asarray(apal[k]),
                                       np.asarray(ar[k]),
                                       err_msg=f"aux {i} {k}", **FS_TOL)
        state = nr


def test_fabric_step_engine_parity_wildcard_phases():
    """The ring collectives' uniform schedules emit wildcard-phase flow
    rows (flow_phase < 0) — the gating happens upstream of the core, but
    the kernel must agree through phase transitions too."""
    import jax
    from repro.core.fabric import simulator as sim

    geom, p = _engine_cell("ring_allgather", 3)
    assert bool(np.any(np.asarray(geom.flow_phase) < 0))
    s_ref = jax.jit(lambda s: sim.step_debug(geom, p, s, backend="ref"))
    s_pal = jax.jit(lambda s: sim.step_debug(geom, p, s, backend="pallas"))
    state = sim.init_state(geom, p)
    for i in range(25):
        nr, _, _ = s_ref(state)
        npal, _, _ = s_pal(state)
        for k in nr:
            np.testing.assert_allclose(np.asarray(npal[k]),
                                       np.asarray(nr[k]),
                                       err_msg=f"{i} {k}", **FS_TOL)
        state = nr


def test_fabric_step_run_cells_backend_parity():
    """Full vmapped runs through run_cells: both backends must agree on
    the discrete outputs (iterations, chunk count) exactly and on the
    continuous ones within fp32 tolerance."""
    import jax
    from repro.core.fabric import simulator as sim

    geom, p0 = _engine_cell("ring_allreduce", 0)
    _, p3 = _engine_cell("ring_allreduce", 3)
    params = sim.stack_params([p0, p3])
    n = jnp.asarray(3, jnp.int32)
    kw = dict(chunk=128, max_chunks=12, stride=8)
    out_r = sim.run_cells(geom, params, n, backend="ref", **kw)
    out_p = sim.run_cells(geom, params, n, backend="pallas", **kw)
    for k in ("it", "chunks"):
        np.testing.assert_array_equal(np.asarray(out_r[k]),
                                      np.asarray(out_p[k]), err_msg=k)
    for k in ("t_done", "t", "fbytes"):
        np.testing.assert_allclose(np.asarray(out_p[k]),
                                   np.asarray(out_r[k]),
                                   err_msg=k, rtol=2e-3, atol=1e-5)


def test_fabric_step_hetero_padded_bucket_parity():
    """run_cells_hetero over bucket-padded stacked geometries (the PR 4
    scale-batched path): pallas must match ref through the nested vmap,
    and padding must stay inert under the kernel."""
    import jax
    from repro.core.fabric import simulator as sim

    g1, p1 = _engine_cell("ring_allreduce", 1, n_nodes=6)
    g2, p2 = _engine_cell("alltoall", 4, n_nodes=8)
    dims = sim.bucket_dims([g1, g2])
    geoms = sim.stack_geometries([sim.pad_geometry(g, dims)
                                  for g in (g1, g2)])

    def pad_p(p, g):
        F = dims.n_flows
        pad = lambda x: jnp.concatenate(
            [x, jnp.zeros((F - x.shape[0],), x.dtype)])
        return dataclasses.replace(p, bytes_per_iter=pad(p.bytes_per_iter),
                                   host_caps=pad(p.host_caps))
    params = sim.stack_params([pad_p(p1, g1), pad_p(p2, g2)])
    params = jax.tree_util.tree_map(lambda x: x[:, None], params)
    n = jnp.asarray(2, jnp.int32)
    kw = dict(chunk=128, max_chunks=10, stride=8)
    out_r = sim.run_cells_hetero(geoms, params, n, backend="ref", **kw)
    out_p = sim.run_cells_hetero(geoms, params, n, backend="pallas", **kw)
    for k in ("it", "chunks"):
        np.testing.assert_array_equal(np.asarray(out_r[k]),
                                      np.asarray(out_p[k]), err_msg=k)
    for k in ("t_done", "t"):
        np.testing.assert_allclose(np.asarray(out_p[k]),
                                   np.asarray(out_r[k]),
                                   err_msg=k, rtol=2e-3, atol=1e-5)


def test_fabric_step_backend_resolution():
    """Env var / override / explicit-argument resolution order, and the
    auto default (ref off-TPU)."""
    from repro.core.fabric import simulator as sim

    assert sim.resolve_step_backend() == "ref"  # CPU container
    assert sim.resolve_step_backend("pallas") == "pallas"
    sim.set_step_backend("pallas")
    try:
        assert sim.resolve_step_backend() == "pallas"
        assert sim.resolve_step_backend("ref") == "ref"  # arg wins
    finally:
        sim.set_step_backend(None)
    assert sim.resolve_step_backend() == "ref"
    with pytest.raises(ValueError):
        sim.resolve_step_backend("mosaic")
    with pytest.raises(ValueError):
        sim.set_step_backend("xla")
