"""Per-kernel allclose tests: sweep shapes/dtypes in interpret=True mode and
assert against the pure-jnp oracles in kernels/ref.py (brief deliverable (c)).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref


def _rand(rng, shape, dtype):
    x = jax.random.normal(rng, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

FA_SHAPES = [
    # (B, Sq, Skv, H, KH, D)
    (1, 128, 128, 4, 4, 32),     # MHA square
    (2, 64, 64, 8, 2, 16),       # GQA 4:1
    (1, 96, 96, 4, 1, 64),       # MQA, non-multiple of block
    (1, 256, 256, 2, 2, 128),    # multi kv-block
]


@pytest.mark.parametrize("shape", FA_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(shape, dtype, causal):
    B, Sq, Skv, H, KH, D = shape
    rng = jax.random.PRNGKey(hash(shape) & 0xFFFF)
    kq, kk, kv = jax.random.split(rng, 3)
    q = _rand(kq, (B, Sq, H, D), dtype)
    k = _rand(kk, (B, Skv, KH, D), dtype)
    v = _rand(kv, (B, Skv, KH, D), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_flash_attention_matches_xla_path():
    """The Pallas kernel and the XLA-native flash path used by the models
    implement the same algorithm; they must agree."""
    from repro.models.layers import flash_attention_xla

    rng = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(rng, 3)
    q = _rand(kq, (2, 64, 4, 32), jnp.float32)
    k = _rand(kk, (2, 64, 2, 32), jnp.float32)
    v = _rand(kv, (2, 64, 2, 32), jnp.float32)
    a = ops.flash_attention(q, k, v, causal=True, block_q=32, block_kv=32)
    b = flash_attention_xla(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# selective scan
# --------------------------------------------------------------------------

SSM_SHAPES = [
    (1, 8, 64, 8),    # (B, T, Di, N)
    (2, 16, 128, 16),
    (1, 32, 96, 4),   # Di not a block multiple
]


@pytest.mark.parametrize("shape", SSM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan(shape, dtype):
    B, T, Di, N = shape
    rng = jax.random.PRNGKey(hash(shape) & 0xFFFF)
    ka, kb, kh = jax.random.split(rng, 3)
    # decay coefficients in (0, 1) like exp(dt*A)
    dA = jax.nn.sigmoid(jax.random.normal(ka, (B, T, Di, N))).astype(dtype)
    dBx = _rand(kb, (B, T, Di, N), dtype)
    h0 = _rand(kh, (B, Di, N), jnp.float32)
    hs, hT = ops.ssm_scan(dA, dBx, h0, block_d=64)
    hs_r, hT_r = ref.ssm_scan(dA, dBx, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_r), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_r), **_tol(dtype))


@pytest.mark.parametrize("shape", [(1, 8, 64, 8), (2, 12, 96, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_selective_scan(shape, dtype):
    """The fused kernel (dA on the fly + in-kernel C contraction — the
    §Perf F-series deploy path) must match the composed oracle."""
    B, T, Di, N = shape
    rng = jax.random.PRNGKey(hash(shape) & 0xFFF)
    kd, ka, kb, kc, kx, kh = jax.random.split(rng, 6)
    dt = jax.nn.softplus(jax.random.normal(kd, (B, T, Di))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ka, (Di, N))).astype(jnp.float32)
    Bc = _rand(kb, (B, T, N), dtype)
    Cc = _rand(kc, (B, T, N), dtype)
    x = _rand(kx, (B, T, Di), dtype)
    h0 = _rand(kh, (B, Di, N), jnp.float32)
    y, hT = ops.fused_selective_scan(dt, A, Bc, Cc, x, h0, block_d=32)
    y_r, hT_r = ref.fused_selective_scan(dt, A, Bc, Cc, x, h0)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else _tol(dtype)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r), **tol)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_r), **tol)


# --------------------------------------------------------------------------
# int8 quant / dequant
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape,block", [((4, 512), 256), ((1, 256), 256),
                                         ((8, 1024), 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_roundtrip(shape, block, dtype):
    rng = jax.random.PRNGKey(11)
    x = (_rand(rng, shape, dtype).astype(jnp.float32) * 3.0)
    q, s = ops.quantize_int8(x, block=block)
    q_r, s_r = ref.quantize_int8(x, block=block)
    # codes may differ by 1 on exact .5 rounding ties (fp associativity)
    dq = np.abs(np.asarray(q, np.int32) - np.asarray(q_r, np.int32))
    assert dq.max() <= 1 and (dq > 0).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_r), rtol=1e-6)
    back = ops.dequantize_int8(q, s, block=block)
    back_r = ref.dequantize_int8(q_r, s_r, block=block)
    # where codes agree dequant is exact; tie rows differ by <= one step
    step = float(np.asarray(s).max())
    np.testing.assert_allclose(np.asarray(back), np.asarray(back_r),
                               rtol=0, atol=step + 1e-6)
    # quantization error bounded by scale/2 per element
    err = np.abs(np.asarray(back) - np.asarray(x, np.float32))
    bound = np.repeat(np.asarray(s), shape[1] // s.shape[1], axis=1) * 0.5
    assert (err <= bound + 1e-6).all()


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 6), nb=st.integers(1, 4),
       scale_exp=st.integers(-3, 3), seed=st.integers(0, 2 ** 16))
def test_quant_roundtrip_property(rows, nb, scale_exp, seed):
    """Property: |x - dq(q(x))| <= scale/2, any magnitude, any shape."""
    block = 128
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(rows, nb * block) * 10.0 ** scale_exp,
                    jnp.float32)
    q, s = ref.quantize_int8(x, block=block)
    back = ref.dequantize_int8(q, s, block=block)
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.repeat(np.asarray(s), block, axis=1) * 0.5 + 1e-9
    assert (err <= bound).all()
    assert np.asarray(q).min() >= -127 and np.asarray(q).max() <= 127


# --------------------------------------------------------------------------
# fused ring-reduce accumulate
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(128, 512), (64, 384), (300, 640)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("scale", [1.0, 0.25])
def test_fused_accumulate(shape, dtype, scale):
    rng = jax.random.PRNGKey(5)
    k1, k2 = jax.random.split(rng)
    acc = _rand(k1, shape, dtype)
    x = _rand(k2, shape, dtype)
    got = ops.fused_accumulate(acc, x, scale=scale)
    want = ref.fused_accumulate(acc, x, scale=scale)
    assert got.dtype == acc.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_fused_accumulate_fp32_accumulation():
    """bf16 inputs must accumulate in fp32 (the kernel's whole point)."""
    acc = jnp.full((8, 128), 256.0, jnp.bfloat16)
    x = jnp.full((8, 128), 1.0, jnp.bfloat16)  # 256+1 not representable in bf16
    out = ops.fused_accumulate(acc, x, scale=1.0)
    # fp32 accumulate then round-to-nearest-bf16 gives 258 (256 rounds down)
    want = ref.fused_accumulate(acc, x, scale=1.0)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(want, np.float32))
