"""Batched-engine tests: run_grid/vmap vs per-cell equivalence, envelope
fixed points and duty cycles, CC-kind-as-data dispatch, dt quantization,
and the scale-batched geometry engine (padding bit-identity, bucket
compile counts, cross-scale ratio agreement)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bench, congestion as cong, envelopes as env_lib
from repro.core.fabric import simulator as sim_lib, systems


# --------------------------------------------------------------------------
# vmapped grid == sequential per-cell within tolerance
# --------------------------------------------------------------------------

def test_run_grid_matches_run_point():
    sysp = systems.get_system("nanjing_ecmp")
    sizes = [1 << 20, 8 << 20]
    profiles = [cong.steady(), cong.bursty(1e-3, 1e-3)]
    grid = bench.run_grid(sysp, 8, "alltoall", "alltoall", sizes, profiles,
                          n_iters=12, warmup=3)
    assert len(grid) == len(sizes) * len(profiles)
    by_label = {p.label(): p for p in profiles}
    for r in grid:
        pt = bench.run_point(sysp, 8, "alltoall", "alltoall", r.vector_bytes,
                             by_label[r.profile], n_iters=12, warmup=3)
        assert np.isclose(r.t_uncongested_s, pt.t_uncongested_s, rtol=0.02), \
            (r.profile, r.vector_bytes, r.t_uncongested_s, pt.t_uncongested_s)
        assert np.isclose(r.t_congested_s, pt.t_congested_s, rtol=0.02), \
            (r.profile, r.vector_bytes, r.t_congested_s, pt.t_congested_s)
        assert np.isclose(r.ratio, pt.ratio, rtol=0.03)


def test_grid_baseline_shared_across_profiles():
    """All cells of one vector size report the same uncongested time."""
    sysp = systems.get_system("lumi")
    grid = bench.run_grid(sysp, 16, "ring_allgather", "incast", [2 << 20],
                          [cong.steady(), cong.bursty(2e-3, 2e-3)],
                          n_iters=10, warmup=2)
    t_u = {r.t_uncongested_s for r in grid}
    assert len(t_u) == 1


# --------------------------------------------------------------------------
# Scale-batched geometry engine: padding is provably inert
# --------------------------------------------------------------------------

RUN_KW = dict(chunk=512, max_chunks=40, stride=8)


def _run_outputs(geom, params, n_iters=8):
    out = sim_lib.run_cell(geom, params, jnp.asarray(n_iters, jnp.int32),
                           **RUN_KW)
    return {k: np.asarray(v) for k, v in out.items()}


def _assert_bit_identical(out0, out1, label):
    """Real-prefix outputs of the padded run must equal the unpadded run
    bit for bit (padded jobs append extra t_done/it rows — sliced off)."""
    for k in ("t_done", "it", "qd_acc", "t", "trace", "chunks"):
        a0, a1 = out0[k], out1[k]
        if k in ("t_done", "it"):
            a1 = a1[: a0.shape[0]]
        assert np.array_equal(a0, a1), (label, k)


@pytest.mark.parametrize("sysn,n_nodes", [("cresco8", 16),
                                          ("nanjing_ecmp", 8)])
def test_padded_geometry_bit_identical(sysn, n_nodes):
    """A cell padded to a strictly larger bucket shape (every dim grown,
    incl. flows/jobs/links/switches) reproduces the unpadded run exactly."""
    sysp = systems.get_system(sysn)
    case = bench.build_case(sysp, n_nodes, "ring_allgather", "alltoall")
    dt = bench.choose_dt(case.topo, case.n_victims, 2 << 20, case.lat())
    p = case.cell_params(2 << 20, cong.steady(), dt)
    out0 = _run_outputs(case.geom, p)

    cur = sim_lib.geometry_dims(case.geom)
    dims = sim_lib.GeometryDims(
        n_links=cur.n_links + 37, n_flows=cur.n_flows + 13,
        k_max=cur.k_max + 2, max_hops=cur.max_hops + 3,
        n_sw=cur.n_sw + 5, n_src=cur.n_src + 4, n_jobs=cur.n_jobs + 2,
        n_phases=cur.n_phases + 1)
    padded = sim_lib.pad_geometry(case.geom, dims)
    pp = case.cell_params(2 << 20, cong.steady(), dt,
                          n_flows=dims.n_flows)
    out1 = _run_outputs(padded, pp)
    _assert_bit_identical(out0, out1, sysn)


def test_pruned_geometry_bit_identical():
    """Link pruning (machine topology -> allocation-touched links) is a
    pure index remap: flow-visible outputs match the unpruned geometry
    bit for bit."""
    sysp = systems.get_system("cresco8")
    topo = bench.machine_topology(sysp)
    nodes = bench.allocate(sysp, 12)
    vidx, aidx = cong.interleaved_split(12)
    flows = cong.build_flowset(topo, nodes[vidx], nodes[aidx],
                               "ring_allgather", "incast", 2 << 20,
                               k_max=sysp.k_max)
    dt = 4e-6
    outs = {}
    for prune in (False, True):
        geom = sim_lib.make_geometry(topo, flows, prune=prune)
        params = sim_lib.make_params(
            sysp.cc, dt=dt, bytes_per_iter=flows.bytes_per_iter,
            host_caps=flows.host_caps, env=cong.steady().params(),
            policy=systems.default_policy(sysp))
        outs[prune] = _run_outputs(geom, params)
    assert outs[True]["t_done"].shape == outs[False]["t_done"].shape
    _assert_bit_identical(outs[False], outs[True], "prune")


def test_scale_grid_matches_sequential_one_compile_per_bucket():
    """The acceptance sweep: 4 scales x 2 systems through run_grid's
    scale-batched path — at most one simulator compile per geometry
    bucket (both systems route adaptively -> exactly one bucket), and
    ratios matching the sequential per-scale loop."""
    cells = [(s, n) for s in ("cresco8", "lumi") for n in (8, 12, 16, 24)]
    sizes = [1 << 20]
    profiles = [cong.steady()]
    before = sim_lib.trace_count("run_cells_hetero")
    batched = bench.run_grid(cells, 0, "ring_allgather", "incast", sizes,
                             profiles, n_iters=8, warmup=2)
    # one bucket -> at most one compile (0 if an identical bucket shape
    # is already warm in this session's JIT cache)
    assert sim_lib.trace_count("run_cells_hetero") - before <= 1
    assert len(batched) == len(cells) * len(sizes) * len(profiles)

    seq = []
    for s, n in cells:
        seq += bench.run_grid(systems.get_system(s), n, "ring_allgather",
                              "incast", sizes, profiles, n_iters=8,
                              warmup=2)
    for rb, rs in zip(batched, seq):
        assert (rb.system, rb.n_nodes, rb.vector_bytes, rb.profile) \
            == (rs.system, rs.n_nodes, rs.vector_bytes, rs.profile)
        assert np.isclose(rb.t_uncongested_s, rs.t_uncongested_s,
                          rtol=1e-6), (rb.system, rb.n_nodes)
        assert np.isclose(rb.t_congested_s, rs.t_congested_s, rtol=1e-6)
        assert np.isclose(rb.ratio, rs.ratio, rtol=1e-6)

    # a second sweep with the same bucket shape reuses the compile
    before = sim_lib.trace_count("run_cells_hetero")
    bench.run_grid(cells, 0, "ring_allgather", "incast", sizes, profiles,
                   n_iters=8, warmup=2)
    assert sim_lib.trace_count("run_cells_hetero") - before == 0


def test_mixed_routing_single_bucket_single_compile():
    """Routing policy is traced data (SimParams.policy) since the
    mitigation lab, so a cell list mixing fixed-routing (haicgu_ib,
    nanjing ECMP+NSLB static tables) and adaptive-routing (cresco8)
    systems pads into ONE GeometryDims bucket and costs at most ONE
    simulator compile — the routing-mode bucket split is gone — and
    every cell still reports results."""
    cells = [("haicgu_ib", 8), ("cresco8", 8), ("nanjing_nslb", 8),
             ("nanjing_ecmp", 8)]
    before = sim_lib.trace_count("run_cells_hetero")
    rows = bench.run_scale_grid(cells, "ring_allgather", "incast",
                                [1 << 20], [cong.steady()], n_iters=6,
                                warmup=1)
    assert sim_lib.trace_count("run_cells_hetero") - before <= 1
    assert [r.system for r in rows] == ["haicgu_ib", "cresco8",
                                        "nanjing_nslb", "nanjing_ecmp"]
    assert all(0.0 < r.ratio <= 1.1 for r in rows)


# --------------------------------------------------------------------------
# envelopes: fixed points, duty cycles, traceable == host mirror
# --------------------------------------------------------------------------

def test_off_steady_fixed_points():
    t = np.linspace(0.0, 1.0, 5000)
    assert (env_lib.envelope_np(cong.no_congestion().params(), t) == 0).all()
    assert (env_lib.envelope_np(cong.steady().params(), t) == 1).all()
    # traceable path agrees at sampled times
    for prof, want in ((cong.no_congestion(), 0.0), (cong.steady(), 1.0)):
        env = jnp.asarray(prof.params())
        for tv in (0.0, 1e-4, 0.37):
            assert float(env_lib.envelope_at(env, jnp.float32(tv))) == want


@pytest.mark.parametrize("burst,pause", [(2e-3, 1e-3), (0.5e-3, 8e-3),
                                         (8e-3, 0.2e-3)])
def test_parameterized_duty_cycles(burst, pause):
    """Mean envelope ~= burst/(burst+pause) for periodic AND random
    profiles with the same nominal duty cycle."""
    want = burst / (burst + pause)
    n, dt = 400_000, (burst + pause) / 400.0
    for prof in (cong.bursty(burst, pause),
                 cong.random_onoff(burst, pause, seed=2)):
        duty = prof.envelope(0.0, n, dt).mean()
        assert abs(duty - want) < 0.04, (prof.label(), duty, want)


def test_envelope_traceable_matches_host():
    prof = cong.bursty(1.7e-3, 0.9e-3)
    env = jnp.asarray(prof.params())
    ts = np.linspace(0.0, 0.05, 301).astype(np.float32)
    host = env_lib.envelope_np(prof.params(), ts)
    traced = np.array([float(env_lib.envelope_at(env, jnp.float32(t)))
                       for t in ts])
    assert (host == traced).mean() > 0.99  # float32 period-edge wiggle only


def test_multi_tenant_mix_blends():
    mix = cong.multi_tenant((cong.steady(), 0.25),
                            (cong.bursty(1e-3, 1e-3), 0.5))
    vals = env_lib.envelope_np(mix.params(), np.linspace(0, 0.1, 20_000))
    assert vals.min() >= 0.0 and vals.max() <= 1.0
    assert 0.25 <= vals.mean() <= 0.75  # 0.25 base + 0.5 * ~50% duty
    assert set(np.round(np.unique(vals), 4)) == {0.25, 0.75}


def test_mix_component_overflow_raises():
    parts = tuple((cong.bursty(1e-3, 1e-3), 0.2)
                  for _ in range(env_lib.ENV_COMPONENTS + 1))
    with pytest.raises(ValueError):
        cong.multi_tenant(*parts).params()


# --------------------------------------------------------------------------
# CC kind is data: heterogeneous kinds batch in one vmapped call
# --------------------------------------------------------------------------

def test_mixed_cc_kinds_batch():
    from repro.core.fabric import cc as cc_lib

    sysp = systems.get_system("haicgu_ib")
    case = bench.build_case(sysp, 8, "ring_allgather", "incast")
    v, dt = 4 << 20, 4e-6
    ccs = [cc_lib.dcqcn(), cc_lib.infiniband("edr"), cc_lib.slingshot(),
           cc_lib.ai_ecn()]
    params = [sim_lib.make_params(
        c, dt=dt,
        bytes_per_iter=np.where(case.is_victim, case.unit_bytes * v, 1e30),
        host_caps=case.host_caps, env=cong.steady().params()) for c in ccs]
    batched = sim_lib.run_cells(case.geom, sim_lib.stack_params(params),
                                jnp.asarray(8, jnp.int32),
                                chunk=512, max_chunks=40, stride=8)
    for i, p in enumerate(params):
        single = sim_lib.run_cell(case.geom, p, jnp.asarray(8, jnp.int32),
                                  chunk=512, max_chunks=40, stride=8)
        res_b = sim_lib.summarize(batched, n_iters=8, warmup=2, dt=dt,
                                  chunk=512, stride=8, cell=i)
        res_s = sim_lib.summarize(single, n_iters=8, warmup=2, dt=dt,
                                  chunk=512, stride=8)
        assert res_b.n_done == res_s.n_done
        assert np.allclose(res_b.iter_times, res_s.iter_times, rtol=1e-4), \
            ccs[i].kind
    # distinct CC kinds must actually behave differently under incast
    times = [sim_lib.summarize(batched, n_iters=8, warmup=2, dt=dt,
                               chunk=512, stride=8, cell=i).iter_times.mean()
             for i in range(len(ccs))]
    assert len({round(float(t), 8) for t in times}) > 1


# --------------------------------------------------------------------------
# dt ladder
# --------------------------------------------------------------------------

def test_quantize_dt_ladder():
    for raw, want in ((1e-6, 1e-6), (3.1e-6, 2e-6), (200e-6, 128e-6),
                      (0.3e-6, 1e-6)):
        assert bench.quantize_dt(raw) == want
    # quantization never coarsens beyond the raw estimate (except the floor)
    for raw in np.geomspace(1e-6, 2e-4, 40):
        q = bench.quantize_dt(float(raw))
        assert q in bench.DT_LADDER_S
        assert q <= raw or q == bench.DT_LADDER_S[0]


def test_straggler_param():
    out = bench.straggler_impact(systems.get_system("haicgu_ib"), 8,
                                 "ring_allgather", 4 << 20, slow_factor=0.2,
                                 n_iters=10, straggler=0)
    assert out["slowdown"] > 2.0

# --------------------------------------------------------------------------
# Measurement-correctness regressions (ISSUE 7 satellite batch)
# --------------------------------------------------------------------------

def _one_cell_out(n_iters=8, max_chunks=40):
    sysp = systems.get_system("cresco8")
    case = bench.build_case(sysp, 8, "ring_allgather", "incast")
    dt = bench.choose_dt(case.topo, case.n_victims, 1 << 20, case.lat())
    p = case.cell_params(1 << 20, cong.steady(), dt)
    out = sim_lib.run_cell(case.geom, p, jnp.asarray(n_iters, jnp.int32),
                           chunk=512, max_chunks=max_chunks, stride=8)
    return out, dt


def test_summarize_excludes_warmup_and_flags_contamination():
    """A run whose completed-iteration count never clears the warmup
    prefix must not average warmup iterations into iter_times (the old
    behavior): it keeps only the last iteration and flags warmup_ok."""
    out, dt = _one_cell_out(n_iters=8)
    kw = dict(dt=dt, chunk=512, stride=8)
    full = sim_lib.summarize(out, n_iters=8, warmup=2, **kw)
    assert full.warmup_ok and full.n_done == 8
    assert len(full.iter_times) == full.n_done - 2

    tainted = sim_lib.summarize(out, n_iters=8, warmup=8, **kw)
    assert not tainted.warmup_ok
    assert len(tainted.iter_times) == 1  # last iteration only
    # the surviving sample is the LAST (steadiest) iteration, and the
    # contaminated mean (all 8, warmup included) is gone
    raw = np.diff(np.concatenate(
        [[0.0], np.asarray(out["t_done"])[0][:8]]))
    assert tainted.iter_times[0] == raw[-1]


def test_zero_completion_is_nan_dnf_not_inf():
    """A cell that completes zero iterations inside the step budget is an
    explicit DNF: mean_iter_time is NaN (never the old inf that poisoned
    downstream ratio aggregation) and run_grid flags the rows."""
    out, dt = _one_cell_out(n_iters=8, max_chunks=1)
    res = sim_lib.summarize(out, n_iters=8, warmup=2, dt=dt, chunk=512,
                            stride=8)
    if res.n_done == 0:  # chunk budget too small to close one iteration
        t = bench.mean_iter_time(res, lat=1e-6)
        assert np.isnan(t) and not np.isinf(t)

    sysp = systems.get_system("cresco8")
    # a fine dt with a tiny step budget: no lane can close an iteration
    rows = bench.run_grid(sysp, 8, "ring_allgather", "incast", [64 << 20],
                          [cong.steady()], n_iters=8, warmup=2, dt=1e-6,
                          max_steps=512, chunk=512)
    assert all(r.dnf for r in rows)
    assert all(np.isnan(r.ratio) for r in rows)
    ok = bench.run_grid(sysp, 8, "ring_allgather", "incast", [1 << 20],
                        [cong.steady()], n_iters=8, warmup=2)
    assert not any(r.dnf for r in ok)
    assert all(np.isfinite(r.ratio) for r in ok)


def test_topology_cache_keys_on_builder_identity():
    """_TOPO_CACHE used to key on (name, n) alone: a preset re-registered
    under the same name with a different builder (or size) silently got
    the stale topology. The key now fingerprints the builder."""
    sysp = systems.get_system("cresco8")
    base = bench.machine_topology(sysp)
    assert bench.machine_topology(sysp) is base  # cache hit

    modified = dataclasses.replace(
        sysp, make_topology=lambda n: systems.get_system(
            "lumi").make_topology(n))
    alt = bench.machine_topology(modified)
    assert alt is not base
    assert (alt.n_links, alt.name) != (base.n_links, base.name)

    bench.clear_topology_cache()
    assert bench.machine_topology(sysp) is not base  # rebuilt


def test_allocate_seed_scale_mixing():
    """seed+n_nodes seeding made (seed=7, n=8) and (seed=8, n=7) the same
    RNG draw; splitmix64 mixing must decouple them (and distinct scales
    under one seed must not be near-copies)."""
    sysp = systems.get_system("lumi")
    a = bench.allocate(sysp, 8, seed=7)
    b = bench.allocate(sysp, 7, seed=8)
    assert not set(b) <= set(a)  # old scheme: b was a subset-like twin
    # determinism and validity
    np.testing.assert_array_equal(a, bench.allocate(sysp, 8, seed=7))
    assert len(set(a)) == 8 and a.max() < sysp.machine_nodes
    # neighboring scales draw unrelated (not prefix-nested) node sets
    n16 = bench.allocate(sysp, 16, seed=7)
    n17 = bench.allocate(sysp, 17, seed=7)
    assert len(set(n16) & set(n17)) < 16
