"""Checkpoint tests: roundtrip, atomicity, async, resume, cleanup, elastic
restore onto a different mesh (subprocess with 8 devices)."""
import json
import os
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt


def _state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,))},
            "opt": {"m": {"w": jnp.zeros((3, 4)), "b": jnp.zeros((4,))}},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 7, _state())
    out = ckpt.restore(root, _state())
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), _state(), out)


def test_latest_step_ignores_uncommitted(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 5, _state())
    # a partial (crashed) write: directory without COMMIT
    os.makedirs(os.path.join(root, "step_00000009"))
    with open(os.path.join(root, "step_00000009", "index.json"), "w") as f:
        json.dump({}, f)
    assert ckpt.latest_step(root) == 5


def test_restore_rejects_shape_mismatch(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 1, _state())
    bad = _state()
    bad["params"]["w"] = jnp.zeros((5, 5))
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(root, bad)


def test_async_checkpointer_and_cleanup(tmp_path):
    root = str(tmp_path)
    ac = ckpt.AsyncCheckpointer(root, keep=2)
    for step in (10, 20, 30, 40):
        ac.save(step, _state())
    ac.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(root)
                   if n.startswith("step_"))
    assert steps == [30, 40]
    assert ckpt.latest_step(root) == 40


def test_async_snapshot_isolated_from_mutation(tmp_path):
    """The device->host snapshot must be taken synchronously: mutating the
    'live' state after save() must not affect what lands on disk."""
    root = str(tmp_path)
    ac = ckpt.AsyncCheckpointer(root)
    state = {"w": jnp.ones((4,))}
    ac.save(1, state)
    state["w"] = state["w"] * 100.0  # training continues immediately
    ac.wait()
    out = ckpt.restore(root, {"w": jnp.zeros((4,))})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((4,)))


def test_meta_roundtrip(tmp_path):
    root = str(tmp_path)
    ckpt.save(root, 3, _state(), extra_meta={"loss": 1.25})
    assert ckpt.checkpoint_step_meta(root, 3)["loss"] == 1.25


_ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import checkpoint as ckpt

root = sys.argv[1]
mesh8 = jax.make_mesh((8, 1), ("data", "model"))
specs = {"w": P("data", "model"), "b": P(None)}
w = jnp.arange(64.0).reshape(8, 8)
state = {"w": jax.device_put(w, NamedSharding(mesh8, specs["w"])),
         "b": jax.device_put(jnp.ones((3,)), NamedSharding(mesh8, specs["b"]))}
ckpt.save(root, 11, state, specs=specs)

# elastic restore onto a 4x2 mesh (as if half the hosts were lost and the
# model axis regrown from spares)
mesh4 = jax.make_mesh((4, 2), ("data", "model"))
like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32),
        "b": jax.ShapeDtypeStruct((3,), jnp.float32)}
out = ckpt.restore(root, like, mesh=mesh4, specs=specs)
ok = bool(jnp.all(out["w"] == w))
shard_shapes = sorted({tuple(s.data.shape) for s in out["w"].addressable_shards})
print("REPORT" + json.dumps({
    "values_ok": ok,
    "shard_shapes": [list(s) for s in shard_shapes],
    "n_shards": len(out["w"].addressable_shards)}))
"""


def test_elastic_restore_different_mesh(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _ELASTIC_SCRIPT, str(tmp_path)],
                       env=env, capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("REPORT")][-1]
    rep = json.loads(line[len("REPORT"):])
    assert rep["values_ok"]
    assert rep["n_shards"] == 8
    assert rep["shard_shapes"] == [[2, 4]]  # (8/4, 8/2) on the new mesh
