"""Traffic-program IR tests: schedule compilation vs the analytic wire
model, phase-structure invariants, bandwidth lower bounds, phased-vs-
flattened congestion divergence, and multi-job mixes through run_grid."""
import numpy as np
import pytest

from repro.core import bench, congestion as cong, traffic
from repro.core.collectives import wire_bytes_model
from repro.core.fabric import systems

KINDS = ("ring_allgather", "ring_allreduce", "alltoall",
         "pairwise_alltoall", "incast")


# --------------------------------------------------------------------------
# compiler: phased bytes x steps == wire_bytes_model, for every kind
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("n", [4, 5, 8])
@pytest.mark.parametrize("phased", [True, False])
def test_program_matches_wire_model(kind, n, phased):
    v = 3 << 20
    job = traffic.JobSpec("j", kind, v, nodes=tuple(range(n)),
                          phased=phased)
    prog = traffic.compile_programs([job])  # validate=True raises on drift
    got = traffic.job_wire_stats(prog, 0)
    model = wire_bytes_model(traffic.WIRE_KIND[kind], n, v)
    assert np.isclose(got["bytes"], model["bytes"], rtol=1e-6)
    if phased:
        want = model["steps"] if kind != "alltoall" else \
            wire_bytes_model("pairwise_all_to_all", n, v)["steps"]
        assert got["steps"] == want
    else:
        assert got["steps"] == 1


def test_pairwise_phases_are_perfect_matchings():
    """Power-of-two pairwise AlltoAll: every phase pairs each rank with
    exactly one partner (r XOR k), and partners are symmetric."""
    phases = traffic.compile_phases("pairwise_alltoall", range(8), 8.0)
    assert len(phases) == 7
    for k, ph in enumerate(phases, start=1):
        srcs = [s for s, _, _ in ph.flows]
        assert sorted(srcs) == list(range(8))  # each rank sends once
        pair = {s: d for s, d, _ in ph.flows}
        assert all(pair[d] == s for s, d in pair.items())  # symmetric
        assert all(d == s ^ k for s, d in pair.items())


def test_incast_phases_serialize_fan_in():
    phases = traffic.compile_phases("incast", range(6), 5.0)
    assert len(phases) == 5
    for ph in phases:
        assert len(ph.flows) == 1 and ph.flows[0][1] == 0


def test_compile_rejects_byte_drift():
    """The validator must catch a program whose bytes disagree with the
    analytic model."""
    job = traffic.JobSpec("j", "ring_allgather", 1 << 20,
                          nodes=tuple(range(4)))
    prog = traffic.compile_programs([job])
    prog.bytes_per_phase = prog.bytes_per_phase * 2.0
    with pytest.raises(ValueError):
        traffic.check_program(prog)


def test_uniform_ring_schedule_collapses_to_wildcard_rows():
    """Phased ring schedules reuse the same n neighbor edges every step,
    so the packed program stores one wildcard row per edge (re-armed at
    each phase entry) instead of n_phases copies."""
    n = 8
    job = traffic.JobSpec("j", "ring_allreduce", 1 << 20,
                          nodes=tuple(range(n)), phased=True)
    prog = traffic.compile_programs([job])
    assert prog.n_flows == n  # not n * 2(n-1)
    assert (prog.flow_phase == traffic.WILDCARD_PHASE).all()
    assert int(prog.n_phases[0]) == 2 * (n - 1)
    # non-uniform schedules (pairwise, incast) keep per-phase rows
    pw = traffic.compile_programs([traffic.JobSpec(
        "p", "pairwise_alltoall", 1 << 20, nodes=tuple(range(n)))])
    assert (pw.flow_phase >= 0).all() and pw.n_flows == n * (n - 1)


def test_split_nodes_never_double_books_pinned_nodes():
    jobs = [traffic.JobSpec("a", "alltoall"),
            traffic.JobSpec("b", "incast", nodes=(0, 1, 2, 3))]
    out = traffic.split_nodes(range(8), jobs)
    assert out[0].nodes == (4, 5, 6, 7)  # pinned nodes excluded
    assert out[1].nodes == (0, 1, 2, 3)


def test_zero_flow_job_rejected():
    """A job whose node share is too small to run its collective must
    fail loudly at compile time, not silently complete empty phases."""
    with pytest.raises(ValueError, match="zero flows"):
        traffic.compile_programs(
            [traffic.JobSpec("j", "alltoall", nodes=(3,))])


def test_split_nodes_interleaves():
    jobs = [traffic.JobSpec("a", "alltoall"), traffic.JobSpec("b", "incast")]
    out = traffic.split_nodes(range(8), jobs)
    assert out[0].nodes == (0, 2, 4, 6)
    assert out[1].nodes == (1, 3, 5, 7)
    # pre-assigned nodes survive
    pinned = traffic.JobSpec("c", "alltoall", nodes=(9, 11))
    out2 = traffic.split_nodes(range(8), [jobs[0], pinned])
    assert out2[1].nodes == (9, 11) and out2[0].nodes == tuple(range(8))


# --------------------------------------------------------------------------
# engine: phased programs respect physics and diverge from flattened ones
# --------------------------------------------------------------------------

def test_phased_ring_allreduce_bandwidth_lower_bound():
    """An uncongested phased ring AllReduce can complete no faster than
    its wire bytes over the injection rate (per-phase barriers only ever
    add time)."""
    sysp = systems.get_system("haicgu_ib")  # single switch, 100 Gb/s
    n, v = 8, 8 << 20
    r = bench.run_point(sysp, n, "ring_allreduce", "", v,
                        cong.no_congestion(), n_iters=12, warmup=3,
                        phased=True)
    cap = 100e9 / 8.0  # B/s per NIC
    # victims are the even half of the allocation -> ring of n/2 ranks
    nv = n // 2
    t_lb = wire_bytes_model("ring_all_reduce", nv, v)["bytes"] / cap
    assert r.t_uncongested_s >= t_lb, (r.t_uncongested_s, t_lb)
    # and within a small multiple (phases quantize to dt, adding < ~2x)
    assert r.t_uncongested_s < 6.0 * t_lb, (r.t_uncongested_s, t_lb)


def test_phased_and_flattened_ratios_differ_under_same_aggressor():
    """Acceptance: a phased ring AllReduce and a flattened AlltoAll
    produce measurably different congestion ratios under the same
    steady incast aggressor — temporal structure changes congestion
    impact, which the pre-IR single-blob engine could not express."""
    sysp = systems.get_system("leonardo")
    kw = dict(n_iters=10, warmup=2)
    phased_ar = bench.run_point(sysp, 16, "ring_allreduce", "incast",
                                2 << 20, cong.steady(), phased=True, **kw)
    flat_a2a = bench.run_point(sysp, 16, "alltoall", "incast", 2 << 20,
                               cong.steady(), **kw)
    assert abs(flat_a2a.ratio - phased_ar.ratio) > 0.05, \
        (flat_a2a.ratio, phased_ar.ratio)


def test_pairwise_phasing_changes_alltoall_congestion():
    """Same victim kind, two lowerings: the flattened linear AlltoAll
    (all n(n-1) pairs at once) and the phased pairwise schedule (n-flow
    perfect matchings behind barriers) see measurably different impact
    from the same aggressor on the blocking fat-tree."""
    sysp = systems.get_system("cresco8")
    kw = dict(n_iters=10, warmup=2)
    flat = bench.run_point(sysp, 16, "alltoall", "alltoall", 2 << 20,
                           cong.steady(), **kw)
    phased = bench.run_point(sysp, 16, "alltoall", "alltoall", 2 << 20,
                             cong.steady(), phased=True, **kw)
    assert abs(flat.ratio - phased.ratio) > 0.05, (flat.ratio, phased.ratio)


def test_two_job_mix_runs_batched_with_per_job_times():
    """Acceptance: a two-training-job mix sweeps through bench.run_grid
    (one jit(vmap) compile for the whole grid) and reports per-job
    iteration times for both tenants."""
    jobs = [traffic.JobSpec("train_a", "ring_allreduce", phased=True),
            traffic.JobSpec("train_b", "ring_allreduce",
                            vector_bytes=2 << 20, phased=True,
                            envelope_gated=True, sweep_bytes=False)]
    res = bench.run_grid(systems.get_system("lumi"), 16, "", "",
                         [1 << 20, 4 << 20], [cong.steady()],
                         n_iters=8, warmup=2, jobs=jobs)
    assert len(res) == 2  # sizes x profiles
    for r in res:
        names = [name for name, _, _ in r.job_times]
        assert "train_a" in names and "train_b" in names, r.job_times
        by = dict((name, (t, n)) for name, t, n in r.job_times)
        assert by["train_a"][1] == 8  # primary ran to completion
        assert by["train_b"][1] >= 1  # background tenant progressed
        assert by["train_a"][0] > 0 and by["train_b"][0] > 0
        assert 0.0 < r.ratio <= 1.1


def test_endless_aggressor_reports_no_iterations():
    """Endless background jobs never close a program iteration, so they
    must not appear in job_times."""
    r = bench.run_point(systems.get_system("leonardo"), 8, "ring_allgather",
                        "incast", 1 << 20, cong.steady(), n_iters=8,
                        warmup=2)
    names = [name for name, _, _ in r.job_times]
    assert names == ["victim"], r.job_times
