"""Optimizer unit tests: convergence, clipping, schedules, state sharding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import OptConfig, adamw, adafactor_m, get_optimizer, global_norm


def _quadratic_params():
    return {"a": jnp.array([3.0, -2.0, 5.0]), "b": jnp.ones((4, 8)) * 2.0}


def _loss(p):
    return jnp.sum(p["a"] ** 2) + jnp.sum(p["b"] ** 2)


@pytest.mark.parametrize("name", ["adamw", "adafactor_m"])
def test_optimizer_converges_on_quadratic(name):
    cfg = OptConfig(lr=0.05, warmup_steps=1, decay_steps=10_000,
                    weight_decay=0.0, grad_clip=100.0)
    opt = get_optimizer(name, cfg)
    params = _quadratic_params()
    state = opt.init(params)
    l0 = float(_loss(params))
    for step in range(200):
        grads = jax.grad(_loss)(params)
        params, state, gnorm = opt.update(grads, state, params,
                                          jnp.int32(step))
    assert float(_loss(params)) < 0.01 * l0


def test_grad_clip():
    cfg = OptConfig(grad_clip=1.0, lr=0.0, weight_decay=0.0)
    opt = adamw(cfg)
    params = {"w": jnp.zeros((3,))}
    grads = {"w": jnp.full((3,), 100.0)}
    state = opt.init(params)
    _, _, gnorm = opt.update(grads, state, params, jnp.int32(0))
    assert np.isclose(float(gnorm), np.sqrt(3 * 100.0 ** 2))


def test_schedule_warmup_and_decay():
    from repro.optim.adamw import _schedule

    cfg = OptConfig(lr=1e-3, warmup_steps=10, decay_steps=100)
    lr_early = float(_schedule(cfg, jnp.int32(0)))
    lr_peak = float(_schedule(cfg, jnp.int32(10)))
    lr_end = float(_schedule(cfg, jnp.int32(100)))
    assert lr_early < lr_peak
    assert lr_end < 0.2 * lr_peak  # cosine floor = 0.1 * lr
    assert lr_end >= 0.099e-3


def test_adamw_state_specs_mirror_params():
    opt = adamw()
    specs = {"w": P("data", "model"), "b": P(None)}
    s = opt.state_specs(specs)
    assert s["m"] == specs and s["v"] == specs


def test_adafactor_state_is_factored():
    opt = adafactor_m()
    shapes = {"w": jax.ShapeDtypeStruct((64, 128), jnp.float32)}
    ss = opt.state_shapes(shapes)
    assert ss["m"]["w"].dtype == jnp.bfloat16
    assert ss["vr"]["w"].shape == (64,)
    assert ss["vc"]["w"].shape == (128,)
    # factored memory: 64+128 floats instead of 64*128
    n_second = np.prod(ss["vr"]["w"].shape) + np.prod(ss["vc"]["w"].shape)
    assert n_second < 0.05 * 64 * 128


def test_global_norm():
    t = {"a": jnp.ones((2, 2)), "b": jnp.ones((12,))}
    assert np.isclose(float(global_norm(t)), 4.0)
