"""Fabric layer tests: topology invariants, routing policies, CC behaviors,
and the paper's validation targets expressed as assertions (DESIGN.md §1.5).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bench, congestion as cong
from repro.core.fabric import cc as cc_lib
from repro.core.fabric import routing, systems, topology


# --------------------------------------------------------------------------
# topology invariants
# --------------------------------------------------------------------------

TOPOS = {
    "single_switch": lambda: topology.single_switch(8),
    "leaf_spine": lambda: topology.leaf_spine(8),
    "fat_tree": lambda: topology.fat_tree(64),
    "dragonfly": lambda: topology.dragonfly(128),
    "dragonfly_plus": lambda: topology.dragonfly_plus(128),
    "torus2d": lambda: topology.torus2d(4, 4),
}


def _check_path(topo, src, dst, path):
    """A path must start at src's injection link, end at dst's ejection link,
    and be link-contiguous (each link's head == next link's tail)."""
    assert len(path) >= 1
    names = topo.link_names
    a0 = names[path[0]][0]
    assert a0 == ("h", src), (a0, src)
    b_last = names[path[-1]][1]
    assert b_last == ("h", dst), (b_last, dst)
    for l1, l2 in zip(path, path[1:]):
        assert names[l1][1] == names[l2][0], (names[l1], names[l2])


@pytest.mark.parametrize("name", sorted(TOPOS))
def test_topology_paths_valid(name):
    topo = TOPOS[name]()
    rng = np.random.RandomState(0)
    for _ in range(40):
        src, dst = rng.randint(0, topo.n_nodes, 2)
        if src == dst:
            continue
        paths = topo.paths(src, dst)
        assert len(paths) >= 1
        for p in paths:
            _check_path(topo, src, dst, p)
        # candidate paths must be distinct
        assert len({tuple(p) for p in paths}) == len(paths)


def test_fat_tree_taper():
    topo = topology.fat_tree(64, nodes_per_leaf=16, taper=1.67)
    # 1.67:1 blocking -> fewer spine uplinks than hosts per leaf
    assert topo.meta["n_spine"] == round(16 / 1.67)
    # cross-leaf pairs have exactly one path per spine
    assert len(topo.paths(0, 63)) == topo.meta["n_spine"]


def test_torus_dor_hop_count():
    topo = topology.torus2d(4, 4)
    # DOR minimal routing: hops = manhattan distance on the torus (+2 if you
    # count both unit moves; links here ARE the hops)
    p = topo.paths(0, 5)[0]  # (0,0) -> (1,1): 2 hops
    assert len(p) == 2
    p = topo.paths(0, 15)[0]  # (0,0) -> (3,3): wrap = 1+1 hops
    assert len(p) == 2


@settings(max_examples=30, deadline=None)
@given(src=st.integers(0, 127), dst=st.integers(0, 127))
def test_dragonfly_paths_property(src, dst):
    topo = _DF_CACHE[0]
    if src == dst:
        return
    for p in topo.paths(src, dst):
        _check_path(topo, src, dst, p)


_DF_CACHE = [topology.dragonfly(128)]


# --------------------------------------------------------------------------
# static routing policies
# --------------------------------------------------------------------------

def _uplink_flows(n=8):
    topo = topology.leaf_spine(n)
    # concurrent flows from the same source leaf to the other leaf
    src_dst = [(0, 4), (1, 5), (2, 6), (3, 7)]
    paths = [topo.paths(s, d) for s, d in src_dst]
    return topo, src_dst, paths


def test_nslb_collision_free():
    """NSLB must place concurrent flows on distinct uplinks when possible
    (the paper's flow-matrix collision-free property, ref [22])."""
    topo, src_dst, paths = _uplink_flows()
    choice = routing.assign_paths("nslb", src_dst, paths, len(topo.caps))
    used = [tuple(paths[f][choice[f]][1:3]) for f in range(len(src_dst))]
    assert len(set(used)) == len(used), used


def test_deterministic_routing_collides():
    topo, src_dst, paths = _uplink_flows()
    choice = routing.assign_paths("deterministic", src_dst, paths,
                                  len(topo.caps))
    used = [tuple(paths[f][choice[f]][1:3]) for f in range(len(src_dst))]
    assert len(set(used)) == 1  # everyone picks candidate 0


def test_ecmp_is_deterministic_per_seed():
    topo, src_dst, paths = _uplink_flows()
    c1 = routing.assign_paths("ecmp", src_dst, paths, len(topo.caps), seed=3)
    c2 = routing.assign_paths("ecmp", src_dst, paths, len(topo.caps), seed=3)
    assert (c1 == c2).all()


def test_splitmix64_reference_vectors():
    """The ECMP mixer is an explicit integer permutation — fixed
    expectations hold on every platform/implementation (splitmix64(0)
    is the published SplitMix64 test vector)."""
    assert int(routing.splitmix64(0)) == 0xE220A8397B1DCDAF
    assert int(routing.splitmix64(1)) == 0x910A2DEC89025CC1
    assert int(routing.splitmix64(42)) == 0xBDD732262FEB6E95
    # vectorized == scalar
    vec = routing.splitmix64(np.array([0, 1, 42], np.uint64))
    assert [int(v) for v in vec] == [0xE220A8397B1DCDAF,
                                     0x910A2DEC89025CC1,
                                     0xBDD732262FEB6E95]


def test_ecmp_hash_fixed_expectations():
    """Path choices are pure functions of (src, dst, salt): pinned
    values, src/dst asymmetry, salt sensitivity."""
    assert int(routing.ecmp_hash(3, 7, 0)) == 0x8C19E8018B510253
    assert int(routing.ecmp_hash(7, 3, 0)) == 0x9BDBD056CBAE684F
    assert int(routing.ecmp_hash(3, 7, 9)) == 0x476318EECEAEED47
    topo, src_dst, paths = _uplink_flows()  # 4 candidate paths per flow
    assert list(routing.assign_paths("ecmp", src_dst, paths,
                                     len(topo.caps), seed=0)) == [0, 3, 3, 3]
    assert list(routing.assign_paths("ecmp", src_dst, paths,
                                     len(topo.caps), seed=3)) == [2, 2, 2, 3]


# --------------------------------------------------------------------------
# congestion profiles + flow construction
# --------------------------------------------------------------------------

def test_interleaved_split():
    v, a = cong.interleaved_split(8)
    assert list(v) == [0, 2, 4, 6] and list(a) == [1, 3, 5, 7]


@settings(max_examples=30, deadline=None)
@given(burst=st.floats(1e-4, 1e-2), pause=st.floats(1e-4, 1e-2),
       t0=st.floats(0, 1.0))
def test_bursty_duty_cycle(burst, pause, t0):
    """The envelope's on-fraction must approach burst/(burst+pause)."""
    prof = cong.bursty(burst, pause)
    dt = (burst + pause) / 500.0
    env = prof.envelope(t0, 50_000, dt)
    duty = env.mean()
    want = burst / (burst + pause)
    assert abs(duty - want) < 0.02, (duty, want)


def test_collective_flow_bytes():
    """Per-iteration wire bytes must match the analytic schedule models."""
    v = 1 << 20
    n = 8
    nodes = list(range(n))
    ag = cong.collective_flows(nodes, "ring_allgather", v)
    assert len(ag) == n
    assert np.isclose(sum(b for *_, b in ag), n * v * (n - 1) / n)
    a2a = cong.collective_flows(nodes, "alltoall", v)
    assert len(a2a) == n * (n - 1)
    inc = cong.collective_flows(nodes, "incast", v)
    assert len(inc) == n - 1 and all(d == nodes[0] for _, d, _ in inc)


# --------------------------------------------------------------------------
# simulator: conservation + paper validation targets
# --------------------------------------------------------------------------

def test_goodput_bounded_by_capacity():
    """Victim goodput can never exceed aggregate injection capacity."""
    sysp = systems.get_system("nanjing_nslb")
    res = bench.goodput_trace(sysp, 8, "alltoall", 8 * 2 ** 20, n_iters=20)
    cap = 8 * 200e9 / 8.0  # 8 nodes x 200 Gb/s in B/s
    assert res.victim_rate_trace.max() <= cap * 1.01


def test_fig4_nslb_protects_victims():
    """Paper Fig. 4: NSLB on -> no drop under congestion; off -> ~2/3."""
    v = 16 * 2 ** 20
    on = bench.run_point(systems.get_system("nanjing_nslb"), 8, "alltoall",
                         "alltoall", v, cong.steady(), n_iters=30, warmup=5)
    off = bench.run_point(systems.get_system("nanjing_ecmp"), 8, "alltoall",
                          "alltoall", v, cong.steady(), n_iters=30, warmup=5)
    assert on.ratio > 0.92, on
    assert off.ratio < 0.80, off


def test_obs1_ce8850_sawtooth():
    """Paper Obs. 1 / Fig. 3: CE8850 self-congests on large AllGather
    (sawtooth = high goodput variability); CE9855(+AI-ECN) stays stable;
    EDR InfiniBand on the same nodes stays stable."""
    v = 128 * 2 ** 20

    def cv(sys_name, n=4):
        res = bench.goodput_trace(systems.get_system(sys_name), n,
                                  "ring_allgather", v, n_iters=25)
        tr = res.victim_rate_trace
        tr = tr[len(tr) // 3:]
        tr = tr[tr > 0]
        return tr.std() / tr.mean()

    cv_ce8850 = cv("haicgu_ce8850")
    cv_ib = cv("haicgu_ib")
    cv_ce9855 = cv("nanjing_nslb")
    assert cv_ce8850 > 2.5 * cv_ib, (cv_ce8850, cv_ib)
    assert cv_ce8850 > 2.5 * cv_ce9855, (cv_ce8850, cv_ce9855)


@pytest.mark.slow
def test_fig5_steady_large_scale_ordering():
    """Paper Fig. 5 / Obs. 2 at 64 nodes (scaled): LUMI ~unaffected under
    both aggressors; Leonardo collapses under Incast but not AlltoAll;
    CRESCO8 degrades under AlltoAll."""
    v = 2 * 2 ** 20
    n = 64

    def ratio(sys_name, aggr):
        return bench.run_point(systems.get_system(sys_name), n,
                               "ring_allgather", aggr, v, cong.steady(),
                               n_iters=25, warmup=5).ratio

    lumi_a2a = ratio("lumi", "alltoall")
    lumi_inc = ratio("lumi", "incast")
    leo_a2a = ratio("leonardo", "alltoall")
    leo_inc = ratio("leonardo", "incast")
    cre_a2a = ratio("cresco8", "alltoall")
    assert lumi_a2a > 0.90 and lumi_inc > 0.90, (lumi_a2a, lumi_inc)
    assert leo_a2a > 0.75, leo_a2a
    assert leo_inc < 0.55, leo_inc           # incast collapse (paper: ~0.2)
    assert cre_a2a < 0.85, cre_a2a           # blocking fat-tree degradation
    assert leo_inc < lumi_inc and cre_a2a < lumi_a2a


def test_bursty_short_gap_worse_than_long_gap():
    """Paper Obs. 3: short inter-burst gaps leave no drain time and hurt
    more than long gaps (same burst length)."""
    v = 2 * 2 ** 20
    sysp = systems.get_system("leonardo")
    short = bench.run_point(sysp, 32, "ring_allgather", "incast", v,
                            cong.bursty(2e-3, 0.2e-3), n_iters=25, warmup=5)
    long_ = bench.run_point(sysp, 32, "ring_allgather", "incast", v,
                            cong.bursty(2e-3, 8e-3), n_iters=25, warmup=5)
    assert long_.ratio > short.ratio + 0.05, (short.ratio, long_.ratio)
