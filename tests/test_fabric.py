"""Fabric layer tests: topology invariants, routing policies, CC behaviors,
and the paper's validation targets expressed as assertions (DESIGN.md §1.5).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bench, congestion as cong
from repro.core.fabric import cc as cc_lib
from repro.core.fabric import routing, systems, topology


# --------------------------------------------------------------------------
# topology invariants
# --------------------------------------------------------------------------

TOPOS = {
    "single_switch": lambda: topology.single_switch(8),
    "leaf_spine": lambda: topology.leaf_spine(8),
    "fat_tree": lambda: topology.fat_tree(64),
    "dragonfly": lambda: topology.dragonfly(128),
    "dragonfly_plus": lambda: topology.dragonfly_plus(128),
    "torus2d": lambda: topology.torus2d(4, 4),
}


def _check_path(topo, src, dst, path):
    """A path must start at src's injection link, end at dst's ejection link,
    and be link-contiguous (each link's head == next link's tail)."""
    assert len(path) >= 1
    names = topo.link_names
    a0 = names[path[0]][0]
    assert a0 == ("h", src), (a0, src)
    b_last = names[path[-1]][1]
    assert b_last == ("h", dst), (b_last, dst)
    for l1, l2 in zip(path, path[1:]):
        assert names[l1][1] == names[l2][0], (names[l1], names[l2])


@pytest.mark.parametrize("name", sorted(TOPOS))
def test_topology_paths_valid(name):
    topo = TOPOS[name]()
    rng = np.random.RandomState(0)
    for _ in range(40):
        src, dst = rng.randint(0, topo.n_nodes, 2)
        if src == dst:
            continue
        paths = topo.paths(src, dst)
        assert len(paths) >= 1
        for p in paths:
            _check_path(topo, src, dst, p)
        # candidate paths must be distinct
        assert len({tuple(p) for p in paths}) == len(paths)


def test_fat_tree_taper():
    topo = topology.fat_tree(64, nodes_per_leaf=16, taper=1.67)
    # 1.67:1 blocking -> fewer spine uplinks than hosts per leaf
    assert topo.meta["n_spine"] == round(16 / 1.67)
    # cross-leaf pairs have exactly one path per spine
    assert len(topo.paths(0, 63)) == topo.meta["n_spine"]


def test_torus_dor_hop_count():
    topo = topology.torus2d(4, 4)
    # DOR minimal routing: hops = manhattan distance on the torus (+2 if you
    # count both unit moves; links here ARE the hops)
    p = topo.paths(0, 5)[0]  # (0,0) -> (1,1): 2 hops
    assert len(p) == 2
    p = topo.paths(0, 15)[0]  # (0,0) -> (3,3): wrap = 1+1 hops
    assert len(p) == 2


@settings(max_examples=30, deadline=None)
@given(src=st.integers(0, 127), dst=st.integers(0, 127))
def test_dragonfly_paths_property(src, dst):
    topo = _DF_CACHE[0]
    if src == dst:
        return
    for p in topo.paths(src, dst):
        _check_path(topo, src, dst, p)


_DF_CACHE = [topology.dragonfly(128)]


# --------------------------------------------------------------------------
# static routing policies
# --------------------------------------------------------------------------

def _uplink_flows(n=8):
    topo = topology.leaf_spine(n)
    # concurrent flows from the same source leaf to the other leaf
    src_dst = [(0, 4), (1, 5), (2, 6), (3, 7)]
    paths = [topo.paths(s, d) for s, d in src_dst]
    return topo, src_dst, paths


def test_nslb_collision_free():
    """NSLB must place concurrent flows on distinct uplinks when possible
    (the paper's flow-matrix collision-free property, ref [22])."""
    topo, src_dst, paths = _uplink_flows()
    choice = routing.assign_paths("nslb", src_dst, paths, len(topo.caps))
    used = [tuple(paths[f][choice[f]][1:3]) for f in range(len(src_dst))]
    assert len(set(used)) == len(used), used


def test_deterministic_routing_collides():
    topo, src_dst, paths = _uplink_flows()
    choice = routing.assign_paths("deterministic", src_dst, paths,
                                  len(topo.caps))
    used = [tuple(paths[f][choice[f]][1:3]) for f in range(len(src_dst))]
    assert len(set(used)) == 1  # everyone picks candidate 0


def test_ecmp_is_deterministic_per_seed():
    topo, src_dst, paths = _uplink_flows()
    c1 = routing.assign_paths("ecmp", src_dst, paths, len(topo.caps), seed=3)
    c2 = routing.assign_paths("ecmp", src_dst, paths, len(topo.caps), seed=3)
    assert (c1 == c2).all()


def test_splitmix64_reference_vectors():
    """The ECMP mixer is an explicit integer permutation — fixed
    expectations hold on every platform/implementation (splitmix64(0)
    is the published SplitMix64 test vector)."""
    assert int(routing.splitmix64(0)) == 0xE220A8397B1DCDAF
    assert int(routing.splitmix64(1)) == 0x910A2DEC89025CC1
    assert int(routing.splitmix64(42)) == 0xBDD732262FEB6E95
    # vectorized == scalar
    vec = routing.splitmix64(np.array([0, 1, 42], np.uint64))
    assert [int(v) for v in vec] == [0xE220A8397B1DCDAF,
                                     0x910A2DEC89025CC1,
                                     0xBDD732262FEB6E95]


def test_ecmp_hash_fixed_expectations():
    """Path choices are pure functions of (src, dst, salt): pinned
    values, src/dst asymmetry, salt sensitivity."""
    assert int(routing.ecmp_hash(3, 7, 0)) == 0x8C19E8018B510253
    assert int(routing.ecmp_hash(7, 3, 0)) == 0x9BDBD056CBAE684F
    assert int(routing.ecmp_hash(3, 7, 9)) == 0x476318EECEAEED47
    topo, src_dst, paths = _uplink_flows()  # 4 candidate paths per flow
    assert list(routing.assign_paths("ecmp", src_dst, paths,
                                     len(topo.caps), seed=0)) == [0, 3, 3, 3]
    assert list(routing.assign_paths("ecmp", src_dst, paths,
                                     len(topo.caps), seed=3)) == [2, 2, 2, 3]


# --------------------------------------------------------------------------
# congestion profiles + flow construction
# --------------------------------------------------------------------------

def test_interleaved_split():
    v, a = cong.interleaved_split(8)
    assert list(v) == [0, 2, 4, 6] and list(a) == [1, 3, 5, 7]


@settings(max_examples=30, deadline=None)
@given(burst=st.floats(1e-4, 1e-2), pause=st.floats(1e-4, 1e-2),
       t0=st.floats(0, 1.0))
def test_bursty_duty_cycle(burst, pause, t0):
    """The envelope's on-fraction must approach burst/(burst+pause)."""
    prof = cong.bursty(burst, pause)
    dt = (burst + pause) / 500.0
    env = prof.envelope(t0, 50_000, dt)
    duty = env.mean()
    want = burst / (burst + pause)
    assert abs(duty - want) < 0.02, (duty, want)


def test_collective_flow_bytes():
    """Per-iteration wire bytes must match the analytic schedule models."""
    v = 1 << 20
    n = 8
    nodes = list(range(n))
    ag = cong.collective_flows(nodes, "ring_allgather", v)
    assert len(ag) == n
    assert np.isclose(sum(b for *_, b in ag), n * v * (n - 1) / n)
    a2a = cong.collective_flows(nodes, "alltoall", v)
    assert len(a2a) == n * (n - 1)
    inc = cong.collective_flows(nodes, "incast", v)
    assert len(inc) == n - 1 and all(d == nodes[0] for _, d, _ in inc)


# --------------------------------------------------------------------------
# simulator: conservation + paper validation targets
# --------------------------------------------------------------------------

def test_goodput_bounded_by_capacity():
    """Victim goodput can never exceed aggregate injection capacity."""
    sysp = systems.get_system("nanjing_nslb")
    res = bench.goodput_trace(sysp, 8, "alltoall", 8 * 2 ** 20, n_iters=20)
    cap = 8 * 200e9 / 8.0  # 8 nodes x 200 Gb/s in B/s
    assert res.victim_rate_trace.max() <= cap * 1.01


def test_fig4_nslb_protects_victims():
    """Paper Fig. 4: NSLB on -> no drop under congestion; off -> ~2/3."""
    v = 16 * 2 ** 20
    on = bench.run_point(systems.get_system("nanjing_nslb"), 8, "alltoall",
                         "alltoall", v, cong.steady(), n_iters=30, warmup=5)
    off = bench.run_point(systems.get_system("nanjing_ecmp"), 8, "alltoall",
                          "alltoall", v, cong.steady(), n_iters=30, warmup=5)
    assert on.ratio > 0.92, on
    assert off.ratio < 0.80, off


def test_obs1_ce8850_sawtooth():
    """Paper Obs. 1 / Fig. 3: CE8850 self-congests on large AllGather
    (sawtooth = high goodput variability); CE9855(+AI-ECN) stays stable;
    EDR InfiniBand on the same nodes stays stable."""
    v = 128 * 2 ** 20

    def cv(sys_name, n=4):
        res = bench.goodput_trace(systems.get_system(sys_name), n,
                                  "ring_allgather", v, n_iters=25)
        tr = res.victim_rate_trace
        tr = tr[len(tr) // 3:]
        tr = tr[tr > 0]
        return tr.std() / tr.mean()

    cv_ce8850 = cv("haicgu_ce8850")
    cv_ib = cv("haicgu_ib")
    cv_ce9855 = cv("nanjing_nslb")
    assert cv_ce8850 > 2.5 * cv_ib, (cv_ce8850, cv_ib)
    assert cv_ce8850 > 2.5 * cv_ce9855, (cv_ce8850, cv_ce9855)


@pytest.mark.slow
def test_fig5_steady_large_scale_ordering():
    """Paper Fig. 5 / Obs. 2 at 64 nodes (scaled): LUMI ~unaffected under
    both aggressors; Leonardo collapses under Incast but not AlltoAll;
    CRESCO8 degrades under AlltoAll.

    Collapse depth is placement-dependent (incast hurts when victims
    share the hotspot switch): the paper's §III-A methodology *selects*
    maximal-sharing placements, so this test pins an allocation draw
    that exhibits the reported sharing (seed=5 reproduces the ~0.2
    Leonardo collapse; scattered draws can land anywhere in 0.2..0.9)."""
    v = 2 * 2 ** 20
    n = 64

    def ratio(sys_name, aggr):
        return bench.run_point(systems.get_system(sys_name), n,
                               "ring_allgather", aggr, v, cong.steady(),
                               n_iters=25, warmup=5, seed=5).ratio

    lumi_a2a = ratio("lumi", "alltoall")
    lumi_inc = ratio("lumi", "incast")
    leo_a2a = ratio("leonardo", "alltoall")
    leo_inc = ratio("leonardo", "incast")
    cre_a2a = ratio("cresco8", "alltoall")
    assert lumi_a2a > 0.90 and lumi_inc > 0.90, (lumi_a2a, lumi_inc)
    assert leo_a2a > 0.75, leo_a2a
    assert leo_inc < 0.55, leo_inc           # incast collapse (paper: ~0.2)
    assert cre_a2a < 0.85, cre_a2a           # blocking fat-tree degradation
    assert leo_inc < lumi_inc and cre_a2a < lumi_a2a


def test_bursty_short_gap_worse_than_long_gap():
    """Paper Obs. 3: short inter-burst gaps leave no drain time and hurt
    more than long gaps (same burst length)."""
    v = 2 * 2 ** 20
    sysp = systems.get_system("leonardo")
    short = bench.run_point(sysp, 32, "ring_allgather", "incast", v,
                            cong.bursty(2e-3, 0.2e-3), n_iters=25, warmup=5)
    long_ = bench.run_point(sysp, 32, "ring_allgather", "incast", v,
                            cong.bursty(2e-3, 8e-3), n_iters=25, warmup=5)
    assert long_.ratio > short.ratio + 0.05, (short.ratio, long_.ratio)


# --------------------------------------------------------------------------
# step micro-optimizations are bit-identical (ISSUE 6 satellite)
# --------------------------------------------------------------------------

def _old_step(geom, p, state):
    """The pre-kernel `_step_impl` (with_aux=False path) VERBATIM — with
    the duplicated `state["q"] / p.qmax_bytes`, the per-step
    `jnp.arange` constants, and NIC limiting before routing. The
    refactored step (shared occ, hoisted aranges, NIC limit inside the
    fused core) must reproduce it bit-for-bit."""
    import jax
    import jax.numpy as jnp
    from repro.core.envelopes import envelope_at
    from repro.core.fabric import simulator as sim
    from repro.core.fabric.routing import (POLICY_ADAPTIVE, POLICY_ECMP,
                                           POLICY_FIXED, POLICY_FLOWLET,
                                           POLICY_NSLB)

    dt = p.dt
    env_t = envelope_at(p.env, state["t"])
    in_phase = (geom.flow_phase == state["ph"][geom.flow_job]) \
        | (geom.flow_phase < 0)
    alive = (state["rem"] > 0) & in_phase
    active = (geom.is_victim | (env_t > 0)) & alive
    gate = jnp.where(geom.is_victim, 1.0, env_t) * alive
    inject = state["c"] * gate
    src_load = jnp.zeros((geom.n_src,), jnp.float32).at[geom.src_id].add(
        inject)
    scale = jnp.minimum(1.0, p.host_caps
                        / jnp.maximum(src_load[geom.src_id], 1.0))
    inject = inject * scale

    occ_paths = state["q"] / p.qmax_bytes
    score = jnp.max(occ_paths[geom.paths], axis=2) \
        + 0.05 * geom.path_len / jnp.maximum(geom.path_len[:, :1], 1)
    score = jnp.where(jnp.arange(geom.paths.shape[1])[None, :]
                      < geom.n_paths[:, None], score, jnp.inf)
    best = jnp.argmin(score, axis=1)
    best_score = jnp.min(score, axis=1)

    def _hysteresis(anchor):
        a_score = jnp.take_along_axis(score, anchor[:, None], 1)[:, 0]
        return jnp.where(a_score > best_score + 0.10, best, anchor)

    def _route_adaptive(_):
        return _hysteresis(geom.spray_choice), state["rc"]

    def _route_flowlet(_):
        rc = jnp.where(state["idle"] >= p.flowlet_gap_s,
                       _hysteresis(state["rc"]), state["rc"])
        return rc, rc

    route_branches = [None] * 5
    route_branches[POLICY_FIXED] = lambda _: (geom.fixed_choice, state["rc"])
    route_branches[POLICY_ECMP] = lambda _: (geom.ecmp_choice, state["rc"])
    route_branches[POLICY_NSLB] = lambda _: (geom.nslb_choice, state["rc"])
    route_branches[POLICY_ADAPTIVE] = _route_adaptive
    route_branches[POLICY_FLOWLET] = _route_flowlet
    choice, rc_new = jax.lax.switch(p.policy, route_branches, None)
    idle_new = jnp.where(active, 0.0, state["idle"] + dt)
    plinks = jnp.take_along_axis(
        geom.paths, choice[:, None, None], axis=1)[:, 0]
    valid = plinks < geom.L

    occ_prev = state["q"] / p.qmax_bytes
    sat_l = jnp.clip((occ_prev - p.hol_start)
                     / (1.0 - p.hol_start), 0.0, 1.0)
    hot_q = jnp.zeros((geom.n_sw,), jnp.float32).at[
        geom.src_sw].add(state["q"] * sat_l)
    tot_q = jnp.zeros((geom.n_sw,), jnp.float32).at[
        geom.src_sw].add(state["q"])
    share = hot_q / jnp.maximum(tot_q, 1.0)
    sw_sat = jnp.zeros((geom.n_sw,), jnp.float32).at[
        geom.src_sw].max(sat_l)
    stall = 1.0 - p.hol_factor * sw_sat * share
    stall = stall.at[0].set(1.0)
    caps_eff = geom.caps_finite * stall[geom.dst_sw]

    r = inject
    arrival = jnp.zeros((geom.L + 1,), jnp.float32)
    for h in range(plinks.shape[1]):
        lk = plinks[:, h]
        contrib = r * valid[:, h]
        load = jnp.zeros((geom.L + 1,), jnp.float32).at[lk].add(contrib)
        arrival = arrival + load
        over = jnp.maximum(load / caps_eff, 1.0)
        r = jnp.where(valid[:, h], r / over[lk], r)
    a = r
    q = jnp.clip(state["q"] + (arrival * (1.0 + p.burst_jitter)
                               - caps_eff) * dt,
                 0.0, p.qmax_bytes)
    q = q.at[geom.L].set(0.0)

    adapted = jnp.clip(0.9 * state["thresh"] + 0.1 * (0.5 * q + p.kmin
                                                      * p.qmax_bytes),
                       0.05 * p.qmax_bytes, p.kmax * p.qmax_bytes)
    thresh = jnp.where(p.thresh_adapt > 0, adapted, state["thresh"])
    over_thresh = q > thresh
    fmark = jnp.any(over_thresh[plinks] & valid, axis=1)
    strength_l = jnp.clip((q - thresh)
                          / (p.kmax * p.qmax_bytes - thresh + 1.0),
                          0.0, 1.0)
    fstrength = jnp.max(jnp.where(valid, strength_l[plinks], 0.0), axis=1)

    can_dec = state["last_dec"] >= p.cc_interval_s
    c, dec = sim._cc_update(p, state["c"], a, fmark, fstrength, can_dec)
    c = jnp.where(active, c, state["c"])
    dec = dec & active
    c = jnp.clip(c, p.min_rate_frac * p.host_caps, p.host_caps)
    last_dec = jnp.where(dec, 0.0, state["last_dec"] + dt)

    rem = state["rem"] - a * dt
    t_new = state["t"] + dt
    busy = jnp.zeros((geom.n_jobs,), jnp.int32).at[geom.flow_job].max(
        (in_phase & (rem > 0)).astype(jnp.int32)) > 0
    gap = state["gap"] - dt * (~busy)
    advance = ~busy & (gap <= 0)
    ph_next = jnp.where(advance,
                        (state["ph"] + 1) % geom.n_phases, state["ph"])
    wrap = advance & (state["ph"] + 1 >= geom.n_phases)
    gap = jnp.where(advance,
                    jnp.take_along_axis(geom.phase_gap, ph_next[:, None],
                                        axis=1)[:, 0], gap)
    enter = advance[geom.flow_job] \
        & ((geom.flow_phase == ph_next[geom.flow_job])
           | (geom.flow_phase < 0))
    rem = jnp.where(enter, p.bytes_per_iter, rem)
    it = state["it"]
    slot = jnp.minimum(it, sim.TDONE_SLOTS - 1)
    onehot = jnp.arange(sim.TDONE_SLOTS)[None, :] == slot[:, None]
    t_done = jnp.where(wrap[:, None] & onehot, t_new, state["t_done"])
    it = it + wrap.astype(jnp.int32)
    q = jnp.where(wrap[0], q * p.iter_drain, q)

    qdel = jnp.max(jnp.where(valid, (q / geom.caps_finite)[plinks], 0.0),
                   axis=1)
    mean_qdel = jnp.sum(qdel * geom.is_victim) / jnp.maximum(
        jnp.sum(geom.is_victim), 1)
    vict_goodput = jnp.sum(a * geom.is_victim)

    new_state = {"c": c, "rem": rem, "q": q, "arr": arrival,
                 "thresh": thresh, "last_dec": last_dec,
                 "rc": rc_new, "idle": idle_new,
                 "fbytes": state["fbytes"] + a * dt,
                 "ph": ph_next, "gap": gap, "it": it, "t_done": t_done,
                 "qd_acc": state["qd_acc"] + mean_qdel * dt, "t": t_new}
    return new_state, vict_goodput


@pytest.mark.parametrize("policy", [routing.POLICY_FIXED,
                                    routing.POLICY_ADAPTIVE,
                                    routing.POLICY_FLOWLET])
def test_step_microopt_bit_identical(policy):
    """Hoisting the shared occupancy, replacing per-step jnp.arange with
    host constants, and moving the NIC limit after routing must not
    change a single bit of any state leaf or the goodput output."""
    import jax
    from repro.core.fabric import simulator as sim
    from repro.core.fabric import topology as topo_lib

    topo = topo_lib.leaf_spine(8)
    vidx, aidx = cong.interleaved_split(8)
    nodes = np.arange(8)
    flows = cong.build_flowset(topo, nodes[vidx], nodes[aidx],
                               "ring_allreduce", "incast", 1 << 20,
                               phased=True)
    geom = sim.make_geometry(topo, flows)
    p = sim.make_params(cc_lib.dcqcn(), dt=2e-6,
                        bytes_per_iter=flows.bytes_per_iter,
                        host_caps=flows.host_caps,
                        env=cong.steady().params(), policy=policy,
                        flowlet_gap_s=50e-6)
    old = jax.jit(lambda s: _old_step(geom, p, s))
    new = jax.jit(lambda s: sim.step(geom, p, s))
    state = sim.init_state(geom, p)
    for i in range(30):
        s_old, g_old = old(state)
        s_new, g_new = new(state)
        assert np.array_equal(np.asarray(g_old), np.asarray(g_new)), i
        for k in s_old:
            assert np.array_equal(np.asarray(s_old[k]),
                                  np.asarray(s_new[k])), (i, k)
        state = s_new
