"""Gradient-compression tests: error-feedback unbiasedness, wire-byte
accounting, compressed cross-pod mean."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim import compression as comp


def test_ef_compress_roundtrip_structure():
    grads = {"a": jnp.ones((4, 300)), "b": jnp.arange(5.0)}
    ef = comp.init_error_feedback(grads)
    payload, new_ef = comp.ef_compress(grads, ef)
    back = comp.ef_decompress(payload, grads)
    assert back["a"].shape == (4, 300)
    assert back["b"].shape == (5,)
    # int8 quantization error is bounded per block
    err = np.abs(np.asarray(back["a"]) - np.asarray(grads["a"]))
    assert err.max() < np.abs(np.asarray(grads["a"])).max() / 100


def test_error_feedback_telescopes():
    """sum_t dq(q(g + ef_t)) -> t*g : the EF residual cannot accumulate."""
    rng = np.random.RandomState(0)
    g = {"w": jnp.asarray(rng.randn(700) * 1e-3, jnp.float32)}
    ef = comp.init_error_feedback(g)
    total = np.zeros(700, np.float32)
    T = 50
    for _ in range(T):
        payload, ef = comp.ef_compress(g, ef)
        total += np.asarray(comp.ef_decompress(payload, g)["w"])
    # time-averaged compressed gradient == true gradient (EF unbiasedness)
    np.testing.assert_allclose(total / T, np.asarray(g["w"]),
                               rtol=0, atol=np.abs(np.asarray(g["w"])).max()
                               / T * 2)
    # residual stays bounded (no drift)
    assert np.abs(np.asarray(ef["w"])).max() \
        < 2 * np.abs(np.asarray(g["w"])).max()


@settings(max_examples=15, deadline=None)
@given(scale=st.floats(1e-6, 1e3), n=st.integers(10, 600),
       seed=st.integers(0, 999))
def test_ef_residual_bounded_property(scale, n, seed):
    rng = np.random.RandomState(seed)
    g = {"w": jnp.asarray(rng.randn(n) * scale, jnp.float32)}
    ef = comp.init_error_feedback(g)
    for _ in range(10):
        _, ef = comp.ef_compress(g, ef)
    # EF residual bounded by one quantization step of (g + ef)'s magnitude
    bound = 2 * scale * (np.abs(rng.randn(1000)).max()) / 127 + 1e-6
    assert np.abs(np.asarray(ef["w"])).max() < max(bound, 0.05 * scale + 1e-6)


def test_wire_bytes_ratio():
    wb = comp.wire_bytes(1_000_000, dtype_bytes=4, n=2)
    assert wb["ratio"] > 7.0  # fp32 ring AR vs int8 all-gather
    wb16 = comp.wire_bytes(1_000_000, dtype_bytes=2, n=2)
    assert 3.5 < wb16["ratio"] < 4.5


def test_compressed_psum_mean_single_axis():
    """On a 1-device mesh the compressed mean must equal the identity up to
    quantization error."""
    mesh = jax.make_mesh((1,), ("pod",))
    from jax.sharding import PartitionSpec as P

    x = jnp.asarray(np.random.RandomState(1).randn(512), jnp.float32)
    fn = jax.shard_map(
        lambda v: comp.compressed_psum_mean(v, "pod", 1),
        mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False)
    out = fn(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                               atol=float(jnp.abs(x).max()) / 100)
