"""Link-fault engine contracts (DESIGN.md §16): the numpy envelope and
fault-scale mirrors must match the traced path bit-for-bit (including
the degenerate rows and large-``t`` regimes behind the uint32-cast
guard), an inert fault table / inf-capacity intra-node stage must be
bit-identical to the fault-free engine on every state leaf across both
step-core backends and all routing policies, and the new scenario
families must keep the one-compile-per-bucket property."""
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import congestion as cong  # noqa: E402
from repro.core import envelopes as env_lib  # noqa: E402
from repro.core import scenarios as scen  # noqa: E402
from repro.core.fabric import cc as cc_lib  # noqa: E402
from repro.core.fabric import simulator as sim  # noqa: E402
from repro.core.fabric import topology as topo_lib  # noqa: E402

# time grid: slot boundaries, mid-window, far past every window, and the
# large-t regime where f64 quotients floor into different slots than f32
TIMES = [0.0, 1e-7, 5e-4, 2.5e-3, 1.01e-2, 0.25, 123.456, 1e4, 2.0 ** 24]


# --------------------------------------------------------------------------
# envelope_np == envelope_at, bin for bin (satellite of the uint32 guard)
# --------------------------------------------------------------------------

def _one_row(kind, p0, p1, w=1.0, seed=3):
    rows = np.zeros((env_lib.ENV_COMPONENTS, 5), np.float32)
    rows[0] = (kind, p0, p1, w, seed)
    return rows


ENV_ROWS = [
    ("off", _one_row(env_lib.ENV_OFF, 0.0, 0.0)),
    ("steady", _one_row(env_lib.ENV_STEADY, 0.0, 0.0)),
    ("bursty", _one_row(env_lib.ENV_BURSTY, 2e-3, 8e-3)),
    ("bursty_p0_0", _one_row(env_lib.ENV_BURSTY, 0.0, 8e-3)),
    ("bursty_p1_0", _one_row(env_lib.ENV_BURSTY, 2e-3, 0.0)),
    ("bursty_both_0", _one_row(env_lib.ENV_BURSTY, 0.0, 0.0)),
    ("ramp", _one_row(env_lib.ENV_RAMP, 5e-3, 0.0)),
    ("ramp_0", _one_row(env_lib.ENV_RAMP, 0.0, 0.0)),
    ("random", _one_row(env_lib.ENV_RANDOM, 2e-3, 6e-3)),
    ("random_p0_0", _one_row(env_lib.ENV_RANDOM, 0.0, 6e-3)),
    ("random_p1_0", _one_row(env_lib.ENV_RANDOM, 2e-3, 0.0)),
    ("random_w0", _one_row(env_lib.ENV_RANDOM, 2e-3, 6e-3, w=0.0)),
]


@pytest.mark.parametrize("name,rows", ENV_ROWS, ids=[n for n, _ in ENV_ROWS])
def test_envelope_np_matches_traced_bin_for_bin(name, rows):
    """Single-component tables: the numpy mirror and the traced envelope
    must agree EXACTLY at every time, including the off/steady rows whose
    slot quotient only stays castable thanks to the mod-2**32 guard and
    the large-t points where f64 host math would pick different bins."""
    at = jax.jit(env_lib.envelope_at)
    got_np = env_lib.envelope_np(rows, np.asarray(TIMES, np.float32))
    for t, v_np in zip(TIMES, got_np):
        v_tr = float(at(jnp.asarray(rows), jnp.float32(t)))
        assert v_tr == float(v_np), (name, t, v_tr, float(v_np))
        assert 0.0 <= v_tr <= 1.0


def test_envelope_mix_matches_traced():
    prof = cong.multi_tenant((cong.bursty(2e-3, 8e-3), 0.5),
                             (cong.random_onoff(1e-3, 3e-3, seed=7), 0.3),
                             (cong.steady(), 0.0))
    rows = prof.params()
    at = jax.jit(env_lib.envelope_at)
    got_np = env_lib.envelope_np(rows, np.asarray(TIMES, np.float32))
    for t, v_np in zip(TIMES, got_np):
        # multi-component sums may reduce in a different order under XLA
        assert float(at(jnp.asarray(rows), jnp.float32(t))) \
            == pytest.approx(float(v_np), abs=1e-6)


# --------------------------------------------------------------------------
# fault_scale_np == fault_scale_at, and the per-kind semantics
# --------------------------------------------------------------------------

GROUPS = np.asarray([env_lib.GROUP_NONE, env_lib.GROUP_EDGE_UP,
                     env_lib.GROUP_EDGE_DOWN, env_lib.GROUP_FABRIC,
                     env_lib.GROUP_HOT], np.int32)


def test_fault_scale_np_matches_traced():
    table = cong.fault_table([
        cong.outage(1e-3, 2e-3, 1.0, link_group=env_lib.GROUP_EDGE_UP),
        cong.flap(0.5e-3, 20e-3, duty=0.4, seed=5),
        cong.degrade(0.2e-3, 1.5e-3, severity=0.7,
                     link_group=env_lib.GROUP_FABRIC),
        cong.jitter(2e-3, 30e-3, severity=0.6,
                    link_group=env_lib.GROUP_EDGE_DOWN, seed=9),
    ])
    at = jax.jit(env_lib.fault_scale_at)
    for t in TIMES:
        v_np = env_lib.fault_scale_np(table, GROUPS, t)
        v_tr = np.asarray(at(jnp.asarray(table), jnp.asarray(GROUPS),
                             jnp.float32(t)))
        np.testing.assert_array_equal(v_tr, v_np, err_msg=str(t))
        # group 0 (sink/padding) is untouchable by construction
        assert v_tr[0] == 1.0
        assert np.all(v_tr >= env_lib.FAULT_FLOOR) and np.all(v_tr <= 1.0)


def _scale(events, group, t):
    return float(env_lib.fault_scale_np(
        cong.fault_table(events), np.asarray([group], np.int32), t)[0])


def test_outage_window_semantics():
    ev = cong.outage(1e-3, 2e-3, 0.75, link_group=env_lib.GROUP_HOT)
    assert _scale([ev], env_lib.GROUP_HOT, 0.5e-3) == 1.0  # before
    assert _scale([ev], env_lib.GROUP_HOT, 2e-3) == pytest.approx(0.25)
    assert _scale([ev], env_lib.GROUP_HOT, 4e-3) == 1.0  # after
    assert _scale([ev], env_lib.GROUP_FABRIC, 2e-3) == 1.0  # other group
    # severity 1.0 hits the floor, never exactly 0 (caps is a divisor)
    hard = cong.outage(1e-3, 2e-3, 1.0)
    assert _scale([hard], env_lib.GROUP_HOT, 2e-3) == env_lib.FAULT_FLOOR


def test_degrade_persists_after_window():
    ev = cong.degrade(1e-3, 4e-3, severity=0.6)
    assert _scale([ev], env_lib.GROUP_HOT, 0.5e-3) == 1.0
    assert _scale([ev], env_lib.GROUP_HOT, 3e-3) == pytest.approx(0.7)
    # the optic does not heal: still at 1 - severity long after
    assert _scale([ev], env_lib.GROUP_HOT, 1.0) == pytest.approx(0.4)


def test_flap_duty_and_binary_levels():
    ev = cong.flap(0.0, 10.0, duty=0.3, seed=11)
    slots = np.arange(4000)
    vals = np.asarray([
        _scale([ev], env_lib.GROUP_HOT,
               (s + 0.5) * env_lib.FLAP_SLOT_S) for s in slots])
    assert set(np.unique(vals)) <= {np.float32(env_lib.FAULT_FLOOR),
                                    np.float32(1.0)}
    down = float(np.mean(vals == np.float32(env_lib.FAULT_FLOOR)))
    assert abs(down - 0.3) < 0.05  # counter-PRNG telegraph hits the duty


def test_jitter_bounds_and_compounding():
    ev = cong.jitter(0.0, 1.0, severity=0.5, link_group=env_lib.GROUP_HOT)
    vals = [_scale([ev], env_lib.GROUP_HOT,
                   (s + 0.5) * env_lib.FLAP_SLOT_S) for s in range(200)]
    assert min(vals) >= 0.5 and max(vals) <= 1.0
    assert np.std(vals) > 0.01  # actually wobbles
    # rows targeting the same group multiply
    o = cong.outage(0.0, 1.0, 0.5)
    both = _scale([o, o], env_lib.GROUP_HOT, 0.5)
    assert both == pytest.approx(0.25)


def test_fault_table_overflow_raises():
    with pytest.raises(ValueError):
        cong.fault_table([cong.outage(0, 1, 0.5)]
                         * (env_lib.FAULT_EVENTS + 1))


# --------------------------------------------------------------------------
# engine inertness: all-none table / inf node cap are bit-identical
# --------------------------------------------------------------------------

def _cell(n_nodes=8, policy=0, intra_node=False):
    topo = topo_lib.leaf_spine(n_nodes)
    vidx, aidx = cong.interleaved_split(n_nodes)
    nodes = np.arange(n_nodes)
    flows = cong.build_flowset(topo, nodes[vidx], nodes[aidx],
                               "ring_allgather", "incast", 1 << 20,
                               phased=True)
    geom = sim.make_geometry(topo, flows, intra_node=intra_node)
    return geom, flows, policy


def _params(geom, flows, policy, fault=None, node_cap=np.inf):
    return sim.make_params(cc_lib.dcqcn(), dt=2e-6,
                           bytes_per_iter=flows.bytes_per_iter,
                           host_caps=flows.host_caps,
                           env=cong.steady().params(), policy=policy,
                           flowlet_gap_s=50e-6, fault=fault,
                           node_cap=node_cap)


def _run_steps(geom, p, backend, n=25):
    stepf = jax.jit(lambda s: jax.lax.scan(
        lambda c, _: sim.step(geom, p, c, backend=backend),
        s, None, length=n))
    return stepf(sim.init_state(geom, p))


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("policy", list(range(5)))
def test_inert_fault_table_bit_identical(backend, policy):
    """The all-``none`` table lowers to an exact 1.0 capacity scale:
    every state leaf and the goodput trace must match the table-free
    engine bit-for-bit, on both step-core backends, under every traced
    routing policy."""
    geom, flows, policy = _cell(policy=policy)
    s0, gp0 = _run_steps(geom, _params(geom, flows, policy), backend)
    s1, gp1 = _run_steps(
        geom, _params(geom, flows, policy, fault=cong.no_fault_table()),
        backend)
    np.testing.assert_array_equal(np.asarray(gp0), np.asarray(gp1))
    for k in s0:
        assert np.array_equal(np.asarray(s0[k]), np.asarray(s1[k])), k


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_inf_node_cap_stage_bit_identical(backend):
    """intra_node=True with node_cap=+inf is an exact no-op: the scale
    is min(1, inf/load) == 1.0 and inject * 1.0 is bit-exact."""
    geom0, flows, _ = _cell()
    geom1, _, _ = _cell(intra_node=True)
    s0, gp0 = _run_steps(geom0, _params(geom0, flows, 0), backend)
    s1, gp1 = _run_steps(geom1, _params(geom1, flows, 0), backend)
    np.testing.assert_array_equal(np.asarray(gp0), np.asarray(gp1))
    for k in s0:
        assert np.array_equal(np.asarray(s0[k]), np.asarray(s1[k])), k


def test_active_fault_actually_bites():
    """Guard against an accidentally-inert implementation: a hard outage
    on the hot link must change the state, and a tight node cap must cut
    goodput."""
    geom, flows, _ = _cell()
    table = cong.fault_table([cong.outage(0.0, 1.0, 1.0)])
    _, gp0 = _run_steps(geom, _params(geom, flows, 0), "ref", n=50)
    _, gp1 = _run_steps(geom, _params(geom, flows, 0, fault=table),
                        "ref", n=50)
    assert float(jnp.sum(gp1)) < float(jnp.sum(gp0))

    geom_in, flows_in, _ = _cell(intra_node=True)
    cap = 0.25 * float(np.max(np.asarray(flows_in.host_caps)))
    _, gp2 = _run_steps(geom_in, _params(geom_in, flows_in, 0), "ref", n=50)
    _, gp3 = _run_steps(geom_in,
                        _params(geom_in, flows_in, 0, node_cap=cap),
                        "ref", n=50)
    assert float(jnp.sum(gp3)) < float(jnp.sum(gp2))


def test_geometry_link_groups_cover_topology():
    """make_geometry stamps every real link with a structural group and
    promotes exactly one most-traversed link to GROUP_HOT; the padding
    lane (index L) stays GROUP_NONE so faults can never touch it."""
    geom, _, _ = _cell()
    lg = np.asarray(geom.link_group)
    assert lg.shape == (int(geom.L) + 1,)
    assert lg[int(geom.L)] == env_lib.GROUP_NONE
    assert int(np.sum(lg == env_lib.GROUP_HOT)) == 1
    assert {env_lib.GROUP_EDGE_UP, env_lib.GROUP_EDGE_DOWN} \
        <= set(lg.tolist())


# --------------------------------------------------------------------------
# profile-layer contracts
# --------------------------------------------------------------------------

def test_empty_mix_raises_not_silently_off():
    with pytest.raises(ValueError, match="zero components"):
        cong.multi_tenant().params()


def test_degenerate_profile_labels_are_honest():
    assert "(=off)" in cong.bursty(0.0, 5e-3).label()
    assert "(=on)" in cong.bursty(5e-3, 0.0).label()
    assert "(=step)" in cong.ramp(0.0).label()
    assert "(=off)" in cong.random_onoff(0.0, 5e-3).label()
    zero_mix = cong.multi_tenant((cong.steady(), 0.0))
    assert "(=off)" in zero_mix.label()
    # non-degenerate labels stay unannotated
    assert "(=" not in cong.bursty(2e-3, 8e-3).label()


def test_fault_profile_labels_and_helpers():
    p = cong.with_node_cap(
        cong.with_faults(cong.steady(),
                         cong.flap(0.2e-3, 20e-3, duty=0.3, seed=5)), 0.5)
    lab = p.label()
    assert lab.startswith("steady+flap[hot 0.3") and "+node0.5x" in lab
    assert p.fault_params() is not None
    assert cong.no_congestion().fault_params() is None
    assert cong.needs_fault_table([cong.steady(), p])
    assert not cong.needs_fault_table([cong.steady()])


# --------------------------------------------------------------------------
# scenario families: one compile per GeometryDims bucket
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["link_fault", "intra_node"])
def test_fault_families_one_compile_per_bucket(name):
    """The fault table and node cap are traced DATA: a shrunk two-cell
    grid of each new family must reuse one run_cells_hetero compile for
    its bucket (the same contract every other scale-batched family
    keeps)."""
    scenario = scen.get(name, quick=True)
    grid = scenario.grids[0]
    grid = dataclasses.replace(grid, sizes=grid.sizes[:1],
                               profiles=grid.profiles[:2],
                               cells=grid.cells[:2])
    scenario = dataclasses.replace(scenario, n_iters=6, warmup=1,
                                   grids=(grid,))
    before = sim.trace_count("run_cells_hetero")
    rows = [scen.result_row(grid, r)
            for r in scen.run_grid_spec(scenario, grid)]
    assert rows and all(float(r["ratio"]) > 0 for r in rows)
    assert sim.trace_count("run_cells_hetero") - before <= 1, name


# --------------------------------------------------------------------------
# switch-level fault groups (separate structural channel, ISSUE 10)
# --------------------------------------------------------------------------

def test_switch_group_stamping_and_channel_separation():
    """make_geometry promotes the most-traversed switch's whole incident
    link set into the SEPARATE ``link_sw_group`` channel; the primary
    ``link_group`` channel is untouched (no re-labeling — committed
    fault scenarios keep their exact link sets) and the padding lane
    stays untouchable."""
    geom, _, _ = _cell()
    sg = np.asarray(geom.link_sw_group)
    lg = np.asarray(geom.link_group)
    L = int(geom.L)
    assert sg.shape == (L + 1,)
    assert sg[L] == env_lib.GROUP_NONE
    assert set(np.unique(sg)) <= {env_lib.GROUP_NONE, env_lib.GROUP_SWITCH}
    # a switch's link set is plural — that is the point of the group
    assert int(np.sum(sg == env_lib.GROUP_SWITCH)) >= 2
    assert env_lib.GROUP_SWITCH not in set(lg.tolist())
    # the switch links are real fabric links (already carrying a group)
    assert np.all(lg[:L][sg[:L] == env_lib.GROUP_SWITCH]
                  != env_lib.GROUP_NONE)


def test_switch_outage_scale_semantics_and_traced_match():
    """A GROUP_SWITCH outage row dips exactly the links whose sw-channel
    matches, leaves every other link at 1.0, and the numpy mirror equals
    the traced path bit-for-bit."""
    table = cong.fault_table([cong.switch_outage(1e-3, 2e-3,
                                                 severity=0.8)])
    groups = np.asarray([env_lib.GROUP_NONE, env_lib.GROUP_EDGE_UP,
                         env_lib.GROUP_FABRIC, env_lib.GROUP_HOT],
                        np.int32)
    sw = np.asarray([env_lib.GROUP_NONE, env_lib.GROUP_SWITCH,
                     env_lib.GROUP_SWITCH, env_lib.GROUP_NONE], np.int32)
    at = jax.jit(env_lib.fault_scale_at)
    for t in TIMES:
        v_np = env_lib.fault_scale_np(table, groups, t, link_sw_group=sw)
        v_tr = np.asarray(at(jnp.asarray(table), jnp.asarray(groups),
                             jnp.float32(t),
                             link_sw_group=jnp.asarray(sw)))
        np.testing.assert_array_equal(v_tr, v_np, err_msg=str(t))
    mid = env_lib.fault_scale_np(table, groups, 2e-3, link_sw_group=sw)
    assert mid[1] == pytest.approx(0.2) and mid[2] == pytest.approx(0.2)
    assert mid[0] == 1.0 and mid[3] == 1.0  # non-switch links untouched
    np.testing.assert_array_equal(
        env_lib.fault_scale_np(table, groups, 0.5e-3, link_sw_group=sw),
        1.0)  # before the window


def test_switch_channel_guard_bit_identity():
    """Tables WITHOUT a GROUP_SWITCH row must produce bit-identical
    scales whether or not the sw channel is supplied: the channel can
    only match group-5 event rows, and only switch_outage writes 5s."""
    table = cong.fault_table([
        cong.outage(1e-3, 2e-3, 1.0, link_group=env_lib.GROUP_EDGE_UP),
        cong.flap(0.5e-3, 20e-3, duty=0.4, seed=5),
        cong.degrade(0.2e-3, 1.5e-3, severity=0.7,
                     link_group=env_lib.GROUP_FABRIC),
    ])
    sw = np.asarray([env_lib.GROUP_NONE, env_lib.GROUP_SWITCH,
                     env_lib.GROUP_SWITCH, env_lib.GROUP_NONE,
                     env_lib.GROUP_SWITCH], np.int32)
    for t in TIMES:
        np.testing.assert_array_equal(
            env_lib.fault_scale_np(table, GROUPS, t, link_sw_group=sw),
            env_lib.fault_scale_np(table, GROUPS, t), err_msg=str(t))


def test_switch_outage_bites_engine():
    """A hard switch outage through the geometry's stamped sw channel
    must cut goodput (guard against an accidentally-inert channel)."""
    geom, flows, _ = _cell()
    assert int(np.sum(np.asarray(geom.link_sw_group)
                      == env_lib.GROUP_SWITCH)) > 0
    table = cong.fault_table([cong.switch_outage(0.0, 1.0, 1.0)])
    _, gp0 = _run_steps(geom, _params(geom, flows, 0), "ref", n=50)
    _, gp1 = _run_steps(geom, _params(geom, flows, 0, fault=table),
                        "ref", n=50)
    assert float(jnp.sum(gp1)) < float(jnp.sum(gp0))


def test_link_fault_scenario_carries_switch_variant():
    """The full link_fault family now includes a whole-switch outage
    profile (the quick variant stays unchanged for CI cost)."""
    labels = [p.label() for g in scen.get("link_fault", quick=False).grids
              for p in g.profiles]
    assert any("outage[sw" in lab for lab in labels), labels
    quick = [p.label() for g in scen.get("link_fault", quick=True).grids
             for p in g.profiles]
    assert not any("outage[sw" in lab for lab in quick)
