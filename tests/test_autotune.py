"""Autotuner tests: analytic schedule choice, simulator tier, pod strategy."""
import numpy as np
import pytest

from repro.core import autotune, congestion as cong
from repro.core.fabric.systems import get_system


def test_small_message_prefers_fewer_steps():
    """Latency-bound: bidirectional ring halves serialized steps."""
    p = autotune.choose_schedule("all_gather", 16, 512.0)
    assert p.algo == "bidir_ring_all_gather"


def test_alltoall_linear_wins_analytically():
    p = autotune.choose_schedule("all_to_all", 16, 1 << 20)
    assert p.algo == "linear_all_to_all"  # same bytes, 1 step vs n-1


def test_predictions_monotone_in_bytes():
    t = [autotune.predict_analytic("all_gather", "ring_all_gather", 8, v).time_s
         for v in (1e3, 1e5, 1e7)]
    assert t[0] < t[1] < t[2]


def test_congestion_factor_scales_bandwidth_term():
    a = autotune.predict_analytic("all_gather", "ring_all_gather", 8, 1e8,
                                  congestion_factor=1.0)
    b = autotune.predict_analytic("all_gather", "ring_all_gather", 8, 1e8,
                                  congestion_factor=2.0)
    assert b.time_s > 1.8 * a.time_s


def test_simulated_tier_runs_and_caches():
    sysp = get_system("nanjing_nslb")
    p1 = autotune.choose_schedule("all_gather", 4, 1 << 20, system=sysp,
                                  use_simulator=True)
    p2 = autotune.choose_schedule("all_gather", 4, 1 << 20, system=sysp,
                                  use_simulator=True)
    assert p1.tier == "simulated" and p1.time_s > 0
    assert p1.algo == p2.algo  # cache hit -> stable decision


def test_simulated_congestion_slows_collective():
    sysp = get_system("nanjing_ecmp")
    base = autotune.predict_simulated(
        "all_to_all", "linear_all_to_all", 4, 16 << 20, sysp)
    cong_p = autotune.predict_simulated(
        "all_to_all", "linear_all_to_all", 4, 16 << 20, sysp,
        profile=cong.steady(), aggressor="alltoall")
    assert cong_p.time_s > 1.2 * base.time_s


def test_pod_strategy_compresses_large_grads():
    s = autotune.choose_pod_strategy(14e9, n_pods=2)  # 7B params bf16
    assert s.compress_grads
    assert s.speedup_on_collective_term > 2.0


def test_pod_strategy_skips_tiny_grads():
    # 1 MB of gradient: wire time trivial, quantization not worth structure
    s = autotune.choose_pod_strategy(1e6, n_pods=2, dcn_bw=400e9)
    # either decision is allowed but the predicted times must be sane
    assert s.predicted_collective_s <= s.predicted_baseline_s * 1.001
