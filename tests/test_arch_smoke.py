"""Per-architecture smoke tests (brief requirement (f)).

For every assigned architecture: instantiate the REDUCED same-family config,
run one train step + prefill + decode on CPU, assert output shapes and no
NaNs. Additionally check prefill->decode consistency: the decode step after
prefilling S tokens must (numerically) match a fresh prefill of S+1 tokens.
The FULL configs are exercised only via the dry-run (launch/dryrun.py).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config
from repro.launch.mesh import make_host_mesh, rules_for
from repro.launch.steps import init_train_state, make_train_step
from repro.models.api import build_model
from repro.optim.adamw import OptConfig, get_optimizer

ARCHS = list(all_arch_names())


def _reduced(name):
    cfg = get_config(name).reduced()
    # drop-free MoE dispatch so prefill/decode consistency is exact
    return dataclasses.replace(cfg, capacity_factor=8.0)


def _batch(cfg, rng, B=2, S=16):
    tok = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    return batch


def _pad_kv(cfg, cache, extra):
    """Grow full-attention KV caches along seq so decode can append."""
    if cfg.sliding_window:
        return cache  # ring buffer — fixed size

    def pad(path, x):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if key in ("k", "v") and x.ndim == 5:
            return jnp.pad(x, ((0, 0), (0, 0), (0, extra), (0, 0), (0, 0)))
        return x

    return jax.tree_util.tree_map_with_path(pad, cache)


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch, mesh):
    cfg = _reduced(arch)
    rules = rules_for(cfg, mesh)
    model = build_model(cfg, rules, mesh)
    opt = get_optimizer(cfg.optimizer, OptConfig(warmup_steps=1, lr=1e-3))
    step_fn = jax.jit(make_train_step(model, opt))
    rng = jax.random.PRNGKey(0)
    state = init_train_state(model, opt, rng)
    batch = _batch(cfg, rng)
    with jax.set_mesh(mesh):
        losses = []
        for _ in range(3):
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["total_loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    assert np.isfinite(float(metrics["grad_norm"]))
    # overfitting a single tiny batch must reduce the loss
    assert losses[-1] < losses[0], losses
    assert int(state["step"]) == 3


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, mesh):
    cfg = _reduced(arch)
    rules = rules_for(cfg, mesh)
    model = build_model(cfg, rules, mesh)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    B, S = 2, 16
    batch_full = _batch(cfg, rng, B=B, S=S + 1)
    batch_pre = dict(batch_full, tokens=batch_full["tokens"][:, :S],
                     labels=batch_full["labels"][:, :S])
    # the decode position is absolute within the cache; VLM prefill prepends
    # n_frontend_tokens patch embeddings ahead of the text tokens
    pos = S + (cfg.n_frontend_tokens if cfg.family == "vlm" else 0)
    with jax.set_mesh(mesh):
        logits_pre, cache = model.prefill(params, batch_pre)
        cache = _pad_kv(cfg, cache, extra=1)
        logits_dec, _ = model.decode(
            params, cache, batch_full["tokens"][:, S:S + 1], jnp.int32(pos))
        logits_ref, _ = model.prefill(params, batch_full)
    assert logits_dec.shape == (B, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits_dec)))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_ref), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_config_matches_assignment(arch):
    """The full config must carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
    }[arch]
    L, d, H, KH, dff, V = expected
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == H and cfg.n_kv_heads == KH
    assert cfg.d_ff == dff and cfg.vocab_size == V
    if arch == "grok-1-314b":
        assert (cfg.n_experts, cfg.top_k) == (8, 2)
    if arch == "kimi-k2-1t-a32b":
        assert (cfg.n_experts, cfg.top_k) == (384, 8)
    if arch in ("hymba-1.5b", "falcon-mamba-7b"):
        assert cfg.ssm_state == 16
    if arch == "whisper-tiny":
        assert cfg.enc_layers == 4


def test_param_counts_plausible():
    """Analytic parameter counts should land near the advertised sizes."""
    expect = {"grok-1-314b": 314e9, "phi3-mini-3.8b": 3.8e9, "yi-6b": 6e9,
              "granite-20b": 20e9, "nemotron-4-15b": 15e9,
              "falcon-mamba-7b": 7e9, "hymba-1.5b": 1.5e9,
              "internvl2-76b": 76e9, "kimi-k2-1t-a32b": 1.0e12}
    for name, n in expect.items():
        got = get_config(name).param_count()
        assert 0.55 * n < got < 1.45 * n, (name, got, n)
    # MoE active counts
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.active_param_count() < 0.1 * kimi.param_count()


def test_long_500k_applicability():
    """long_500k only for sub-quadratic archs (DESIGN.md §6 skip table)."""
    runnable = {a for a in ARCHS
                if any(s.name == "long_500k" for s in get_config(a).shapes())}
    assert runnable == {"falcon-mamba-7b", "hymba-1.5b"}
