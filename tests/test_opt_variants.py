"""Optimization-variant tests: every arch's "opt" config must build and
train on CPU, and the new sharding modes (seq_shard, ep_sp) must be
numerically equivalent to the baseline on a real multi-device mesh."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config
from repro.configs.opt_variants import apply_variant
from repro.launch.mesh import make_host_mesh, rules_for
from repro.models.api import build_model

ARCHS = list(all_arch_names())


@pytest.mark.parametrize("arch", ARCHS)
def test_opt_variant_smoke(arch):
    """Reduced opt-variant config: one loss eval, finite."""
    cfg = apply_variant(get_config(arch), "opt").reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    mesh = make_host_mesh()
    rules = rules_for(cfg, mesh)
    model = build_model(cfg, rules, mesh)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    tok = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((2, cfg.n_frontend_tokens, cfg.d_model),
                                    jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((2, cfg.n_frontend_tokens, cfg.d_model),
                                   jnp.float32)
    with jax.set_mesh(mesh):
        loss, _ = model.loss(params, batch)
    assert np.isfinite(float(loss))


def test_variant_respects_ssm_incompatibility():
    for arch in ("falcon-mamba-7b", "hymba-1.5b"):
        cfg = apply_variant(get_config(arch), "opt")
        assert not cfg.seq_shard  # sequential state cannot shard S


_EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import rules_for
from repro.models.api import build_model

report = {}
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((2, 4), ("data", "model"))

def loss_of(arch, **kw):
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              capacity_factor=8.0, **kw)
    rules = rules_for(cfg, mesh)
    model = build_model(cfg, rules, mesh)
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                             cfg.vocab_size)
    with jax.set_mesh(mesh):
        loss, _ = model.loss(params, {"tokens": tok, "labels": tok})
    return float(loss)

# sequence parallelism must not change the math
report["yi_base"] = loss_of("yi-6b")
report["yi_sp"] = loss_of("yi-6b", seq_shard=True)
# ep_sp MoE == ep MoE (4 reduced experts over data=2)
report["kimi_ep"] = loss_of("kimi-k2-1t-a32b")
report["kimi_ep_sp"] = loss_of("kimi-k2-1t-a32b", moe_sharding="ep_sp",
                               seq_shard=True)
print("REPORT" + json.dumps(report))
"""


@pytest.fixture(scope="module")
def equiv_report():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _EQUIV_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("REPORT")][-1]
    return json.loads(line[len("REPORT"):])


def test_seq_shard_equivalence(equiv_report):
    assert abs(equiv_report["yi_base"] - equiv_report["yi_sp"]) < 5e-3, \
        equiv_report


def test_ep_sp_equivalence(equiv_report):
    assert abs(equiv_report["kimi_ep"] - equiv_report["kimi_ep_sp"]) < 5e-3, \
        equiv_report
