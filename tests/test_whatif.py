"""Batched what-if serving layer (ISSUE 10, DESIGN.md §17).

Load-bearing contracts pinned here:

* **Coalescing bit-identity** — K queries answered in shared waves
  produce scorecards BIT-IDENTICAL to running each query alone (lane
  construction is per-(cell, candidate); padding lanes and foreign
  queries in the same wave are invisible under vmap).
* **Mixed buckets** — queries whose geometries land in different
  power-of-two buckets coalesce in the same wave without perturbing
  each other.
* **Budget semantics** — budget exhaustion returns best-so-far with
  ``finish_reason="budget"``; a drained grid returns ``"drained"``;
  duplicate candidates cost no evaluations.
"""
import functools

import numpy as np
import pytest

from repro.core import congestion as cong
from repro.core.fabric import simulator as sim
from repro.core.mitigation import agents
from repro.core.mitigation.search import Candidate
from repro.runtime import whatif

KW = dict(n_iters=5, warmup=2, max_steps=50_000)
KiB = float(1 << 10)

CANDS = tuple(agents.grid_candidates(("hol_factor", "md"),
                                     points_per_knob=2))


def _queries():
    qa = whatif.WhatIfQuery(system="cresco8", n_nodes=8,
                            vector_bytes=256 * KiB, agent="grid",
                            candidates=CANDS, budget=8, batch=2)
    # different scale -> different GeometryDims bucket than qa
    qb = whatif.WhatIfQuery(system="cresco8", n_nodes=16,
                            vector_bytes=128 * KiB, agent="grid",
                            candidates=CANDS[:3], budget=8, batch=2)
    return qa, qb


def _table(res):
    return {s.candidate: (s.ratio_min, s.ratio_mean, s.aggr_gbps,
                          s.jain, s.t_base_worst_rel)
            for s in res.scores}


@functools.lru_cache(maxsize=None)
def _serial_results():
    out = []
    for q in _queries():
        srv = whatif.WhatIfServer(max_batch=1, **KW)
        uid = srv.submit(q)
        srv.run_until_drained()
        out.append(srv.result(uid))
    return tuple(out)


def test_coalesced_bit_identical_to_serial_mixed_buckets():
    """Two mixed-bucket queries sharing waves must score every
    (cell, candidate) point bit-for-bit like the one-query-per-server
    runs, and agree on winners and frontiers."""
    qa, qb = _queries()
    srv = whatif.WhatIfServer(max_batch=4, **KW)
    ua, ub = srv.submit(qa), srv.submit(qb)
    stats = srv.run_until_drained()
    ra, rb = srv.result(ua), srv.result(ub)
    r1, r2 = _serial_results()
    assert _table(ra) == _table(r1)
    assert _table(rb) == _table(r2)
    assert ra.winner.candidate == r1.winner.candidate
    assert rb.winner.candidate == r2.winner.candidate
    assert [s.candidate for s in ra.frontier] \
        == [s.candidate for s in r1.frontier]
    # both queries drained their grids; the waves were truly shared
    assert ra.finish_reason == rb.finish_reason == "drained"
    assert stats.queries_done == 2
    assert stats.coalesced_calls < ra.evals + rb.evals, \
        "coalescing must batch many candidates per engine dispatch"
    assert stats.lanes > 0 and stats.evals == ra.evals + rb.evals


def test_coalesced_waves_one_call_per_wave():
    """Each wave is ONE run_candidate_rows invocation even with
    multiple active queries (the whole point of the serving layer)."""
    qa, qb = _queries()
    srv = whatif.WhatIfServer(max_batch=4, **KW)
    srv.submit(qa), srv.submit(qb)
    waves = 0
    while srv.active or srv.queue:
        calls0 = srv.stats.coalesced_calls
        srv.step_wave()
        waves += 1
        assert srv.stats.coalesced_calls - calls0 <= 1
        if waves > 20:
            pytest.fail("server failed to drain")
    assert srv.stats.waves == waves


def test_budget_exhaustion_returns_best_so_far():
    q = whatif.WhatIfQuery(system="cresco8", n_nodes=8,
                           vector_bytes=128 * KiB, agent="grid",
                           candidates=CANDS, budget=2, batch=2)
    srv = whatif.WhatIfServer(max_batch=2, **KW)
    uid = srv.submit(q)
    assert srv.poll(uid) is None
    with pytest.raises(KeyError):
        srv.result(uid)
    srv.run_until_drained()
    res = srv.result(uid)
    assert res.finish_reason == "budget"
    assert res.evals == 2  # stopped at the budget, not the grid size
    assert len(res.scores) == 3  # default + 2 evaluated candidates
    assert res.winner is not None and np.isfinite(res.objective)
    assert res.winner_candidate is None \
        or res.winner_candidate.label() == res.winner.candidate


def test_duplicate_candidates_cost_nothing():
    dup = (CANDS[0], CANDS[1], CANDS[0], CANDS[1], CANDS[2])
    q = whatif.WhatIfQuery(system="cresco8", n_nodes=8,
                           vector_bytes=128 * KiB, agent="grid",
                           candidates=dup, budget=10, batch=2)
    srv = whatif.WhatIfServer(**KW)
    uid = srv.submit(q)
    srv.run_until_drained()
    res = srv.result(uid)
    assert res.finish_reason == "drained"
    assert res.evals == 3  # the two repeats were served from the memo
    assert len(res.scores) == 4  # default + 3 distinct candidates


def test_agent_tier_budget_and_observe():
    q = whatif.WhatIfQuery(system="cresco8", n_nodes=8,
                           vector_bytes=128 * KiB, agent="cmaes",
                           knobs=("hol_factor", "md"), budget=6, batch=3,
                           seed=0)
    srv = whatif.WhatIfServer(**KW)
    uid = srv.submit(q)
    srv.run_until_drained()
    res = srv.result(uid)
    assert res.finish_reason == "budget" and res.evals >= 6
    assert len(res.frontier) >= 1
    # the query's agent actually observed its generations
    assert res.scores and np.isfinite(res.objective)


def test_query_validation():
    with pytest.raises(KeyError):
        whatif.WhatIfQuery(system="cresco8", n_nodes=8, agent="annealing")
    with pytest.raises(ValueError):
        whatif.WhatIfQuery(system="cresco8", n_nodes=8, budget=0)
    with pytest.raises(KeyError):
        whatif.WhatIfQuery(system="not_a_fabric", n_nodes=8)


def test_whatif_launcher_helper():
    """launch.sweep.whatif_launcher wires the lane-sharded dispatch the
    serving layer uses on a mesh — on the 1-device mesh it must be
    bit-identical to the plain path."""
    import jax

    from repro.launch.sweep import whatif_launcher

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("cell",))
    q = whatif.WhatIfQuery(system="cresco8", n_nodes=8,
                           vector_bytes=128 * KiB, agent="grid",
                           candidates=CANDS[:2], budget=4, batch=2)
    srv = whatif.WhatIfServer(launcher=whatif_launcher(mesh), **KW)
    uid = srv.submit(q)
    srv.run_until_drained()
    res = srv.result(uid)
    plain = whatif.WhatIfServer(**KW)
    uid2 = plain.submit(q)
    plain.run_until_drained()
    assert _table(res) == _table(plain.result(uid2))
