"""Scenario-registry completeness: every registered family must build
its cases (quick and full), run one shrunk cell end-to-end through its
grid path, and emit exactly the cache-key columns the benchmark drivers
and the CSV cache read (benchmarks.common.expected_grid_keys is the
shared source of truth — this is the drift catcher for the CSV layout
PR 2 had to patch around)."""
import dataclasses
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import (SCENARIO_KEYS, expected_grid_keys,  # noqa: E402
                               expected_point_keys)
from repro.core import scenarios as scen  # noqa: E402
from repro.core.fabric import systems  # noqa: E402

GRID_DRIVER_COLS = {"ratio", "t_uncongested_us", "t_congested_us"}


def test_every_scenario_builds_quick_and_full():
    assert scen.SCENARIOS, "registry is empty"
    # the mitigation lab's families must stay registered (its scoring
    # panel is drawn from the registry — score.panel_from_scenario), and
    # so must the fault-engine families benchmarks.fault_scenarios runs
    assert {"mitigation_panel", "mitigation_routing",
            "link_fault", "intra_node"} <= set(scen.SCENARIOS)
    for name in scen.SCENARIOS:
        for quick in (False, True):
            s = scen.get(name, quick)
            assert s.name == name
            assert s.grids or s.points or s.microbench_sizes, name
            for grid in s.grids:
                assert grid.sizes and grid.profiles, (name, grid)
                for sysname, n in grid.cells or ((grid.system,
                                                  grid.n_nodes),):
                    if grid.cells:
                        assert sysname in systems.PRESETS, (name, sysname)
                        assert int(n) >= 2, (name, sysname, n)


def _shrunk(scenario):
    """One quick cell of the scenario's first grid (scale-batched grids
    keep two cells so the batched path itself is exercised)."""
    grid = scenario.grids[0]
    grid = dataclasses.replace(grid, sizes=grid.sizes[:1],
                               profiles=grid.profiles[:1],
                               cells=grid.cells[:2])
    return dataclasses.replace(scenario, n_iters=6, warmup=1,
                               grids=(grid,)), grid


@pytest.mark.parametrize("name", sorted(scen.SCENARIOS))
def test_registered_family_runs_and_emits_driver_columns(name):
    scenario = scen.get(name, quick=True)
    if not scenario.grids:
        # points/microbench families: the matching driver interprets the
        # tuples — validate the references they carry
        assert scenario.points or scenario.microbench_sizes
        if scenario.points:
            # cache-key layout: POINT_KEYS and the point tuples must agree
            # (raises on drift), and points must be unique cache keys
            _, pts = expected_point_keys(scenario)
            assert len(pts) == len(set(pts)), name
        if name == "fig3_sawtooth":
            assert all(s in systems.PRESETS for s, _ in scenario.points)
        if name == "fig4_nslb":
            assert {m for m, _ in scenario.points} <= {"nslb", "ecmp"}
        if name == "fleet_replay":
            for s, n, n_seeds in scenario.points:
                assert s in systems.PRESETS, (name, s)
                assert int(n) >= 2 and int(n_seeds) >= 1, (name, n, n_seeds)
        return

    scenario, grid = _shrunk(scenario)
    rows = [scen.result_row(grid, r)
            for r in scen.run_grid_spec(scenario, grid)]
    assert rows, name

    # cache keys: exactly what benchmarks.common would expect, in order
    got = [tuple(str(row[k]) for k in SCENARIO_KEYS) for row in rows]
    assert got == expected_grid_keys(grid), name

    for row in rows:
        assert GRID_DRIVER_COLS <= set(row), (name, sorted(row))
        assert 0.0 < float(row["ratio"]) <= 1.2, (name, row)
        prof = grid.profiles[0]
        if prof.kind in ("bursty", "random"):
            assert "burst_ms" in row and "pause_ms" in row, name
        if grid.jobs:
            assert "job_times" in row, name
