"""Custom collective schedules vs XLA one-shot natives on an 8-device mesh.

jax locks the device count at first backend init, and conftest must NOT
force a multi-device view (the brief: smoke tests see 1 device). These
tests therefore run one subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` that executes every
check and reports JSON; the pytest cases assert on the parsed report.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P

from repro.core import collectives as C

mesh = jax.make_mesh((8,), ("x",))
n = 8
report = {}

x = jax.random.normal(jax.random.PRNGKey(0), (n, 4, 16), jnp.float32)

def run(fn, inp, in_spec=P("x"), out_spec=P(None)):
    return np.asarray(C.run_on_mesh(mesh, "x", fn, inp, in_spec, out_spec))

# ring all-gather == the full buffer in rank order, replicated
full = np.asarray(x)
ag = run(partial(C.ring_all_gather, axis_name="x", n=n),
         x.reshape(n * 4, 16), in_spec=P("x"), out_spec=P(None))
report["ring_ag"] = float(np.abs(ag.reshape(n, 4, 16) - full).max())

bag = run(lambda v: C.ring_all_gather(v, "x", n, bidirectional=True),
          x.reshape(n * 4, 16), in_spec=P("x"), out_spec=P(None))
report["bidir_ring_ag"] = float(np.abs(bag.reshape(n, 4, 16) - full).max())

# ring reduce-scatter: rank r gets sum over ranks of chunk r
# per-rank payload under P("x") keeps the rank: (1, n, 3) -> v[0] is (n, 3)
y = jax.random.normal(jax.random.PRNGKey(1), (n, n, 3), jnp.float32)
rs = run(lambda v: C.ring_reduce_scatter(v[0], "x", n),
         y, in_spec=P("x"), out_spec=P("x"))
want_rs = np.asarray(y).sum(axis=0)  # (n, 3): chunk r summed over ranks
report["ring_rs"] = float(np.abs(rs.reshape(n, 3) - want_rs).max())

# ring all-reduce == everyone holds the full sum (replicated output)
ar = run(lambda v: C.ring_all_reduce(v[0], "x", n),
         y, in_spec=P("x"), out_spec=P(None))
report["ring_ar"] = float(np.abs(np.asarray(ar) - want_rs).max())

# all-to-all schedules vs the native one-shot
z = jnp.arange(n * n * 2, dtype=jnp.float32).reshape(n, n, 2)
native = run(lambda v: jax.lax.all_to_all(v[0], "x", 0, 0, tiled=True),
             z, in_spec=P("x"), out_spec=P("x"))
linear = run(lambda v: C.linear_all_to_all(v[0], "x", n),
             z, in_spec=P("x"), out_spec=P("x"))
pair = run(lambda v: C.pairwise_all_to_all(v[0], "x", n),
           z, in_spec=P("x"), out_spec=P("x"))
report["a2a_linear"] = float(np.abs(linear - native).max())
report["a2a_pairwise"] = float(np.abs(pair - native).max())

# incast: root 0 collects everyone's buffer. Output differs per rank
# (zeros off-root), so gather all ranks' views and check the root's.
w = jax.random.normal(jax.random.PRNGKey(2), (n, 5), jnp.float32)
inc = run(lambda v: C.incast_gather(v[0], "x", n, root=0),
          w, in_spec=P("x"), out_spec=P("x"))
inc = inc.reshape(n, n, 5)  # rank-major stacking
report["incast"] = float(np.abs(inc[0] - np.asarray(w)).max())

# MoE EP dispatch path on a real 8-way mesh (the paper's AlltoAll pattern)
import dataclasses
from repro.configs import get_config
from repro.models.api import build_model
from repro.launch.mesh import rules_for
mesh2 = jax.make_mesh((8, 1), ("data", "model"))
cfg = dataclasses.replace(get_config("kimi-k2-1t-a32b").reduced(),
                          n_experts=16, top_k=2, capacity_factor=8.0)
rules = rules_for(cfg, mesh2)
model = build_model(cfg, rules, mesh2)
params = model.init(jax.random.PRNGKey(0))
tok = jax.random.randint(jax.random.PRNGKey(1), (16, 8), 0, cfg.vocab_size)
with jax.set_mesh(mesh2):
    loss, metrics = model.loss(params, {"tokens": tok, "labels": tok})
report["moe_ep8_loss_finite"] = bool(jnp.isfinite(loss))
# same loss on a single-device run (EP must not change the math)
mesh1 = jax.make_mesh((1, 1), ("data", "model"))
rules1 = rules_for(cfg, mesh1)
model1 = build_model(cfg, rules1, mesh1)
with jax.set_mesh(mesh1):
    loss1, _ = model1.loss(params, {"tokens": tok, "labels": tok})
report["moe_ep_vs_single"] = abs(float(loss) - float(loss1))

# analyzer correction: a bf16-primal psum must be counted at 2 B/elem even
# though the CPU backend float-normalizes the wire to f32 (A1), and the
# CPU tuple-form scaffolding must not inflate HBM bytes (A2)
from repro.launch.hlo_stats import analyze
def psum_bf16(v):
    return jax.lax.psum(v.astype(jnp.bfloat16), "x").astype(jnp.float32)
fn = jax.jit(jax.shard_map(psum_bf16, mesh=mesh, in_specs=P(),
                           out_specs=P(), check_vma=False))
text = fn.lower(jnp.ones((1024,), jnp.float32)).compile().as_text()
st = analyze(text, 8)
elems = 1024
bf16_ar_wire = 2 * (7 / 8) * elems * 2  # ring all-reduce, 2-byte elements
report["bf16_psum_wire"] = st["collectives"]["total"]["wire_bytes"]
report["bf16_psum_wire_expected"] = bf16_ar_wire

print("REPORT" + json.dumps(report))
"""


@pytest.fixture(scope="module")
def report():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("REPORT")][-1]
    return json.loads(line[len("REPORT"):])


def test_ring_all_gather(report):
    assert report["ring_ag"] < 1e-6
    assert report["bidir_ring_ag"] < 1e-6


def test_ring_reduce_scatter(report):
    assert report["ring_rs"] < 1e-5


def test_ring_all_reduce(report):
    assert report["ring_ar"] < 1e-5


def test_all_to_all_schedules(report):
    assert report["a2a_linear"] < 1e-6
    assert report["a2a_pairwise"] < 1e-6


def test_incast(report):
    assert report["incast"] < 1e-6


def test_moe_ep_dispatch(report):
    assert report["moe_ep8_loss_finite"]
    assert report["moe_ep_vs_single"] < 5e-3


def test_bf16_wire_correction(report):
    """hlo_stats must count bf16-primal collectives at 2 B/element despite
    the CPU backend's f32 float-normalization (EXPERIMENTS.md §Perf A1)."""
    got = report["bf16_psum_wire"]
    want = report["bf16_psum_wire_expected"]
    assert got <= want * 1.10, (got, want)  # not counted as f32 (2x)
    assert got >= want * 0.5, (got, want)   # and not dropped entirely


# ---------------------------------------------------------------------------
# analytic wire-byte model invariants (pure python — no devices needed)
# ---------------------------------------------------------------------------

def test_wire_bytes_model():
    from repro.core.collectives import wire_bytes_model as wbm

    v = 1024.0
    for n in (2, 4, 16):
        ag = wbm("ring_all_gather", n, v)
        ar = wbm("ring_all_reduce", n, v)
        a2a = wbm("linear_all_to_all", n, v)
        inc = wbm("incast", n, v)
        assert np.isclose(ar["bytes"], 2 * ag["bytes"])  # RS+AG
        assert ag["steps"] == n - 1 and ar["steps"] == 2 * (n - 1)
        assert np.isclose(a2a["bytes"], (n - 1) / n * v)
        assert inc["bytes"] == v
        # bidirectional halves the serialized step count
        bi = wbm("bidir_ring_all_gather", n, v)
        assert bi["steps"] == (n - 1 + 1) // 2
        assert np.isclose(bi["bytes"], ag["bytes"])
    assert wbm("ring_all_gather", 1, v) == {"bytes": 0.0, "steps": 0}
