"""Simulator property tests: monotonicity, straggler gating, profile
ordering — the invariants any congestion model must satisfy."""
import numpy as np
import pytest

from repro.core import bench, congestion as cong
from repro.core.fabric import systems


def test_congestion_never_helps():
    """ratio = t_uncongested / t_congested must be <= ~1 (within noise)."""
    for sysn in ("leonardo", "lumi", "cresco8"):
        r = bench.run_point(systems.get_system(sysn), 32, "ring_allgather",
                            "alltoall", 2 << 20, cong.steady(),
                            n_iters=20, warmup=4)
        assert r.ratio <= 1.1, (sysn, r.ratio)


def test_more_intense_duty_cycle_is_worse_or_equal():
    """Monotone in the burst duty cycle (same period)."""
    sysp = systems.get_system("leonardo")
    ratios = []
    for burst, pause in ((1e-3, 7e-3), (4e-3, 4e-3), (7e-3, 1e-3)):
        r = bench.run_point(sysp, 32, "ring_allgather", "incast", 2 << 20,
                            cong.bursty(burst, pause), n_iters=20, warmup=4)
        ratios.append(r.ratio)
    assert ratios[0] >= ratios[1] - 0.08
    assert ratios[1] >= ratios[2] - 0.08
    assert ratios[0] > ratios[2]  # light duty strictly better than heavy


def test_steady_at_least_as_bad_as_any_burst():
    sysp = systems.get_system("leonardo")
    steady = bench.run_point(sysp, 32, "ring_allgather", "incast", 2 << 20,
                             cong.steady(), n_iters=20, warmup=4).ratio
    light = bench.run_point(sysp, 32, "ring_allgather", "incast", 2 << 20,
                            cong.bursty(1e-3, 7e-3), n_iters=20,
                            warmup=4).ratio
    assert steady <= light + 0.05


def test_straggler_gates_collective():
    """A 10x-degraded NIC on one node must stretch a synchronous ring
    collective by >3x (gated by the slowest member) — the signal that
    makes elastic eviction pay (DESIGN.md §7)."""
    out = bench.straggler_impact(systems.get_system("nanjing_nslb"), 8,
                                 "ring_allgather", 8 << 20, slow_factor=0.1)
    assert out["slowdown"] > 3.0, out
    assert out["slowdown"] < 20.0, out  # and bounded by ~1/slow_factor


def test_bigger_vectors_take_longer():
    sysp = systems.get_system("lumi")
    t = []
    for v in (1 << 20, 8 << 20, 64 << 20):
        r = bench.run_point(sysp, 16, "ring_allgather", "", v,
                            cong.no_congestion(), n_iters=15, warmup=3)
        t.append(r.t_uncongested_s)
    assert t[0] < t[1] < t[2]
