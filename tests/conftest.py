"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single device (the 512-device override
belongs to launch/dryrun.py alone). Multi-device collective tests spawn a
subprocess with their own flags (tests/test_collectives.py)."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # pragma: no cover - prefer the real library when present
    import hypothesis  # noqa: F401
except ImportError:
    # Minimal deterministic stand-in so property tests still run (with
    # bounded pseudo-random examples) on images without hypothesis.
    import functools
    import random
    import types

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw  # draw(rng, example_index) -> value

    def _integers(a, b):
        return _Strategy(
            lambda rng, i: a if i == 0 else b if i == 1 else rng.randint(a, b))

    def _floats(a, b):
        import math

        def draw(rng, i):
            if i == 0:
                return a
            if i == 1:
                return b
            if a > 0 and b / a > 1e3:  # log-uniform for wide positive ranges
                return math.exp(rng.uniform(math.log(a), math.log(b)))
            return rng.uniform(a, b)

        return _Strategy(draw)

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng, i: seq[i % len(seq)] if i < len(seq)
                         else rng.choice(seq))

    def _given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(1234)
                n = getattr(wrapper, "_max_examples", 20)
                for i in range(n):
                    drawn = {k: s.draw(rng, i) for k, s in strats.items()}
                    fn(*args, **kwargs, **drawn)
            # hide the wrapped signature so pytest does not treat the
            # strategy parameters as fixtures
            import inspect

            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            wrapper._max_examples = 20
            return wrapper
        return deco

    def _settings(max_examples=20, deadline=None, **_):
        def deco(fn):
            fn._max_examples = min(int(max_examples), 20)
            return fn
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def host_mesh():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh()


@pytest.fixture(scope="session")
def rng0():
    import jax

    return jax.random.PRNGKey(0)
