"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single device (the 512-device override
belongs to launch/dryrun.py alone). Multi-device collective tests spawn a
subprocess with their own flags (tests/test_collectives.py)."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def host_mesh():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh()


@pytest.fixture(scope="session")
def rng0():
    import jax

    return jax.random.PRNGKey(0)
