"""Sharded sweep launcher (launch/sweep.py) + persistent compile cache.

In-process tests use a 1-device mesh (the tier-1 suite must not force a
host device count — conftest.py); the multi-device bit-identity and
warm-cache properties are exercised through the launcher's own subprocess
smoke (``--smoke --host-devices 2 --tiny``), which forces devices in
fresh children.
"""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from repro.core import bench, congestion as cong
from repro.core.fabric import simulator as sim_lib, systems
from repro.core.mitigation import score as mscore, search as msearch
from repro.launch import sweep
from repro.launch.mesh import make_sweep_mesh

CELLS = [("cresco8", 8), ("cresco8", 12)]
GRID_KW = dict(n_iters=6, warmup=2)


def _rows_equal(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        for f in ("system", "n_nodes", "vector_bytes", "profile"):
            assert getattr(ra, f) == getattr(rb, f)
        for f in ("t_uncongested_s", "t_congested_s", "ratio"):
            va, vb = getattr(ra, f), getattr(rb, f)
            assert va == vb or (np.isnan(va) and np.isnan(vb)), \
                (f, va, vb)  # bit-identical, not approx


def test_shard_bounds():
    assert sweep._shard_bounds(10, 4) == [(0, 3), (3, 6), (6, 8), (8, 10)]
    assert sweep._shard_bounds(4, 4) == [(0, 1), (1, 2), (2, 3), (3, 4)]
    # fewer items than devices: empty shards are skipped, not dispatched
    assert sweep._shard_bounds(2, 8) == [(0, 1), (1, 2)]
    assert [hi - lo for lo, hi in sweep._shard_bounds(17, 4)] \
        == [5, 4, 4, 4]


def test_pad_batch():
    tree = {"a": np.arange(10).reshape(5, 2), "b": np.ones(5)}
    padded = sim_lib.pad_batch(tree, 4)
    assert padded["a"].shape == (8, 2) and padded["b"].shape == (8,)
    np.testing.assert_array_equal(padded["a"][:5], tree["a"])
    np.testing.assert_array_equal(padded["a"][5:], tree["a"][[0, 0, 0]])
    # already a multiple: returned untouched
    assert sim_lib.pad_batch(tree, 5) is tree
    # axis-1 padding (candidate lanes)
    p1 = sim_lib.pad_batch({"x": np.arange(6).reshape(2, 3)}, 2, axis=1)
    assert p1["x"].shape == (2, 4)
    np.testing.assert_array_equal(p1["x"][:, 3], p1["x"][:, 0])


def test_device_launcher_bit_identical_to_plain():
    """run_scale_grid through the per-device dispatcher (1-device mesh —
    every executable is the plain single-device jit) reproduces the
    plain path bit for bit; ShardedOut marshals lazily."""
    plain = bench.run_scale_grid(CELLS, "ring_allgather", "incast",
                                 [1 << 20], [cong.steady()], **GRID_KW)
    mesh = make_sweep_mesh()
    sharded = bench.run_scale_grid(CELLS, "ring_allgather", "incast",
                                   [1 << 20], [cong.steady()], mesh=mesh,
                                   **GRID_KW)
    _rows_equal(plain, sharded)


def test_shard_map_entry_bit_identical_on_one_device_mesh():
    """simulator.run_cells_hetero(mesh=...) — the shard_map dispatch —
    is bit-identical to the plain batched call on a 1-device mesh, and
    the sharded executable is memoized per mesh (one trace, reused)."""
    sysp = systems.get_system("cresco8")
    cases = [bench.build_case(sysp, n, "ring_allgather", "incast")
             for _, n in CELLS]
    dims, stacked = bench.bucket_stack([c.geom for c in cases])
    rows = []
    for case in cases:
        dt = bench.choose_dt(case.topo, case.n_victims, 1 << 20, case.lat())
        p = case.cell_params(1 << 20, cong.steady(), dt,
                             n_flows=dims.n_flows)
        rows.append(sim_lib.stack_params([p, p]))
    params = sim_lib.stack_params(rows)
    kw = dict(chunk=512, max_chunks=40, stride=8)
    n_it = jnp.asarray(6, jnp.int32)

    plain = sim_lib.run_cells_hetero(stacked, params, n_it, **kw)
    mesh = make_sweep_mesh()
    before = sim_lib.trace_count("run_cells_hetero_sharded")
    out1 = sim_lib.run_cells_hetero(stacked, params, n_it, mesh=mesh, **kw)
    out2 = sim_lib.run_cells_hetero(stacked, params, n_it, mesh=mesh, **kw)
    assert sim_lib.trace_count("run_cells_hetero_sharded") - before <= 1
    for k in plain:
        a = np.asarray(plain[k])
        np.testing.assert_array_equal(a, np.asarray(out1[k]), err_msg=k)
        np.testing.assert_array_equal(a, np.asarray(out2[k]), err_msg=k)

    # lane sharding slices the candidate axis instead of the cell axis
    lane = sim_lib.run_cells_hetero(stacked, params, n_it, mesh=mesh,
                                    shard_axis="lane", **kw)
    for k in plain:
        np.testing.assert_array_equal(np.asarray(plain[k]),
                                      np.asarray(lane[k]), err_msg=k)


def test_launch_then_collect_matches_blocking_run():
    """launch_scale_grid returns without marshalling; .results() later
    yields exactly what the blocking run_scale_grid returns — so grids
    launched back-to-back overlap marshal with in-flight compute."""
    args = (CELLS, "ring_allgather", "incast", [1 << 20], [cong.steady()])
    pending = bench.launch_scale_grid(*args, **GRID_KW)
    blocking = bench.run_scale_grid(*args, **GRID_KW)
    _rows_equal(pending.results(), blocking)


def test_run_candidates_launcher_parity():
    """The mitigation search's lane-sharded launcher path (candidates
    ride vmap lanes) matches the plain call bit for bit on one device."""
    panel = mscore.panel_from_scenario(quick=True)[:1]
    cands = [msearch.default_candidate(),
             msearch.Candidate(policy=1, name="ecmp")]
    plain = msearch.run_candidates(panel, cands, n_iters=6, warmup=2)
    mesh = make_sweep_mesh()
    sharded = msearch.run_candidates(panel, cands, n_iters=6, warmup=2,
                                     mesh=mesh)
    assert len(plain) == len(sharded) == len(panel) * len(cands)
    for ra, rb in zip(plain, sharded):
        assert (ra.cell, ra.candidate) == (rb.cell, rb.candidate)
        assert ra.ratio == rb.ratio or (np.isnan(ra.ratio)
                                        and np.isnan(rb.ratio))
        assert ra.victim_bytes == rb.victim_bytes
        assert ra.aggr_bytes == rb.aggr_bytes


def test_compile_cache_env_resolution(tmp_path, monkeypatch):
    """ensure_compile_cache: explicit dir wins, env var is the fallback,
    and the first successful activation sticks (idempotent)."""
    monkeypatch.setattr(sim_lib, "_COMPILE_CACHE_DIR", None)
    monkeypatch.setenv(sim_lib.COMPILE_CACHE_ENV, str(tmp_path / "env"))
    active = sim_lib.ensure_compile_cache()
    assert active == str(tmp_path / "env") and os.path.isdir(active)
    # already active: a different request is a no-op, not a re-point
    assert sim_lib.ensure_compile_cache(str(tmp_path / "other")) == active


def test_force_host_device_count_appends(monkeypatch):
    from repro.jax_compat import force_host_device_count
    monkeypatch.setenv("XLA_FLAGS",
                       "--xla_foo=1 --xla_force_host_platform_device_count=3")
    force_host_device_count(8)
    flags = os.environ["XLA_FLAGS"].split()
    assert "--xla_foo=1" in flags  # user flag survives
    assert flags.count("--xla_force_host_platform_device_count=8") == 1
    assert not any(f.endswith("=3") for f in flags)  # replaced, not stacked


def test_dryrun_import_preserves_user_xla_flags(tmp_path):
    """Importing launch.dryrun used to OVERWRITE $XLA_FLAGS; it must now
    append its device-count flag after whatever the user set."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_cpu_enable_fast_math=false",
               PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c",
         "import repro.launch.dryrun, os; print(os.environ['XLA_FLAGS'])"],
        env=env, capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), timeout=240)
    assert r.returncode == 0, r.stderr[-2000:]
    flags = r.stdout.strip().split()
    assert "--xla_cpu_enable_fast_math=false" in flags
    assert "--xla_force_host_platform_device_count=512" in flags


def test_sweep_smoke_two_devices(tmp_path):
    """The acceptance harness end-to-end (subprocess children force 2
    host devices): sharded launch bit-identical to single-device, cache
    populated, warm relaunch cheaper than cold."""
    out = tmp_path / "smoke.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.sweep", "--smoke",
         "--host-devices", "2", "--tiny", "--out", str(out)],
        env=dict(os.environ, PYTHONPATH="src"), capture_output=True,
        text=True, cwd=os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), timeout=540)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    report = json.loads(out.read_text())
    assert report["ok"], report["checks"]
    assert report["checks"]["bit_identical_scale"]
    assert report["checks"]["bit_identical_panel"]
    assert report["sharded_cold"]["n_devices"] == 2
