"""Learned mitigation-search agents (ISSUE 10, DESIGN.md §17).

Load-bearing contracts pinned here:

* **Equal-budget convergence** — on the fixed seeded panel, CMA-ES or
  BO reaches the bounded-grid winner's objective with STRICTLY fewer
  simulator evaluations than the random-walk baseline (the acceptance
  criterion the whatif benchmark records).
* **One batched call per generation** — every generation is one
  ``run_candidates`` invocation, and once the steady-state lane shape
  is traced, later generations (and later agents at the same batch
  size) add zero new ``run_cells_hetero`` compiles.
* **Determinism** — a fixed seed fixes the whole search: proposals,
  scores, trajectory.
* **Memoization** — re-proposed candidates are served from the
  evaluator's label-keyed table, never re-simulated.
"""
import functools

import numpy as np
import pytest

from repro.core import congestion as cong
from repro.core.fabric import simulator as sim
from repro.core.fabric.systems import get_system
from repro.core.mitigation import agents, search
from repro.core.mitigation.search import Candidate, PanelCell

KW = dict(n_iters=5, warmup=2, max_steps=60_000)
KNOBS = ("hol_factor", "md")


@functools.lru_cache(maxsize=None)
def _panel():
    """One collision-prone cell whose objective actually moves under
    the searched knobs (probed spread ~0.50-0.56)."""
    return (PanelCell(name="ecmp8", system=get_system("nanjing_ecmp"),
                      n_nodes=8, victim="ring_allgather",
                      aggressor="alltoall", vector_bytes=float(4 << 20),
                      profile=cong.steady()),)


# --------------------------------------------------------------------------
# pure-host contracts (no simulator)
# --------------------------------------------------------------------------


def test_unit_cube_roundtrip_and_clipping():
    ag = agents.make_agent("random", knobs=KNOBS, batch=2, seed=3)
    x = np.asarray([0.25, 0.75])
    c = ag.to_candidate(x)
    np.testing.assert_allclose(ag.to_vector(c), x, atol=1e-12)
    vals = dict(c.cc)
    from repro.core.fabric.cc import SEARCH_BOUNDS
    for k in KNOBS:
        lo, hi = SEARCH_BOUNDS[k]
        assert lo <= vals[k] <= hi
    # out-of-cube vectors clip to the bounds instead of escaping them
    edge = dict(ag.to_candidate(np.asarray([-3.0, 7.0])).cc)
    assert edge["hol_factor"] == SEARCH_BOUNDS["hol_factor"][0]
    assert edge["md"] == SEARCH_BOUNDS["md"][1]


def test_agent_registry_and_knob_validation():
    assert set(agents.AGENTS) == {"random", "ga", "cmaes", "bo"}
    with pytest.raises(KeyError):
        agents.make_agent("annealing")
    with pytest.raises(KeyError):
        # "kind" is the integer CC-kind axis — not a continuous knob
        agents.make_agent("random", knobs=("kind",))
    with pytest.raises(ValueError):
        agents.make_agent("ga", batch=0)


@pytest.mark.parametrize("kind", sorted(agents.AGENTS))
def test_agent_proposals_deterministic_under_seed(kind):
    """Same seed + same synthetic observations => identical proposal
    stream; a different seed diverges. (No simulator involved.)"""

    def drive(seed):
        ag = agents.make_agent(kind, knobs=KNOBS, batch=4, seed=seed)
        seen = []
        for g in range(4):
            props = ag.propose(ag.history)
            assert len(props) == 4
            seen.extend(c.label() for c in props)
            # synthetic but deterministic objective: distance to a corner
            obs = [agents.Observation(
                c, -float(np.sum((ag.to_vector(c) - 0.2) ** 2)), None)
                for c in props]
            ag.observe(obs)
        return seen

    assert drive(7) == drive(7)
    assert drive(7) != drive(8)


def test_trajectory_evals_to():
    tr = agents.Trajectory(agent="x", evals=[4, 8, 12],
                           best=[0.1, 0.5, 0.6])
    assert tr.evals_to(0.5) == 8
    assert tr.evals_to(0.05) == 4
    assert tr.evals_to(0.9) is None


# --------------------------------------------------------------------------
# batched evaluation: memo table, default baseline, compile sharing
# --------------------------------------------------------------------------


def test_evaluator_memoizes_and_charges_fresh_only():
    ev = agents.PanelEvaluator(_panel(), **KW)
    c1 = Candidate(cc=(("hol_factor", 0.3), ("md", 0.5)))
    c2 = Candidate(cc=(("hol_factor", 0.7), ("md", 0.5)))
    s = ev.evaluate([c1, c2])
    assert ev.evals == 2 and ev.calls == 1 and ev.table_hits == 0
    # the default baseline rode the first batch (needed by aggregate)
    assert "default" in ev.table
    again = ev.evaluate([c1, c1, c2])
    assert ev.evals == 2 and ev.calls == 1 and ev.table_hits == 3
    assert [x.candidate for x in again] == [s[0].candidate,
                                            s[0].candidate,
                                            s[1].candidate]
    # fresh + memoized mix charges only the fresh point
    c3 = Candidate(cc=(("hol_factor", 0.5), ("md", 0.9)))
    ev.evaluate([c1, c3])
    assert ev.evals == 3 and ev.calls == 2 and ev.table_hits == 4


def test_compare_agents_convergence_and_compile_contract():
    """The headline acceptance test: at equal budget on the fixed seeded
    panel, CMA-ES or BO reaches the bounded-grid winner's objective with
    strictly fewer simulator evaluations than random walk; every
    generation is one batched call; steady-state generations add no new
    compiles; the whole search is seed-deterministic."""
    before = sim.trace_count("run_cells_hetero")
    rep = agents.compare_agents(["random", "ga", "cmaes", "bo"], _panel(),
                                budget=24, batch=8, knobs=KNOBS, seed=0,
                                **KW)
    new_traces = sim.trace_count("run_cells_hetero") - before
    assert rep["target"]["objective"] > 0.5  # congestion actually bites

    def reached(kind):
        e = rep["agents"][kind]["evals_to_target"]
        return float("inf") if e is None else e

    assert min(reached("cmaes"), reached("bo")) < reached("random"), rep
    for kind, d in rep["agents"].items():
        assert d["evals"][-1] >= 24, (kind, d["evals"])
        assert d["best"] == sorted(d["best"]), kind  # monotone best-so-far
        assert d["best"][-1] <= rep["target"]["objective"] + 0.05
        # once the steady-state lane shape exists, later generations re-use
        # the executable (trace deltas flatten after the second generation)
        assert d["traces"][-1] == d["traces"][1], (kind, d["traces"])
    # across the whole 4-agent comparison + grid reference only a handful
    # of lane shapes exist (grid width, first-gen width, steady width,
    # and table-hit-shortened rows) — far fewer than total generations
    assert new_traces <= 6, new_traces

    # determinism: the same seed reproduces the cmaes trajectory exactly
    ag = agents.make_agent("cmaes", knobs=KNOBS, batch=8, seed=0)
    traj = agents.run_agent(ag, _panel(), budget=24,
                            evaluator=agents.PanelEvaluator(_panel(), **KW))
    assert traj.as_dict()["best"] == rep["agents"]["cmaes"]["best"]
    assert traj.best_label == rep["agents"]["cmaes"]["best_label"]
