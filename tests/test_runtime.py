"""Runtime integration tests: end-to-end training loop with checkpoint
restart, failure recovery, straggler detection, microbatching equivalence,
and the batched server."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh, rules_for
from repro.models.api import build_model
from repro.optim.adamw import OptConfig
from repro.runtime import fault
from repro.runtime.serve import BatchedServer
from repro.runtime.train_loop import (TrainConfig, Trainer,
                                      make_microbatched_train_step)


def _arch(name="yi-6b"):
    return dataclasses.replace(get_config(name).reduced(),
                               capacity_factor=8.0)


def _tc(**kw):
    base = dict(total_steps=20, ckpt_every=5, log_every=100,
                opt=OptConfig(lr=2e-3, warmup_steps=2, decay_steps=1000))
    base.update(kw)
    return TrainConfig(**base)


def test_train_loss_decreases():
    t = Trainer(_arch(), _tc(total_steps=30))
    out = t.run()
    assert out["steps_run"] == 30
    assert out["final_loss"] < out["first_loss"] - 0.3, out


def test_checkpoint_restart_resumes(tmp_path):
    root = str(tmp_path / "ckpt")
    t1 = Trainer(_arch(), _tc(total_steps=10, ckpt_dir=root, ckpt_every=5))
    out1 = t1.run()
    # a fresh trainer resumes from step 10 and runs only the remainder
    t2 = Trainer(_arch(), _tc(total_steps=15, ckpt_dir=root, ckpt_every=5))
    out2 = t2.run()
    assert out2["steps_run"] == 5
    assert out2["log"][0]["step"] == 10
    # loss continuity: the resumed loss is near where the first run ended
    assert abs(out2["first_loss"] - out1["final_loss"]) < 0.5


def test_failure_recovery(tmp_path):
    root = str(tmp_path / "ckpt")
    inj = fault.FailureInjector(fail_at=(7, 13))
    t = Trainer(_arch(), _tc(total_steps=20, ckpt_dir=root, ckpt_every=5),
                failure_injector=inj)
    out = t.run()
    assert inj.failures == 2
    assert out["restarts"] == 2
    # every step up to total ran (some twice, replayed from checkpoints)
    assert out["log"][-1]["step"] == 19
    assert out["final_loss"] < out["first_loss"]


def test_microbatching_matches_full_batch():
    """grad-accumulation over 4 microbatches == one full-batch step."""
    cfg = _arch("phi3-mini-3.8b")
    mesh = make_host_mesh()
    rules = rules_for(cfg, mesh)
    model = build_model(cfg, rules, mesh)
    from repro.launch.steps import init_train_state
    from repro.optim.adamw import get_optimizer

    opt = get_optimizer("adamw", OptConfig(lr=1e-3, warmup_steps=1))
    rng = jax.random.PRNGKey(0)
    state = init_train_state(model, opt, rng)
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=8, seed=1))
    batch = jax.tree.map(jnp.asarray, ds.batch_at(0))
    with jax.set_mesh(mesh):
        s1 = jax.jit(make_microbatched_train_step(model, opt, 1))
        s4 = jax.jit(make_microbatched_train_step(model, opt, 4))
        out1, m1 = s1(jax.tree.map(jnp.copy, state), batch)
        out4, m4 = s4(jax.tree.map(jnp.copy, state), batch)
    assert abs(float(m1["total_loss"]) - float(m4["total_loss"])) < 1e-4
    diff = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        out1["params"], out4["params"])
    assert max(jax.tree.leaves(diff)) < 5e-3


def test_straggler_monitor():
    times = iter([0.0, 1.0,    # step 0: 1s
                  1.0, 2.0,    # step 1: 1s
                  2.0, 12.0,   # step 2: 10s <- straggler
                  12.0, 22.0,  # step 3: 10s
                  22.0, 32.0])  # step 4: 10s -> trips
    mon = fault.StepMonitor(threshold=3.0, trip_after=3,
                            clock=lambda: next(times))
    flags = []
    for s in range(5):
        mon.start_step()
        flags.append(mon.end_step(s).flagged)
    assert flags == [False, False, True, True, True]
    assert mon.tripped


def test_elastic_plan():
    assert fault.elastic_plan(512, 16) == (32, 16)
    assert fault.elastic_plan(500, 16) == (31, 16)
    with pytest.raises(ValueError):
        fault.elastic_plan(8, 16)


def test_monitor_reset_rebaselines_after_legit_rescale():
    """Flagged steps never feed the EMA, so after a rescale to a
    legitimately slower steady state the monitor used to stay tripped
    forever against the stale baseline. reset(rebaseline=True) re-seeds
    the EMA from the recent (slow) history and the monitor accepts the
    new steady state; without reset it keeps flagging."""
    durs = [1.0] * 4 + [10.0] * 8  # rescale at step 4: 10x slower forever
    t, clk = [0.0], (lambda: t[0])
    mon = fault.StepMonitor(threshold=2.5, trip_after=3, clock=clk)
    tripped_at = None
    for s, d in enumerate(durs):
        mon.start_step()
        t[0] += d
        st = mon.end_step(s)
        if mon.tripped and tripped_at is None:
            tripped_at = s
            assert st.flagged
            mon.reset(rebaseline=True, window=3)
    assert tripped_at == 6  # 3 consecutive 10s steps vs the 1s EMA
    # post-reset: the EMA is the new 10s baseline, no step flags again
    assert not mon.tripped
    assert not any(st.flagged for st in mon.history[tripped_at + 1:])
    assert mon.ema_s == pytest.approx(10.0)


def test_monitor_reset_cold_start():
    t, clk = [0.0], (lambda: t[0])
    mon = fault.StepMonitor(threshold=2.0, trip_after=1, clock=clk)
    for s, d in enumerate([1.0, 5.0]):
        mon.start_step()
        t[0] += d
        mon.end_step(s)
    assert mon.tripped
    mon.reset(rebaseline=False)
    assert mon.ema_s is None and not mon.tripped
    # first step after a cold reset seeds the EMA like a fresh monitor
    mon.start_step()
    t[0] += 7.0
    assert not mon.end_step(2).flagged
    assert mon.ema_s == pytest.approx(7.0)


def test_restart_policy_denied_calls_do_not_burn_budget():
    pol = fault.RestartPolicy(max_restarts=2)
    assert pol.should_restart() and pol.should_restart()
    assert pol.restarts == 2
    # exhausted: probing the policy again must not mutate the counter
    for _ in range(5):
        assert not pol.should_restart()
    assert pol.restarts == 2


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def server():
    cfg = _arch("yi-6b")
    mesh = make_host_mesh()
    rules = rules_for(cfg, mesh)
    model = build_model(cfg, rules, mesh)
    params = model.init(jax.random.PRNGKey(0))
    return BatchedServer(model, params, max_batch=4, max_seq=64)


def test_serve_greedy_deterministic(server):
    p = np.arange(1, 9, dtype=np.int32)
    server.submit(p, max_new_tokens=8)
    server.submit(p, max_new_tokens=8)
    server.run_until_drained()
    a, b = server.done[-2], server.done[-1]
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert a.finish_reason == "length"
    assert len(a.tokens) == 8


def test_serve_batch_equals_solo(server):
    """A request's greedy output must not depend on its batch companions
    (same prompt length -> no padding interference)."""
    p1 = np.arange(1, 9, dtype=np.int32)
    p2 = np.arange(20, 28, dtype=np.int32)
    server.submit(p1, max_new_tokens=6)
    server.run_until_drained()
    solo = server.done[-1].tokens.copy()
    server.submit(p1, max_new_tokens=6)
    server.submit(p2, max_new_tokens=6)
    server.run_until_drained()
    batched = next(r for r in server.done[-2:]
                   if np.array_equal(r.prompt, p1)).tokens
    np.testing.assert_array_equal(solo, batched)


def test_serve_throughput_counters(server):
    n0 = server.stats.requests_done
    for _ in range(6):  # > max_batch forces multiple waves
        server.submit(np.arange(4, dtype=np.int32), max_new_tokens=4)
    server.run_until_drained()
    assert server.stats.requests_done == n0 + 6
    assert server.stats.tokens_per_s > 0
