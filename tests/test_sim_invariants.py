"""Property-based simulator invariants under randomized programs and
geometries (hypothesis, or the deterministic stub in conftest.py):

* queues stay within [0, qmax] and the sink queue stays empty,
* per-link served rate never exceeds the effective capacity (FIFO fluid
  sharing caps every stage at caps_eff),
* NIC injection never exceeds the source's host link capacity,
* per-job phase counters advance monotonically (0 or +1 mod n_phases)
  and completed-iteration counters never decrease,
* total delivered bytes equal the program's wire bytes at completion
  (up to one dt of discretization overshoot per phase),
* program padding (traffic.pad_program via build_program_flowset
  pad_to=...) is inert: bit-identical outputs through the full engine.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import congestion as cong, traffic
from repro.core.fabric import cc as cc_lib, simulator as sim
from repro.core.fabric import topology as topo_lib
from repro.core.fabric.routing import N_POLICIES

FAMILIES = sorted(topo_lib.FAMILIES)
COLLECTIVES = ("ring_allgather", "ring_allreduce", "alltoall", "incast")
CCS = {"dcqcn": cc_lib.dcqcn, "ib": lambda: cc_lib.infiniband("hdr"),
       "slingshot": cc_lib.slingshot, "ai_ecn": cc_lib.ai_ecn}

_step_debug = jax.jit(sim.step_debug)


def _build(family, n_nodes, coll, cc_name, policy, vector_bytes,
           aggr="incast"):
    topo = topo_lib.make_family(family, n_nodes)
    vidx, aidx = cong.interleaved_split(n_nodes)
    nodes = np.arange(n_nodes)
    flows = cong.build_flowset(topo, nodes[vidx], nodes[aidx], coll, aggr,
                               vector_bytes, phased=True)
    cc = CCS[cc_name]()
    geom = sim.make_geometry(topo, flows)
    dt = 2e-6
    params = sim.make_params(cc, dt=dt, bytes_per_iter=flows.bytes_per_iter,
                             host_caps=flows.host_caps,
                             env=cong.steady().params(),
                             policy=policy, flowlet_gap_s=100e-6)
    return topo, flows, geom, params


@settings(max_examples=8, deadline=None)
@given(family=st.sampled_from(FAMILIES),
       n_nodes=st.integers(4, 12),
       coll=st.sampled_from(COLLECTIVES),
       cc_name=st.sampled_from(sorted(CCS)),
       policy=st.sampled_from(list(range(N_POLICIES))),
       vector_bytes=st.floats(64 * 1024, 16 * 1024 * 1024))
def test_step_invariants(family, n_nodes, coll, cc_name, policy,
                         vector_bytes):
    """Queues bounded, service capped by capacity, injection capped by
    the NIC, phase/iteration counters monotone — at every step, under
    every traced routing policy (incl. flowlet re-pathing)."""
    topo, flows, geom, params = _build(family, n_nodes, coll, cc_name,
                                       policy, vector_bytes)
    qmax = float(params.qmax_bytes)
    state = sim.init_state(geom, params)
    # max host-link rate per source id (pad-safe: sources with no flows
    # never appear in src_id)
    src_cap = np.zeros(geom.n_src)
    np.maximum.at(src_cap, np.asarray(geom.src_id),
                  np.asarray(params.host_caps))
    prev_ph = np.asarray(state["ph"]).copy()
    prev_it = np.asarray(state["it"]).copy()
    prev_t = float(state["t"])
    n_phases = np.asarray(geom.n_phases)
    for _ in range(150):
        state, _, aux = _step_debug(geom, params, state)
        q = np.asarray(state["q"])
        assert (q >= 0.0).all() and (q <= qmax * (1 + 1e-5)).all()
        assert q[geom.L] == 0.0
        served = np.asarray(aux["served_stage_max"])
        caps_eff = np.asarray(aux["caps_eff"])
        assert (served[: geom.L]
                <= caps_eff[: geom.L] * (1 + 1e-3) + 1.0).all()
        inj = np.asarray(aux["inject"])
        assert (inj >= -1e-6).all()
        src_load = np.zeros(geom.n_src)
        np.add.at(src_load, np.asarray(geom.src_id), inj)
        assert (src_load <= src_cap * (1 + 1e-3) + 1.0).all()
        # end-to-end achieved rate can only shrink along the path
        assert (np.asarray(aux["achieved"]) <= inj * (1 + 1e-5) + 1.0).all()
        ph, it = np.asarray(state["ph"]), np.asarray(state["it"])
        step_fwd = (ph - prev_ph) % np.maximum(n_phases, 1)
        assert np.isin(step_fwd, (0, 1)).all(), (prev_ph, ph)
        assert (it >= prev_it).all()
        assert float(state["t"]) > prev_t
        prev_ph, prev_it, prev_t = ph.copy(), it.copy(), float(state["t"])


@settings(max_examples=6, deadline=None)
@given(family=st.sampled_from(FAMILIES),
       n_nodes=st.integers(4, 10),
       coll=st.sampled_from(COLLECTIVES),
       vector_bytes=st.floats(256 * 1024, 8 * 1024 * 1024))
def test_delivered_bytes_match_program(family, n_nodes, coll, vector_bytes):
    """Run one full program iteration of a phased single-job victim (no
    aggressor): the time-integral of achieved rates must equal the
    program's total wire bytes, within one dt of overshoot per phase
    boundary per flow."""
    topo = topo_lib.make_family(family, n_nodes)
    nodes = np.arange(n_nodes)
    flows = cong.build_flowset(topo, nodes, [], coll, "", vector_bytes,
                               phased=True)
    geom = sim.make_geometry(topo, flows)
    dt = 1e-6
    params = sim.make_params(cc_lib.slingshot(), dt=dt,
                             bytes_per_iter=flows.bytes_per_iter,
                             host_caps=flows.host_caps,
                             env=cong.no_congestion().params())
    state = sim.init_state(geom, params)

    @jax.jit
    def scan_block(state):
        def body(carry, _):
            s, acc = carry
            s2, _, aux = sim.step_debug(geom, params, s)
            # accumulate only while the first program iteration is open
            # (the completing step itself still counts)
            live = s["it"][0] == 0
            acc = acc + jnp.where(live, jnp.sum(aux["achieved"]), 0.0)
            return (s2, acc), None
        (state2, acc), _ = jax.lax.scan(body, (state, jnp.float32(0.0)),
                                        None, length=200)
        return state2, acc

    delivered = 0.0
    for _ in range(100):  # <= 20k steps
        state, acc = scan_block(state)
        delivered += float(acc) * dt
        if int(np.asarray(state["it"])[0]) >= 1:
            break
    else:
        raise AssertionError("program did not complete in 20k steps")
    # expected: every flow row delivers its bytes once per phase it is a
    # member of (wildcard rows re-arm each phase)
    mult = np.where(np.asarray(flows.flow_phase) < 0,
                    np.asarray(flows.n_phases)[flows.flow_job], 1)
    expected = float(np.sum(flows.bytes_per_iter * mult))
    overshoot = float(np.sum(flows.host_caps * mult)) * dt
    assert delivered >= expected * (1 - 1e-3) - 1.0
    assert delivered <= expected + overshoot + 1.0, \
        (delivered, expected, overshoot)


@settings(max_examples=4, deadline=None)
@given(family=st.sampled_from(FAMILIES),
       n_nodes=st.integers(4, 10),
       coll=st.sampled_from(COLLECTIVES),
       extra_flows=st.integers(1, 40),
       extra_jobs=st.integers(1, 3))
def test_program_padding_inert(family, n_nodes, coll, extra_flows,
                               extra_jobs):
    """build_program_flowset(pad_to=...) — the program-level padding the
    geometry buckets ride on — must not perturb the engine at all."""
    topo = topo_lib.make_family(family, n_nodes)
    vidx, aidx = cong.interleaved_split(n_nodes)
    nodes = np.arange(n_nodes)
    jobs = [traffic.JobSpec("victim", coll, 1 << 20,
                            nodes=tuple(nodes[vidx]), phased=True),
            traffic.JobSpec("aggressor", "incast",
                            nodes=tuple(nodes[aidx]), endless=True,
                            envelope_gated=True, sweep_bytes=False)]
    flows0 = cong.build_program_flowset(topo, jobs)
    pad_to = (flows0.n_flows + extra_flows, flows0.n_jobs + extra_jobs,
              int(np.max(flows0.n_phases)) + 1)
    flows1 = cong.build_program_flowset(topo, jobs, pad_to=pad_to)
    assert flows1.n_flows == pad_to[0] and flows1.n_jobs == pad_to[1]

    outs = []
    for flows in (flows0, flows1):
        geom = sim.make_geometry(topo, flows)
        params = sim.make_params(
            cc_lib.infiniband("hdr"), dt=2e-6,
            bytes_per_iter=flows.bytes_per_iter,
            host_caps=flows.host_caps, env=cong.steady().params())
        out = sim.run_cell(geom, params, jnp.asarray(5, jnp.int32),
                           chunk=256, max_chunks=30, stride=8)
        outs.append({k: np.asarray(v) for k, v in out.items()})
    for k in ("t_done", "it", "qd_acc", "t", "trace", "chunks"):
        a0, a1 = outs[0][k], outs[1][k]
        if k in ("t_done", "it"):
            a1 = a1[: a0.shape[0]]
        assert np.array_equal(a0, a1), k


def test_pad_program_validates_prefix_exactly():
    """check_program on a padded program still validates the real jobs
    exactly (padding rows are invisible to the wire-byte model)."""
    jobs = (traffic.JobSpec("j", "ring_allreduce", 1 << 20,
                            nodes=tuple(range(6)), phased=True),)
    prog = traffic.compile_programs(jobs)
    padded = traffic.pad_program(prog, n_flows=prog.n_flows + 9,
                                 n_jobs=len(prog.n_phases) + 1,
                                 n_phases=int(prog.phase_gap.shape[1]) + 2)
    traffic.check_program(padded)  # must not raise
    # and a corrupted prefix must still be caught
    padded.bytes_per_phase[0] *= 2.0
    try:
        traffic.check_program(padded)
    except ValueError:
        pass
    else:
        raise AssertionError("corrupted prefix passed validation")


def test_pad_program_rejects_shrinking_and_orphan_flows():
    jobs = (traffic.JobSpec("j", "ring_allgather", 1 << 20,
                            nodes=tuple(range(4))),)
    prog = traffic.compile_programs(jobs)
    np_flows = prog.n_flows
    try:
        traffic.pad_program(prog, n_flows=np_flows - 1, n_jobs=2,
                            n_phases=1)
    except ValueError:
        pass
    else:
        raise AssertionError("shrinking accepted")
    try:
        traffic.pad_program(prog, n_flows=np_flows + 4, n_jobs=1,
                            n_phases=1)
    except ValueError:
        pass
    else:
        raise AssertionError("orphan pad flows accepted")
