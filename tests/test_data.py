"""Data pipeline tests: determinism, host sharding, learnable structure."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data.pipeline import (DataConfig, SyntheticLM, TokenFileDataset,
                                 write_token_file)


def _cfg(**kw):
    base = dict(vocab_size=256, seq_len=32, global_batch=8, seed=3)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_per_step():
    ds1, ds2 = SyntheticLM(_cfg()), SyntheticLM(_cfg())
    for step in (0, 1, 17, 1000):
        b1, b2 = ds1.batch_at(step), ds2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different steps -> different data
    assert not np.array_equal(ds1.batch_at(0)["tokens"],
                              ds1.batch_at(1)["tokens"])


def test_labels_are_shifted_tokens():
    b = SyntheticLM(_cfg()).batch_at(5)
    # label[t] is the next token after tokens[t]: check via re-generation of
    # the same rows at seq_len+... simpler: label[:-1] == tokens[1:]
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_host_sharding_partitions_global_batch():
    full = SyntheticLM(_cfg(n_hosts=1, host_id=0)).batch_at(7)["tokens"]
    parts = [SyntheticLM(_cfg(n_hosts=4, host_id=h)).batch_at(7)["tokens"]
             for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_tokens_in_vocab_range():
    b = SyntheticLM(_cfg(vocab_size=100)).batch_at(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def test_structure_is_learnable():
    """The order-1 pattern must make next-token frequencies non-uniform
    (otherwise the training-loss assertions downstream are meaningless)."""
    ds = SyntheticLM(_cfg(structure=0.9, vocab_size=64,
                          global_batch=64, seq_len=64))
    b = ds.batch_at(0)
    # count matches of the grammar successor
    succ = ds._succ
    hit = (b["labels"] == succ[b["tokens"]]).mean()
    assert hit > 0.7, hit  # ~= structure fraction


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10_000), hosts=st.sampled_from([1, 2, 4, 8]))
def test_sharding_property(step, hosts):
    full = SyntheticLM(_cfg(n_hosts=1)).batch_at(step)["tokens"]
    parts = [SyntheticLM(_cfg(n_hosts=hosts, host_id=h)).batch_at(step)
             ["tokens"] for h in range(hosts)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_token_file_dataset(tmp_path):
    path = str(tmp_path / "tokens.bin")
    rng = np.random.RandomState(0)
    write_token_file(path, rng.randint(0, 1000, size=(10_000,)))
    ds = TokenFileDataset(path, _cfg(vocab_size=1000))
    b1, b2 = ds.batch_at(3), ds.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert b1["tokens"].shape == (8, 32)
