"""Paper Fig. 1: time distribution of a custom ring AllReduce vs AlltoAll.

The paper's finding: Open MPI AllReduce loses up to 25% bandwidth vs
AlltoAll, and a custom ring AllReduce (ReduceScatter + AllGather) shows the
gap is dominated by *reduction costs and memory handling* (buffer setup +
memcpy), not network — which motivates excluding computation collectives
from the congestion study (§III-B).

Reproduction: measure the per-iteration on-device costs of the ring
AllReduce's compute phases (XLA-jitted accumulate = reduction; buffer copy
= memcpy) and compare with the simulated wire time of the same vector on
the HAICGU EDR fabric. Also reports the fused-kernel (Pallas
fused_accumulate) cost as the optimized variant — the TPU answer to the
paper's observed overhead (DESIGN.md §9).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cached_sweep, size_label
from repro.core import bench, congestion as cong
from repro.core.collectives import wire_bytes_model
from repro.core.fabric import systems

N_NODES = 8


def _time(fn, *args, iters=20) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run_size(vector_bytes: float) -> dict:
    n = N_NODES
    d = int(vector_bytes) // 4
    chunk = jnp.zeros((max(d // n, 1),), jnp.float32)
    recv = jnp.ones_like(chunk)

    add = jax.jit(lambda a, b: a + b)
    copy = jax.jit(lambda a: a + 0.0)  # XLA buffer copy

    t_add = _time(add, chunk, recv) * (n - 1)      # RS accumulate steps
    t_copy = _time(copy, chunk) * 2 * (n - 1)      # send/recv staging
    # fused receive-accumulate (Pallas kernel, interpret on CPU)
    from repro.kernels import ops
    rows = max(d // n // 512, 1)
    acc2 = jnp.zeros((rows, 512), jnp.float32)
    t_fused = _time(lambda a, b: ops.fused_accumulate(a, b), acc2,
                    jnp.ones_like(acc2)) * (n - 1)

    # simulated network time (uncongested EDR, same nodes as the paper)
    sysp = systems.get_system("haicgu_ib")
    res = bench.run_point(sysp, n, "ring_allreduce", "", vector_bytes,
                          cong.no_congestion(), n_iters=15, warmup=3)
    t_net = res.t_uncongested_s

    total = t_add + t_copy + t_net
    return {
        "t_reduce_us": t_add * 1e6,
        "t_memcpy_us": t_copy * 1e6,
        "t_network_us": t_net * 1e6,
        "t_fused_reduce_us": t_fused * 1e6,
        "compute_fraction": (t_add + t_copy) / total,
        "wire_bytes": wire_bytes_model("ring_all_reduce", n, vector_bytes)
        ["bytes"],
    }


def main(force: bool = False, quick: bool = False):
    from repro.core import scenarios
    points = scenarios.get("fig1_breakdown", quick).points
    rows = cached_sweep("fig1_breakdown", ["vector_bytes"],
                        list(points), run_size, force=force)
    print("\n# Fig. 1 — ring AllReduce cost breakdown "
          f"({N_NODES} nodes, EDR sim + on-device compute)")
    print(f"{'size':>8} {'reduce_us':>11} {'memcpy_us':>11} "
          f"{'network_us':>11} {'fused_us':>10} {'compute%':>9}")
    for r in rows:
        print(f"{size_label(r['vector_bytes']):>8} "
              f"{float(r['t_reduce_us']):>11.0f} "
              f"{float(r['t_memcpy_us']):>11.0f} "
              f"{float(r['t_network_us']):>11.0f} "
              f"{float(r['t_fused_reduce_us']):>10.0f} "
              f"{100 * float(r['compute_fraction']):>8.1f}%")
    return rows


if __name__ == "__main__":
    main()
