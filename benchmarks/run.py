"""Benchmark orchestrator — one reproduction per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--force]``

Prints each figure's table plus a final ``name,us_per_call,derived`` CSV
summary line per benchmark point (derived = the figure's key metric).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="reduced grids (smoke)")
    p.add_argument("--force", action="store_true",
                   help="ignore the sweep cache")
    p.add_argument("--only", default="",
                   help="comma-separated subset, e.g. fig4,fig5")
    args = p.parse_args()

    from benchmarks import (collective_bench, fig1_breakdown, fig3_sawtooth,
                            fig4_nslb, fig5_steady, fig6_bursty,
                            fig7_fig8_scale, new_scenarios)

    benches = {
        "fig1": lambda: fig1_breakdown.main(force=args.force,
                                            quick=args.quick),
        "fig3": lambda: fig3_sawtooth.main(force=args.force,
                                           quick=args.quick),
        "fig4": lambda: fig4_nslb.main(force=args.force, quick=args.quick),
        "fig5": lambda: fig5_steady.main(force=args.force, quick=args.quick),
        "fig6": lambda: fig6_bursty.main(force=args.force, quick=args.quick),
        "fig7_fig8": lambda: fig7_fig8_scale.main(force=args.force,
                                                  quick=args.quick),
        "scenarios": lambda: new_scenarios.main(force=args.force,
                                                quick=args.quick),
        "collectives": lambda: collective_bench.main(force=args.force),
    }
    only = {s for s in args.only.split(",") if s}
    summary = []
    failed = []
    for name, fn in benches.items():
        if only and name not in only:
            continue
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}", flush=True)
        t0 = time.time()
        try:
            rows = fn() or []
        except Exception:
            traceback.print_exc()
            failed.append(name)
            continue
        dt = time.time() - t0
        print(f"[{name}] {len(rows)} points in {dt:.0f}s", flush=True)
        for r in rows:
            us = (r.get("us_per_call") or r.get("t_congested_us")
                  or r.get("t_network_us") or "")
            derived = (r.get("ratio") or r.get("cv")
                       or r.get("compute_fraction")
                       or r.get("gbps_congested") or "")
            key = ":".join(str(r.get(k, "")) for k in
                           ("system", "mode", "collective", "aggressor",
                            "n_nodes", "vector_bytes", "size", "burst_ms",
                            "pause_ms") if r.get(k))
            summary.append(f"{name}[{key}],{us},{derived}")

    print("\n# name,us_per_call,derived")
    for line in summary:
        print(line)
    if failed:
        print(f"\n[run] FAILED benches: {failed}", file=sys.stderr)
        sys.exit(1)
    print(f"\n[run] all benches complete ({len(summary)} points)")


if __name__ == "__main__":
    main()
