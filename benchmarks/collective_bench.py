"""§III-B microbenchmark: wall-clock cost of the custom collective
schedules (ring AllGather, bidir ring, linear/pairwise AlltoAll, ring
AllReduce, incast) on an 8-device host mesh.

jax pins the device count at first init, and benches must see 1 device in
this process (the brief); the timing therefore runs in one subprocess with
``--xla_force_host_platform_device_count=8``, exactly like the multi-device
tests.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import cached_sweep, size_label

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys, time
import jax, jax.numpy as jnp
import numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.core import collectives as C
from repro.launch.mesh import compat_make_mesh

mesh = compat_make_mesh((8,), ("x",))
n = 8
out = []

def timeit(fn, x, iters=30):
    y = fn(x); jax.block_until_ready(y)
    t0 = time.perf_counter()
    for _ in range(iters):
        y = fn(x)
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / iters * 1e6

for size in json.loads(sys.argv[1]):
    d = max(size // 4 // n, 8)
    x = jnp.zeros((n * d,), jnp.float32)
    xa = jnp.zeros((n, d), jnp.float32)
    sm = lambda f, in_s, out_s: jax.jit(jax.shard_map(
        f, mesh=mesh, in_specs=in_s, out_specs=out_s, check_vma=False))
    cases = {
        "ring_all_gather": sm(lambda v: C.ring_all_gather(v, "x", n),
                              P("x"), P(None)),
        "bidir_ring_all_gather": sm(
            lambda v: C.ring_all_gather(v, "x", n, bidirectional=True),
            P("x"), P(None)),
        "xla_all_gather": sm(lambda v: jax.lax.all_gather(v, "x"),
                             P("x"), P(None)),
    }
    for name, fn in cases.items():
        out.append({"collective": name, "size": size,
                    "us_per_call": timeit(fn, x)})
    cases2 = {
        "ring_all_reduce": sm(lambda v: C.ring_all_reduce(v[0], "x", n),
                              P("x"), P(None)),
        "xla_all_reduce": sm(lambda v: jax.lax.psum(v[0], "x"),
                             P("x"), P(None)),
        "linear_all_to_all": sm(lambda v: C.linear_all_to_all(v[0], "x", n),
                                P("x"), P("x")),
        "pairwise_all_to_all": sm(
            lambda v: C.pairwise_all_to_all(v[0], "x", n), P("x"), P("x")),
        "incast_gather": sm(lambda v: C.incast_gather(v[0], "x", n),
                            P("x"), P("x")),
    }
    xb = jnp.zeros((n, n, max(d // n, 1)), jnp.float32)
    for name, fn in cases2.items():
        out.append({"collective": name, "size": size,
                    "us_per_call": timeit(fn, xb)})
print("REPORT" + json.dumps(out))
"""


def run_all(sizes) -> list:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT, json.dumps(sizes)],
                       env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("REPORT")][-1]
    return json.loads(line[len("REPORT"):])


def main(force: bool = False):
    from repro.core import scenarios

    sizes = list(scenarios.get("collective_microbench").microbench_sizes)
    cache_points = [(s,) for s in sizes]

    def run_size(size):
        rows = run_all([size])
        return {r["collective"]: round(r["us_per_call"], 1) for r in rows}

    rows = cached_sweep("collective_bench", ["size"], cache_points, run_size,
                        force=force)
    print("\n# §III-B — custom collective schedules, 8 host devices "
          "(us/call)")
    colls = [k for k in rows[0] if k != "size"]
    print(f"{'size':>8} " + " ".join(f"{c:>22}" for c in colls))
    for r in rows:
        print(f"{size_label(r['size']):>8} "
              + " ".join(f"{float(r[c]):>22.1f}" for c in colls))
    return rows


if __name__ == "__main__":
    main()
