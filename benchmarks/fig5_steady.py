"""Paper Fig. 5 / Obs. 2: steady congestion at scale — ratio heatmaps
(nodes x vector size) per system x aggressor, AllGather victim.

Routed through the scenario registry: each (system, aggressor, nodes) grid
runs as ONE batched bench.run_grid call over its vector sizes."""
from __future__ import annotations

import argparse

from benchmarks.common import heatmap, scenario_rows, size_label
from repro.core import scenarios

SYSTEMS = scenarios.FIG5_SYSTEMS
AGGRESSORS = scenarios.FIG5_AGGRESSORS


def main(force: bool = False, quick: bool = False):
    rows = scenario_rows(scenarios.get("fig5_steady", quick), force=force)
    for s in SYSTEMS:
        for a in AGGRESSORS:
            sub = [r for r in rows
                   if r["system"] == s and r["aggressor"] == a]
            if not sub:
                continue
            for r in sub:
                r["size"] = size_label(r["vector_bytes"])
            print(f"\n# Fig. 5 — {s}, {a} aggressor "
                  "(uncongested/congested ratio; higher is better)")
            print(heatmap(sub, x="n_nodes", y="size", val="ratio"))
    # Obs. 2 summary checks
    get = lambda s, a: min(float(r["ratio"]) for r in rows
                           if r["system"] == s and r["aggressor"] == a)
    print("\n# Obs.2 checks (worst cell per system x aggressor):")
    print(f"#  lumi     a2a {get('lumi', 'alltoall'):.2f} / "
          f"incast {get('lumi', 'incast'):.2f}   (paper: ~1.0 both)")
    print(f"#  leonardo a2a {get('leonardo', 'alltoall'):.2f} / "
          f"incast {get('leonardo', 'incast'):.2f}   (paper: >=0.82 / ~0.2)")
    print(f"#  cresco8  a2a {get('cresco8', 'alltoall'):.2f} / "
          f"incast {get('cresco8', 'incast'):.2f}   (paper: ~0.45 / ~0.6)")
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--force", action="store_true")
    p.add_argument("--quick", action="store_true")
    a = p.parse_args()
    main(force=a.force, quick=a.quick)
