"""Paper Fig. 5 / Obs. 2: steady congestion at scale — ratio heatmaps
(nodes x vector size) per system x aggressor, AllGather victim."""
from __future__ import annotations

import argparse

from benchmarks.common import cached_sweep, heatmap, size_label
from repro.core import bench, congestion as cong
from repro.core.fabric import systems

SYSTEMS = ("cresco8", "leonardo", "lumi")
AGGRESSORS = ("alltoall", "incast")
NODES = (16, 32, 64, 128, 256)
SIZES = (512, 32 * 2 ** 10, 2 * 2 ** 20, 16 * 2 ** 20)


def run_point(system: str, aggr: str, n_nodes: int,
              vector_bytes: float) -> dict:
    r = bench.run_point(systems.get_system(system), int(n_nodes),
                        "ring_allgather", aggr, float(vector_bytes),
                        cong.steady(), n_iters=25, warmup=5)
    return {"ratio": round(r.ratio, 4),
            "t_uncongested_us": round(r.t_uncongested_s * 1e6, 1),
            "t_congested_us": round(r.t_congested_s * 1e6, 1)}


def main(force: bool = False, quick: bool = False):
    nodes = (16, 64, 256) if quick else NODES
    sizes = (32 * 2 ** 10, 2 * 2 ** 20) if quick else SIZES
    points = [(s, a, n, v) for s in SYSTEMS for a in AGGRESSORS
              for n in nodes for v in sizes]
    rows = cached_sweep("fig5_steady",
                        ["system", "aggressor", "n_nodes", "vector_bytes"],
                        points, run_point, force=force)
    for s in SYSTEMS:
        for a in AGGRESSORS:
            sub = [r for r in rows
                   if r["system"] == s and r["aggressor"] == a]
            if not sub:
                continue
            for r in sub:
                r["size"] = size_label(r["vector_bytes"])
            print(f"\n# Fig. 5 — {s}, {a} aggressor "
                  "(uncongested/congested ratio; higher is better)")
            print(heatmap(sub, x="n_nodes", y="size", val="ratio"))
    # Obs. 2 summary checks
    get = lambda s, a: min(float(r["ratio"]) for r in rows
                           if r["system"] == s and r["aggressor"] == a)
    print("\n# Obs.2 checks (worst cell per system x aggressor):")
    print(f"#  lumi     a2a {get('lumi', 'alltoall'):.2f} / "
          f"incast {get('lumi', 'incast'):.2f}   (paper: ~1.0 both)")
    print(f"#  leonardo a2a {get('leonardo', 'alltoall'):.2f} / "
          f"incast {get('leonardo', 'incast'):.2f}   (paper: >=0.82 / ~0.2)")
    print(f"#  cresco8  a2a {get('cresco8', 'alltoall'):.2f} / "
          f"incast {get('cresco8', 'incast'):.2f}   (paper: ~0.45 / ~0.6)")
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--force", action="store_true")
    p.add_argument("--quick", action="store_true")
    a = p.parse_args()
    main(force=a.force, quick=a.quick)
