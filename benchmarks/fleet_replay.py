"""Fleet-scale stochastic workload replay throughput (ISSUE 8).

Replays seeded stochastic workloads (core/workload.py: Poisson short
flows + long-lived training tenants with per-tenant CC mixes) as batched
seed sweeps through the hetero engine, with streaming percentile metrics
(p50/p99/p99.9 queue delay, FCT CDFs, per-tenant slowdown) accumulated
inside the scan — no per-step trace is ever materialized.

Per seed-count it measures, over ALL systems stacked into one geometry
bucket (one compile, asserted via TRACE_COUNTS):

* ``seeds_per_sec`` and ``sim_s_per_wall_s`` — replay throughput: how
  many seeds (and simulated fabric-seconds) one wall-second buys.
* ``metrics_overhead`` — wall-time ratio of the metrics-on run vs the
  metrics-off run of the same batch (both traceless); the streaming
  accumulators must stay cheap next to the step core.

Sanity gates (fail the run, exit 1): p99 >= p50 on the aggregate queue
delay, short flows complete (FCT samples > 0), per-flow delivered bytes
respect the NIC capacity bound, and shorts never deliver more than the
seed drew for them.

``--check-against BENCH_engine.json`` compares the hardware-normalized
``metrics_overhead`` per seed count against the committed ``"replay"``
rows and fails on > ``--regress-margin`` relative regression (CI smoke).
A plain run (or ``--write``) updates ONLY the ``"replay"`` section of
the artifact, read-modify-write, so engine_bench rows are untouched.

Usage:
  PYTHONPATH=src python -m benchmarks.fleet_replay             # full
  PYTHONPATH=src python -m benchmarks.fleet_replay --quick \
      --check-against BENCH_engine.json                        # CI smoke
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import POINT_KEYS, cached_sweep, expected_point_keys
from repro.core import scenarios as scen
from repro.core import workload as wl
from repro.core.fabric import simulator as sim

SEED_COUNTS_FULL = (256, 1024)
CAP_TOL = 1.05  # fp32 accumulation slack on the capacity bound


def _specs(points, quick: bool):
    """One WorkloadSpec per registry point (deduped by system/n_nodes)."""
    seen = {}
    for system, n_nodes, _ in points:
        key = (system, int(n_nodes))
        if key in seen:
            continue
        if quick:
            seen[key] = wl.WorkloadSpec(
                system=system, n_nodes=int(n_nodes), short_slots=16,
                arrivals_mean=8.0, horizon_s=4e-3,
                tenant_bytes=float(1 << 19))
        else:
            seen[key] = wl.WorkloadSpec(system=system, n_nodes=int(n_nodes))
    return list(seen.values())


def _timed_replay(templates, seeds, *, chunk, metrics):
    t0 = time.perf_counter()
    out, padded = wl.run_replay(templates, seeds, chunk=chunk,
                                metrics=metrics, with_trace=False)
    jax.block_until_ready(out)
    first = time.perf_counter() - t0
    t0 = time.perf_counter()
    out, padded = wl.run_replay(templates, seeds, chunk=chunk,
                                metrics=metrics, with_trace=False)
    jax.block_until_ready(out)
    steady = time.perf_counter() - t0
    return out, padded, steady, max(first - steady, 0.0)


def _sanity(out, padded, seeds, summaries):
    """Distribution + conservation gates; returns a list of failures."""
    fails = []
    for k, (t, s) in enumerate(zip(padded, summaries)):
        tag = f"{t.spec.system}/n{t.spec.n_nodes}"
        qd = s["qdelay_s"]
        if not np.isnan(qd["0.99"]) and qd["0.99"] < qd["0.5"]:
            fails.append(f"{tag}: p99 qdelay {qd['0.99']:.3g} < "
                         f"p50 {qd['0.5']:.3g}")
        if s["fct_samples"] <= 0:
            fails.append(f"{tag}: no short-flow completions")
        # capacity bound: no flow delivers more than its NIC could carry
        fb = np.asarray(out["fbytes"])[k]  # (B, F)
        cap = t.host_caps[None, :] * np.asarray(out["t"])[k][:, None]
        if (fb > cap * CAP_TOL + 1.0).any():
            fails.append(f"{tag}: delivered bytes exceed NIC capacity")
        # shorts conservation: delivered <= drawn + one Euler-step
        # quantum (the final step delivers a full rate*dt even when
        # rem < rate*dt)
        params = wl.lower_seeds(t, seeds)
        drawn = np.asarray(params.bytes_per_iter)[:, t.short_idx]
        got = fb[:, t.short_idx]
        quantum = t.host_caps[t.short_idx] * t.dt
        if (got > drawn + quantum[None, :] * CAP_TOL + 1.0).any():
            fails.append(f"{tag}: shorts delivered more than drawn")
    return fails


def run_seed_counts(points, seed_counts, quick: bool, chunk: int):
    templates = [wl.build_template(s) for s in _specs(points, quick)]
    rows = []
    for n_seeds in seed_counts:
        seeds = np.arange(n_seeds, dtype=np.int64)
        t0 = sim.trace_count("run_cells_hetero")
        out, padded, wall_m, compile_m = _timed_replay(
            templates, seeds, chunk=chunk, metrics=True)
        compiles_metrics = sim.trace_count("run_cells_hetero") - t0
        t0 = sim.trace_count("run_cells_hetero")
        _, _, wall_p, _ = _timed_replay(templates, seeds, chunk=chunk,
                                        metrics=False)
        compiles_plain = sim.trace_count("run_cells_hetero") - t0
        summaries = wl.summarize_replay(out, padded)
        sim_s = float(np.asarray(out["t"]).sum())
        overhead = wall_m / max(wall_p, 1e-9)
        fails = _sanity(out, padded, seeds, summaries)
        if compiles_metrics > 1:
            fails.append(f"{n_seeds} seeds: {compiles_metrics} compiles "
                         "for one bucket (expected <= 1)")
        rows.append({
            "n_seeds": n_seeds,
            "n_systems": len(templates),
            "wall_s_metrics": round(wall_m, 4),
            "wall_s_plain": round(wall_p, 4),
            "compile_s": round(compile_m, 3),
            "compiles_metrics": compiles_metrics,
            "compiles_plain": compiles_plain,
            "metrics_overhead": round(overhead, 4),
            "seeds_per_sec": round(n_seeds * len(templates) / wall_m, 2),
            "sim_s_per_wall_s": round(sim_s / wall_m, 3),
            "systems": summaries,
            "failures": fails,
        })
        print(f"  seeds={n_seeds:5d} wall={wall_m:.2f}s "
              f"(plain {wall_p:.2f}s, overhead x{overhead:.3f})  "
              f"{rows[-1]['seeds_per_sec']:.1f} seeds/s  "
              f"{rows[-1]['sim_s_per_wall_s']:.3g} sim-s/s  "
              f"compiles={compiles_metrics}")
        for s in summaries:
            print(f"    {s['system']:8s} n={s['n_nodes']:3d} "
                  f"qdelay p50={s['qdelay_s']['0.5']:.3g}s "
                  f"p99={s['qdelay_s']['0.99']:.3g}s  "
                  f"fct p99={s['fct_s']['0.99']:.3g}s "
                  f"({s['fct_samples']:.0f} completions)")
        for f in fails:
            print(f"    SANITY FAIL: {f}")
    return rows


def _csv_rows(scenario, rows):
    """Flatten per-system summaries into the registry's CSV cache (keyed
    by POINT_KEYS['fleet_replay']) — batched compute, per-point rows."""
    keys, _ = expected_point_keys(scenario)
    by_sys = {}
    for row in rows:
        for s in row["systems"]:
            by_sys[(s["system"], str(s["n_nodes"]), str(row["n_seeds"]))] = {
                "qdelay_p50_s": s["qdelay_s"]["0.5"],
                "qdelay_p99_s": s["qdelay_s"]["0.99"],
                "fct_p99_s": s["fct_s"]["0.99"],
                "fct_samples": s["fct_samples"],
                "seeds_per_sec": row["seeds_per_sec"],
                "metrics_overhead": row["metrics_overhead"],
            }

    def fn(system, n_nodes, n_seeds):
        return by_sys[(system, str(n_nodes), str(n_seeds))]

    points = [(s, str(n), str(ns)) for (s, n, ns) in scenario.points
              if (s, str(n), str(ns)) in by_sys]
    return cached_sweep("fleet_replay", keys, points, fn, force=True)


def check_against(rows, committed_path, margin):
    """Gate the hardware-normalized metrics_overhead ratio per seed
    count; absolute wall times are machine-dependent and never gated."""
    committed = json.loads(Path(committed_path).read_text())
    old_rows = committed.get("replay", {}).get("seed_counts", [])
    old = {r["n_seeds"]: r["metrics_overhead"] for r in old_rows}
    failures = []
    for r in rows:
        n = r["n_seeds"]
        if n not in old:
            continue
        if r["metrics_overhead"] > old[n] * (1.0 + margin):
            failures.append(
                f"seeds={n}: metrics_overhead {r['metrics_overhead']:.3f} "
                f"> committed {old[n]:.3f} + {margin:.0%}")
        else:
            print(f"  seeds={n}: metrics_overhead "
                  f"{r['metrics_overhead']:.3f} vs committed "
                  f"{old[n]:.3f} — OK")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="few seeds x 2 small systems (CI smoke)")
    ap.add_argument("--chunk", type=int, default=None)
    ap.add_argument("--seed-counts", default=None, metavar="N,N",
                    help="override the seed-count ladder (comma list)")
    ap.add_argument("--out", default="BENCH_engine.json")
    ap.add_argument("--check-against", default=None, metavar="JSON",
                    help="compare metrics_overhead per seed count against "
                    "a committed artifact; fail on regression")
    ap.add_argument("--regress-margin", type=float, default=0.10,
                    help="allowed relative overhead regression "
                    "(default 10%%)")
    ap.add_argument("--write", action="store_true",
                    help="write --out even in --check-against mode")
    args = ap.parse_args(argv)

    scenario = scen.get("fleet_replay", quick=args.quick)
    if args.seed_counts:
        seed_counts = tuple(int(s) for s in args.seed_counts.split(","))
    elif args.quick:
        seed_counts = tuple(sorted({int(ns) for _, _, ns
                                    in scenario.points}))
    else:
        seed_counts = SEED_COUNTS_FULL
    chunk = args.chunk or (512 if args.quick else 2048)
    print(f"fleet_replay: points={scenario.points} "
          f"seed_counts={seed_counts} chunk={chunk} "
          f"backend={jax.default_backend()}")
    t0 = time.time()
    rows = run_seed_counts(scenario.points, seed_counts, args.quick, chunk)
    _csv_rows(scenario, rows)

    replay = {
        "schema": 1,
        "quick": args.quick,
        "jax_backend": jax.default_backend(),
        "point_keys": POINT_KEYS["fleet_replay"],
        "wall_s": round(time.time() - t0, 1),
        "seed_counts": rows,
    }

    failures = [f for r in rows for f in r["failures"]]
    if args.check_against:
        failures += check_against(rows, args.check_against,
                                  args.regress_margin)
    if args.write or not args.check_against:
        # read-modify-write: only the "replay" section is ours
        path = Path(args.out)
        doc = json.loads(path.read_text()) if path.exists() else {}
        doc["replay"] = replay
        path.write_text(json.dumps(doc, indent=1) + "\n")
        print(f"wrote {args.out} (replay section)")
    if failures:
        print("FLEET REPLAY FAILURES:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        return 1
    print("fleet_replay: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
