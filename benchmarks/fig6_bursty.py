"""Paper Fig. 6 / Obs. 3: bursty congestion at 64 nodes — 3x3 heatmaps of
(burst length x inter-burst pause) per system x aggressor x vector size."""
from __future__ import annotations

import argparse

from benchmarks.common import cached_sweep, heatmap, size_label
from repro.core import bench, congestion as cong
from repro.core.fabric import systems

SYSTEMS = ("cresco8", "leonardo", "lumi")
AGGRESSORS = ("alltoall", "incast")
BURSTS_MS = (0.5, 2.0, 8.0)
PAUSES_MS = (0.2, 1.0, 8.0)
SIZES = (512, 32 * 2 ** 10, 2 * 2 ** 20)
N_NODES = 64


def run_point(system: str, aggr: str, vector_bytes: float,
              burst_ms: float, pause_ms: float) -> dict:
    r = bench.run_point(systems.get_system(system), N_NODES,
                        "ring_allgather", aggr, float(vector_bytes),
                        cong.bursty(float(burst_ms) * 1e-3,
                                    float(pause_ms) * 1e-3),
                        n_iters=25, warmup=5)
    return {"ratio": round(r.ratio, 4)}


def main(force: bool = False, quick: bool = False):
    sizes = (32 * 2 ** 10,) if quick else SIZES
    bursts = (0.5, 8.0) if quick else BURSTS_MS
    pauses = (0.2, 8.0) if quick else PAUSES_MS
    points = [(s, a, v, b, p) for s in SYSTEMS for a in AGGRESSORS
              for v in sizes for b in bursts for p in pauses]
    rows = cached_sweep(
        "fig6_bursty",
        ["system", "aggressor", "vector_bytes", "burst_ms", "pause_ms"],
        points, run_point, force=force)
    for s in SYSTEMS:
        for a in AGGRESSORS:
            for v in sizes:
                sub = [r for r in rows if r["system"] == s
                       and r["aggressor"] == a
                       and float(r["vector_bytes"]) == float(v)]
                if not sub:
                    continue
                print(f"\n# Fig. 6 — {s}, {a} aggressor, "
                      f"{size_label(v)} victim AllGather, {N_NODES} nodes "
                      "(rows: burst ms, cols: pause ms)")
                print(heatmap(sub, x="pause_ms", y="burst_ms", val="ratio"))
    # Obs. 3: short pauses hurt more than long pauses. Compared at the
    # SHORTEST burst length — at the longest bursts the duty cycle is
    # >= 50% for every tested pause and the fabric never drains, so the
    # pause sensitivity saturates (visible as the flat bottom heatmap row,
    # which the paper also shows).
    for s in ("cresco8", "leonardo"):
        sub = [r for r in rows if r["system"] == s
               and r["aggressor"] == "incast"]
        if not sub:
            continue
        b0 = min(float(x["burst_ms"]) for x in sub)
        row = [r for r in sub if float(r["burst_ms"]) == b0]
        short = min(float(r["ratio"]) for r in row
                    if float(r["pause_ms"]) == min(float(x["pause_ms"])
                                                   for x in row))
        longp = min(float(r["ratio"]) for r in row
                    if float(r["pause_ms"]) == max(float(x["pause_ms"])
                                                   for x in row))
        print(f"# Obs.3 {s} ({b0}ms bursts): ratio short-pause {short:.2f} "
              f"vs long-pause {longp:.2f} -> "
              f"{'REPRODUCED' if short < longp else 'MISMATCH'}")
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--force", action="store_true")
    p.add_argument("--quick", action="store_true")
    a = p.parse_args()
    main(force=a.force, quick=a.quick)
