"""Paper Fig. 6 / Obs. 3: bursty congestion at 64 nodes — 3x3 heatmaps of
(burst length x inter-burst pause) per system x aggressor x vector size.

Routed through the scenario registry: each (system, aggressor) grid runs
as ONE batched bench.run_grid call over sizes x (burst, pause) cells."""
from __future__ import annotations

import argparse

from benchmarks.common import heatmap, scenario_rows, size_label
from repro.core import scenarios

SYSTEMS = scenarios.FIG5_SYSTEMS
AGGRESSORS = scenarios.FIG5_AGGRESSORS
SIZES = scenarios.FIG6_SIZES
N_NODES = 64


def main(force: bool = False, quick: bool = False):
    sizes = (32 * 2 ** 10,) if quick else SIZES
    rows = scenario_rows(scenarios.get("fig6_bursty", quick), force=force)
    for s in SYSTEMS:
        for a in AGGRESSORS:
            for v in sizes:
                sub = [r for r in rows if r["system"] == s
                       and r["aggressor"] == a
                       and float(r["vector_bytes"]) == float(v)]
                if not sub:
                    continue
                print(f"\n# Fig. 6 — {s}, {a} aggressor, "
                      f"{size_label(v)} victim AllGather, {N_NODES} nodes "
                      "(rows: burst ms, cols: pause ms)")
                print(heatmap(sub, x="pause_ms", y="burst_ms", val="ratio"))
    # Obs. 3: short pauses hurt more than long pauses. Compared at the
    # SHORTEST burst length — at the longest bursts the duty cycle is
    # >= 50% for every tested pause and the fabric never drains, so the
    # pause sensitivity saturates (visible as the flat bottom heatmap row,
    # which the paper also shows).
    for s in ("cresco8", "leonardo"):
        sub = [r for r in rows if r["system"] == s
               and r["aggressor"] == "incast"]
        if not sub:
            continue
        b0 = min(float(x["burst_ms"]) for x in sub)
        row = [r for r in sub if float(r["burst_ms"]) == b0]
        short = min(float(r["ratio"]) for r in row
                    if float(r["pause_ms"]) == min(float(x["pause_ms"])
                                                   for x in row))
        longp = min(float(r["ratio"]) for r in row
                    if float(r["pause_ms"]) == max(float(x["pause_ms"])
                                                   for x in row))
        print(f"# Obs.3 {s} ({b0}ms bursts): ratio short-pause {short:.2f} "
              f"vs long-pause {longp:.2f} -> "
              f"{'REPRODUCED' if short < longp else 'MISMATCH'}")
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--force", action="store_true")
    p.add_argument("--quick", action="store_true")
    a = p.parse_args()
    main(force=a.force, quick=a.quick)
