"""Beyond-paper congestion families enabled by traceable envelopes, the
traffic-program IR, and the scale-batched geometry engine: ramp onsets,
random telegraph aggressors, multi-tenant envelope mixes, phased vs
flattened collective schedules, concurrent multi-job interference, and
the cross-scale / cross-topology sweeps (scenario registry: ramp_onset /
random_telegraph / multi_tenant / phased_collectives / multi_job_mix /
scale_sweep / mixed_topology)."""
from __future__ import annotations

import argparse

from benchmarks.common import scenario_rows, size_label
from repro.core import scenarios

FAMILIES = ("ramp_onset", "random_telegraph", "multi_tenant",
            "phased_collectives", "multi_job_mix", "scale_sweep",
            "mixed_topology")


def main(force: bool = False, quick: bool = False, families=FAMILIES):
    all_rows = []
    for name in families:
        scen = scenarios.get(name, quick)
        rows = scenario_rows(scen, force=force)
        all_rows.extend(rows)
        print(f"\n# {name} — {scen.description}")
        print(f"{'system':>10} {'n':>4} {'victim':>22} {'aggr':>20} "
              f"{'size':>8} {'profile':>22} {'ratio':>7}")
        for r in rows:
            print(f"{r['system']:>10} {r['n_nodes']:>4} "
                  f"{r.get('victim', ''):>22} {r['aggressor']:>20} "
                  f"{size_label(r['vector_bytes']):>8} "
                  f"{r['profile']:>22} {float(r['ratio']):>7.3f}"
                  + (f"  [{r['job_times']}]"
                     if name == "multi_job_mix" and r.get("job_times")
                     else ""))
    # sanity narratives
    ramp = [r for r in all_rows if r["profile"].startswith("ramp")]
    if ramp:
        worst = min(float(r["ratio"]) for r in ramp)
        print(f"\n# ramp check: slowest-onset ratio floor {worst:.2f} "
              "(ramps bound steady-state impact from above)")
    phased = [r for r in all_rows if r.get("victim", "").endswith("+phased")]
    if phased:
        flat = {(r["system"], r["victim"], r["aggressor"],
                 r["vector_bytes"], r["profile"]): float(r["ratio"])
                for r in all_rows
                if "+phased" not in r.get("victim", "")}
        deltas = [float(r["ratio"]) - flat[k] for r in phased
                  if (k := (r["system"], r["victim"][:-len("+phased")],
                            r["aggressor"], r["vector_bytes"],
                            r["profile"])) in flat]
        if deltas:
            print(f"# phased check: phased-vs-flat ratio delta "
                  f"min {min(deltas):+.2f} max {max(deltas):+.2f} over "
                  f"{len(deltas)} paired cells (temporal structure matters)")
    return all_rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--force", action="store_true")
    p.add_argument("--quick", action="store_true")
    p.add_argument("--family", default="",
                   help="comma-separated subset of scenario families")
    a = p.parse_args()
    fams = tuple(f for f in a.family.split(",") if f) or FAMILIES
    main(force=a.force, quick=a.quick, families=fams)
