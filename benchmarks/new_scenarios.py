"""Beyond-paper congestion families enabled by traceable envelopes:
ramp onsets, random telegraph aggressors, and multi-tenant envelope mixes
(scenario registry: ramp_onset / random_telegraph / multi_tenant)."""
from __future__ import annotations

import argparse

from benchmarks.common import scenario_rows, size_label
from repro.core import scenarios

FAMILIES = ("ramp_onset", "random_telegraph", "multi_tenant")


def main(force: bool = False, quick: bool = False):
    all_rows = []
    for name in FAMILIES:
        scen = scenarios.get(name, quick)
        rows = scenario_rows(scen, force=force)
        all_rows.extend(rows)
        print(f"\n# {name} — {scen.description}")
        print(f"{'system':>10} {'aggr':>9} {'size':>8} "
              f"{'profile':>34} {'ratio':>7}")
        for r in rows:
            print(f"{r['system']:>10} {r['aggressor']:>9} "
                  f"{size_label(r['vector_bytes']):>8} "
                  f"{r['profile']:>34} {float(r['ratio']):>7.3f}")
    # sanity narratives
    ramp = [r for r in all_rows if r["profile"].startswith("ramp")]
    if ramp:
        worst = min(float(r["ratio"]) for r in ramp)
        print(f"\n# ramp check: slowest-onset ratio floor {worst:.2f} "
              "(ramps bound steady-state impact from above)")
    return all_rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--force", action="store_true")
    p.add_argument("--quick", action="store_true")
    a = p.parse_args()
    main(force=a.force, quick=a.quick)
