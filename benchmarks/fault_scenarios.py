"""Link-fault & degradation scenario driver: flapping links, dying
optics, fabric jitter, and the intra-node (NVLink/PCIe) stage, plus the
coordinator-side StepMonitor detection demo.

``PYTHONPATH=src python -m benchmarks.fault_scenarios [--quick] [--write]``

--quick (the CI smoke) runs the link_fault / intra_node quick scenarios
and asserts the engine contracts:

* inertness gate — an all-``none`` fault table and an +inf-capacity
  intra-node stage are BIT-IDENTICAL to the fault-free engine on every
  state leaf, on both step-core backends (the DESIGN.md §16 contract);
* fault lanes hurt — the hot-link flap lane lands well below ratio 1.0
  and the dying-optic lane degrades monotonically into its window;
* the intra-node stage is monotone in node capacity;
* the mitigation panel reports a baseline-guarded per-fabric winner for
  the flapping-link scenario (score.winners_by_system);
* a StepMonitor fed the replayed per-step queue-delay stream trips
  inside the flap window, and after the elastic_plan + reset(rebaseline)
  response stays untripped in the degraded steady state.

Exit code is non-zero on any MISMATCH, so CI catches regressions.
"""
from __future__ import annotations

import argparse
import functools
import json
import sys
import time
from pathlib import Path
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import scenario_rows, size_label
from repro.core import bench, congestion as cong, scenarios
from repro.core.fabric import simulator as sim, systems
from repro.core.fabric.routing import POLICY_ADAPTIVE, POLICY_ECMP
from repro.core.mitigation import score, search
from repro.core.mitigation.search import Candidate
from repro.runtime import fault as rfault

GATE_STEPS = 48  # inertness-gate scan length (covers several flap slots)


# ---------------------------------------------------------------------------
# claim 1: inertness gate (bit-identity on every state leaf, both backends)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend",))
def _scan_states(geom, p, state, backend):
    return jax.lax.scan(
        lambda s, _: sim.step(geom, p, s, backend=backend),
        state, None, length=GATE_STEPS)


def _leaf_mismatches(sa, sb) -> List[str]:
    return [k for k in sa
            if not bool(jnp.all(sa[k] == sb[k]))]


def inertness_gate() -> bool:
    """All-off fault table + inf-cap intra-node stage vs the plain
    engine: every state leaf must match bit-for-bit after GATE_STEPS
    steps, on both the ref and the fused Pallas step cores."""
    sysp = systems.get_system("leonardo")
    case = bench.build_case(sysp, 8, "ring_allgather", "incast")
    case_in = bench.build_case(sysp, 8, "ring_allgather", "incast",
                               intra_node=True)
    v = 2 << 20
    dt = bench.choose_dt(case.topo, case.n_victims, v, case.lat())
    prof = cong.steady()
    p_plain = case.cell_params(v, prof, dt)              # fault leaf absent
    p_table = case.cell_params(v, prof, dt,
                               with_fault_table=True)    # all-``none`` table
    p_intra = case_in.cell_params(v, prof, dt)           # node_cap == +inf
    ok = True
    for backend in ("ref", "pallas"):
        s0, gp0 = _scan_states(case.geom, p_plain,
                               sim.init_state(case.geom, p_plain), backend)
        s1, gp1 = _scan_states(case.geom, p_table,
                               sim.init_state(case.geom, p_table), backend)
        s2, gp2 = _scan_states(case_in.geom, p_intra,
                               sim.init_state(case_in.geom, p_intra), backend)
        bad_t = _leaf_mismatches(s0, s1) \
            + ([] if bool(jnp.all(gp0 == gp1)) else ["goodput"])
        bad_n = _leaf_mismatches(s0, s2) \
            + ([] if bool(jnp.all(gp0 == gp2)) else ["goodput"])
        verdict = "bit-identical" if not (bad_t or bad_n) else \
            f"MISMATCH (table: {bad_t}, intra: {bad_n})"
        print(f"# inertness[{backend}]: all-none table & inf-cap node "
              f"stage vs plain engine, {GATE_STEPS} steps -> {verdict}")
        ok &= not (bad_t or bad_n)
    return ok


# ---------------------------------------------------------------------------
# claims 2+3: scenario families (registry-driven, cached like every bench)
# ---------------------------------------------------------------------------

def print_rows(name: str, rows: List[Dict]) -> None:
    print(f"\n# {name}")
    print(f"{'system':>10} {'n':>4} {'aggr':>8} {'size':>8} "
          f"{'profile':>42} {'ratio':>7}")
    for r in rows:
        print(f"{r['system']:>10} {r['n_nodes']:>4} {r['aggressor']:>8} "
              f"{size_label(r['vector_bytes']):>8} {r['profile']:>42} "
              f"{float(r['ratio']):>7.3f}")


def fault_claims(quick: bool, force: bool) -> Dict:
    lf = scenarios.get("link_fault", quick)
    rows_lf = scenario_rows(lf, force=force)
    print_rows(f"link_fault — {lf.description}", rows_lf)

    intra = scenarios.get("intra_node", quick)
    rows_in = scenario_rows(intra, force=force)
    print_rows(f"intra_node — {intra.description}", rows_in)

    # flapping hot link: duty-0.3 outage slots must cost well over the
    # measurement noise (the victim's hot link is down ~30% of the time)
    flap = [float(r["ratio"]) for r in rows_lf
            if "flap[" in r["profile"] and r["profile"].startswith("off")]
    ok_flap = bool(flap) and max(flap) < 0.9
    print(f"\n# flap check: hot-link flap ratios "
          f"{[f'{x:.2f}' for x in flap]} (all < 0.9) -> "
          f"{'REPRODUCED' if ok_flap else 'MISMATCH'}")

    # dying optic: a persistent 70% capacity loss on the hot link cannot
    # be free either
    optic = [float(r["ratio"]) for r in rows_lf
             if "degrade[" in r["profile"]]
    ok_optic = bool(optic) and max(optic) < 0.95
    print(f"# dying-optic check: degrade ratios "
          f"{[f'{x:.2f}' for x in optic]} (all < 0.95) -> "
          f"{'REPRODUCED' if ok_optic else 'MISMATCH'}")

    # intra-node stage: ratio must be monotone (non-increasing, small
    # slack) as the node's internal bandwidth shrinks
    fracs_seen: Dict[float, List[float]] = {}
    for r in rows_in:
        frac = float(r["profile"].rsplit("+node", 1)[1].rstrip("x"))
        fracs_seen.setdefault(frac, []).append(float(r["ratio"]))
    fracs = sorted(fracs_seen, reverse=True)
    means = [float(np.mean(fracs_seen[f])) for f in fracs]
    ok_intra = all(b <= a + 0.05 for a, b in zip(means, means[1:])) \
        and means[-1] < means[0] - 0.05
    print(f"# intra-node check: node-cap fracs {fracs} -> mean ratios "
          f"{[f'{m:.2f}' for m in means]} (monotone, tightest frac "
          f"hurts) -> {'REPRODUCED' if ok_intra else 'MISMATCH'}")
    return {"rows_lf": rows_lf, "rows_in": rows_in, "ok_flap": ok_flap,
            "ok_optic": ok_optic, "ok_intra": ok_intra,
            "flap": flap, "optic": optic,
            "intra": {str(f): m for f, m in zip(fracs, means)}}


# ---------------------------------------------------------------------------
# claim 4: per-fabric mitigation winner for the flapping-link panel
# ---------------------------------------------------------------------------

def fault_panel(quick: bool) -> Dict:
    panel = score.panel_from_scenario(score.FAULT_PANEL_SCENARIO,
                                      quick=True)
    cands = [Candidate(policy=POLICY_ECMP),
             Candidate(policy=POLICY_ADAPTIVE),
             Candidate(cc=(("hol_factor", 0.45),))]
    print(f"\n# fault panel: {len(cands) + 1} candidates x {len(panel)} "
          "flap/degrade cells (one vmapped batch)")
    scores = score.score_table(panel, cands,
                               n_iters=8 if quick else 12,
                               warmup=2 if quick else 3,
                               max_steps=120_000)
    runs = [r for s in scores for r in s.cells]
    winners = score.winners_by_system(runs)
    ok = bool(winners)
    for sysname, w in winners.items():
        good = np.isfinite(w.ratio_min)
        ok &= bool(good)
        print(f"#   {sysname}: winner {w.candidate} "
              f"(ratio_min={w.ratio_min:.3f}, jain={w.jain:.3f}, "
              f"base_rel={w.t_base_worst_rel:.3f})")
    print(f"# fault-panel check: baseline-guarded winner per fabric -> "
          f"{'REPRODUCED' if ok else 'MISMATCH'}")
    return {"ok": ok,
            "winners": {s: w.candidate for s, w in winners.items()}}


# ---------------------------------------------------------------------------
# claim 5: StepMonitor detection demo on the replayed queue-delay stream
# ---------------------------------------------------------------------------

def monitor_demo() -> Dict:
    """Coordinator-side detection: replay the per-step victim queue-delay
    stream of a flap run into a StepMonitor (window duration = base step
    latency + mean queue delay, via the injectable clock). The monitor
    must trip INSIDE the flap window, and after the elastic-rescale
    response (elastic_plan + reset(rebaseline=True)) must accept the
    degraded steady state instead of staying tripped forever."""
    sysp = systems.get_system("leonardo")
    case = bench.build_case(sysp, 8, "ring_allgather", "")
    v = 2 << 20
    dt = bench.choose_dt(case.topo, case.n_victims, v, case.lat())
    steps, window = 600, 20
    t_fault = 0.5 * steps * dt  # flap starts mid-replay, runs to the end
    prof = cong.with_faults(
        cong.no_congestion(),
        cong.flap(t_fault, 10.0, duty=0.9, seed=5))
    p = case.cell_params(v, prof, dt, with_fault_table=True)

    geom = case.geom

    def body(s, _):
        s2, _, aux = sim.step_debug(geom, p, s)
        vq = jnp.sum(aux["qdel"] * geom.is_victim) \
            / jnp.maximum(jnp.sum(geom.is_victim), 1)
        return s2, vq
    qdel = np.asarray(jax.jit(
        lambda s: jax.lax.scan(body, s, None, length=steps)[1])(
            sim.init_state(geom, p)))

    durs = [case.lat() + float(np.mean(w))
            for w in qdel.reshape(-1, window)]
    fault_win = int(t_fault / dt) // window
    clock_t = [0.0]
    mon = rfault.StepMonitor(threshold=2.5, trip_after=3,
                             clock=lambda: clock_t[0])
    tripped_at, plan = None, None
    for i, d in enumerate(durs):
        mon.start_step()
        clock_t[0] += d
        mon.end_step(i)
        if mon.tripped and tripped_at is None:
            tripped_at = i
            # coordinator response: drop the node behind the flapping
            # link and rescale to the largest surviving grid, then
            # rebaseline the monitor on the degraded steady state (the
            # trip_after flagged windows themselves — flagged steps never
            # fed the EMA, which is the bug class reset() exists for)
            plan = rfault.elastic_plan(int(geom.n_src) - 1, 2)
            mon.reset(rebaseline=True, window=3)
    retripped = mon.tripped or (tripped_at is not None
                                and any(st.flagged for st in
                                        mon.history[tripped_at + 1:]))
    ok = (tripped_at is not None and tripped_at >= fault_win
          and not retripped)
    print(f"\n# monitor demo: qdel windows clean "
          f"{np.mean(durs[:fault_win]) * 1e6:.1f}us -> flap "
          f"{np.mean(durs[fault_win:]) * 1e6:.1f}us; tripped at window "
          f"{tripped_at} (flap enters at {fault_win}), elastic_plan -> "
          f"{plan}, post-reset tripped={mon.tripped} -> "
          f"{'REPRODUCED' if ok else 'MISMATCH'}")
    return {"ok": ok, "tripped_window": tripped_at,
            "fault_window": fault_win, "plan": list(plan) if plan else None,
            "retripped_after_reset": bool(retripped)}


def main(quick: bool = False, force: bool = False, write: bool = False,
         out: str = "BENCH_engine.json") -> Dict:
    t0 = time.time()
    ok_inert = inertness_gate()
    claims = fault_claims(quick, force)
    panel = fault_panel(quick)
    mon = monitor_demo()

    elapsed = time.time() - t0
    print(f"\n[fault_scenarios] done in {elapsed:.0f}s")
    ok = (ok_inert and claims["ok_flap"] and claims["ok_optic"]
          and claims["ok_intra"] and panel["ok"] and mon["ok"])
    doc_row = {
        "quick": bool(quick), "ok": bool(ok),
        "inert_bit_identical": bool(ok_inert),
        "flap_ratio_worst": min(claims["flap"]) if claims["flap"] else None,
        "optic_ratio_worst": min(claims["optic"]) if claims["optic"] else None,
        "intra_ratio_by_frac": claims["intra"],
        "winner_by_fabric": panel["winners"],
        "monitor": {k: v for k, v in mon.items() if k != "ok"},
        "elapsed_s": round(elapsed, 1),
    }
    if write:
        path = Path(out)
        doc = json.loads(path.read_text()) if path.exists() else {}
        doc["faults"] = doc_row
        path.write_text(json.dumps(doc, indent=1) + "\n")
        print(f"[fault_scenarios] wrote {path}:faults")
    if not ok:
        print("[fault_scenarios] FAILED checks", file=sys.stderr)
        sys.exit(1)
    return doc_row


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    p.add_argument("--force", action="store_true",
                   help="ignore the scenario-row CSV cache")
    p.add_argument("--write", action="store_true",
                   help="update BENCH_engine.json['faults']")
    p.add_argument("--out", default="BENCH_engine.json")
    a = p.parse_args()
    main(quick=a.quick, force=a.force, write=a.write, out=a.out)
