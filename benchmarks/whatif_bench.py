"""What-if serving + learned-search benchmark (ISSUE 10).

Two measured demos, both recorded into ``BENCH_engine.json["whatif"]``
(read-modify-write — other sections untouched):

* **Agent convergence** — random walk, GA, CMA-ES and BO race to the
  bounded-grid winner's objective at equal evaluation budget on a fixed
  seeded panel (a collision-prone ECMP leaf-spine cell where the
  searched CC knobs actually move the victim ratio; the quick
  ``mitigation_panel`` cells are deliberately near-flat there). The
  acceptance gate: CMA-ES or BO reaches the grid target with STRICTLY
  fewer simulator evaluations than random walk.
* **Coalescing** — K=3 mixed-bucket what-if queries answered serially
  (one server each) vs coalesced (one server, shared waves). Gates:
  per-query scorecards bit-identical, and the coalesced path answers
  with strictly fewer engine dispatches.

``--check-against BENCH_engine.json`` additionally gates the two
hardware-independent ratios against the committed artifact:
``evals_ratio`` (best learned agent's evals-to-target over random's —
lower is better) and ``call_ratio`` (coalesced dispatches over serial —
lower is better). Wall-clock numbers ride along for trajectory only and
are never gated.

Usage:
  PYTHONPATH=src python -m benchmarks.whatif_bench --quick \
      --check-against BENCH_engine.json                      # CI smoke
  PYTHONPATH=src python -m benchmarks.whatif_bench           # write
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import congestion as cong
from repro.core.fabric import simulator as sim
from repro.core.fabric.systems import get_system
from repro.core.mitigation import agents
from repro.core.mitigation.search import PanelCell
from repro.runtime import whatif

MiB = float(1 << 20)
KiB = float(1 << 10)
KNOBS = ("hol_factor", "md")


def _convergence_panel():
    """The seeded race panel: ECMP collisions give the knobs a real
    objective gradient (probed spread ~0.50-0.56)."""
    return (PanelCell(name="ecmp8", system=get_system("nanjing_ecmp"),
                      n_nodes=8, victim="ring_allgather",
                      aggressor="alltoall", vector_bytes=4 * MiB,
                      profile=cong.steady()),)


def run_convergence(quick: bool) -> dict:
    budget, batch = (24, 8) if quick else (48, 8)
    kw = dict(n_iters=5, warmup=2, max_steps=60_000) if quick \
        else dict(n_iters=10, warmup=3)
    t0 = time.perf_counter()
    rep = agents.compare_agents(["random", "ga", "cmaes", "bo"],
                                _convergence_panel(), budget=budget,
                                batch=batch, knobs=KNOBS, seed=0, **kw)
    wall = time.perf_counter() - t0

    def reached(kind):
        e = rep["agents"][kind]["evals_to_target"]
        return float("inf") if e is None else float(e)

    best_learned = min(reached("cmaes"), reached("bo"))
    evals_ratio = (best_learned / reached("random")
                   if np.isfinite(reached("random"))
                   and np.isfinite(best_learned) else
                   (0.0 if np.isfinite(best_learned) else float("inf")))
    out = {
        "budget": budget, "batch": batch, "knobs": list(KNOBS),
        "target": rep["target"], "wall_s": round(wall, 2),
        "evals_ratio": round(evals_ratio, 4),
        "agents": {k: {"best_objective": d["best_objective"],
                       "evals_to_target": d["evals_to_target"],
                       "evals": d["evals"], "best": d["best"],
                       "traces": d["traces"],
                       "best_label": d["best_label"]}
                   for k, d in rep["agents"].items()},
    }
    failures = []
    if not best_learned < reached("random"):
        failures.append(
            f"convergence: best learned agent used {best_learned} evals "
            f"to target vs random's {reached('random')} — not strictly "
            "fewer")
    return out, failures


def _coalescing_queries(quick: bool):
    cands = tuple(agents.grid_candidates(
        KNOBS, points_per_knob=2 if quick else 3))
    return [
        whatif.WhatIfQuery(system="cresco8", n_nodes=8,
                           vector_bytes=256 * KiB, agent="grid",
                           candidates=cands, budget=len(cands), batch=2),
        whatif.WhatIfQuery(system="cresco8", n_nodes=16,
                           vector_bytes=128 * KiB, agent="grid",
                           candidates=cands, budget=len(cands), batch=2),
        whatif.WhatIfQuery(system="lumi", n_nodes=16,
                           vector_bytes=256 * KiB, agent="grid",
                           candidates=cands[:-1], budget=len(cands),
                           batch=2),
    ]


def _table(res):
    return {s.candidate: (s.ratio_min, s.ratio_mean, s.aggr_gbps,
                          s.jain, s.t_base_worst_rel)
            for s in res.scores}


def run_coalescing(quick: bool) -> dict:
    kw = dict(n_iters=5, warmup=2, max_steps=50_000) if quick \
        else dict(n_iters=10, warmup=3)
    queries = _coalescing_queries(quick)

    # coalesced first: it pays the compiles, so the serial pass (same
    # lane shapes per query) cannot look artificially slow
    srv = whatif.WhatIfServer(max_batch=len(queries), **kw)
    uids = [srv.submit(q) for q in queries]
    t0 = time.perf_counter()
    stats = srv.run_until_drained()
    wall_coal = time.perf_counter() - t0
    coalesced = [srv.result(u) for u in uids]

    serial = []
    serial_calls = 0
    t0 = time.perf_counter()
    for q in queries:
        s1 = whatif.WhatIfServer(max_batch=1, **kw)
        u = s1.submit(q)
        s1.run_until_drained()
        serial.append(s1.result(u))
        serial_calls += s1.stats.coalesced_calls
    wall_serial = time.perf_counter() - t0

    bit_identical = all(_table(a) == _table(b)
                        for a, b in zip(coalesced, serial))
    out = {
        "n_queries": len(queries),
        "mixed_buckets": True,
        "bit_identical": bit_identical,
        "coalesced_calls": stats.coalesced_calls,
        "serial_calls": serial_calls,
        "call_ratio": round(stats.coalesced_calls / serial_calls, 4),
        "lanes": stats.lanes,
        "wall_coalesced_s": round(wall_coal, 2),
        "wall_serial_s": round(wall_serial, 2),
        "winners": [{"query": f"{q.system}-{q.n_nodes}",
                     "winner": r.winner.candidate,
                     "finish_reason": r.finish_reason,
                     "evals": r.evals}
                    for q, r in zip(queries, coalesced)],
    }
    failures = []
    if not bit_identical:
        failures.append("coalescing: shared-wave scorecards differ from "
                        "serial per-query runs")
    if not stats.coalesced_calls < serial_calls:
        failures.append(
            f"coalescing: {stats.coalesced_calls} coalesced dispatches "
            f">= {serial_calls} serial — batching bought nothing")
    return out, failures


def check_against(section, committed_path, margin):
    """Gate the two hardware-independent ratios vs the committed
    artifact; wall times are machine-dependent and never gated."""
    committed = json.loads(Path(committed_path).read_text())
    old = committed.get("whatif", {})
    failures = []
    for key, path in (("evals_ratio", ("convergence", "evals_ratio")),
                      ("call_ratio", ("coalescing", "call_ratio"))):
        old_v = old.get(path[0], {}).get(path[1])
        new_v = section[path[0]][path[1]]
        if old_v is None:
            continue
        if new_v > old_v * (1.0 + margin):
            failures.append(f"{key}: {new_v:.3f} > committed "
                            f"{old_v:.3f} + {margin:.0%}")
        else:
            print(f"  {key}: {new_v:.3f} vs committed {old_v:.3f} — OK")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="small budgets + 2-point grids (CI smoke)")
    ap.add_argument("--out", default="BENCH_engine.json")
    ap.add_argument("--check-against", default=None, metavar="JSON",
                    help="gate evals_ratio / call_ratio against a "
                    "committed artifact; fail on regression")
    ap.add_argument("--regress-margin", type=float, default=0.30,
                    help="allowed relative ratio regression "
                    "(default 30%%)")
    ap.add_argument("--write", action="store_true",
                    help="write --out even in --check-against mode")
    args = ap.parse_args(argv)

    print(f"whatif_bench: quick={args.quick} "
          f"backend={jax.default_backend()}")
    t0 = time.time()
    conv, fails_c = run_convergence(args.quick)
    print(f"  convergence: target={conv['target']['objective']:.4f} "
          f"({conv['target']['label']})")
    for k, d in conv["agents"].items():
        print(f"    {k:7s} best={d['best_objective']:.4f} "
              f"evals_to_target={d['evals_to_target']} "
              f"traces={d['traces']}")
    coal, fails_k = run_coalescing(args.quick)
    print(f"  coalescing: {coal['n_queries']} queries "
          f"bit_identical={coal['bit_identical']} "
          f"calls {coal['serial_calls']} -> {coal['coalesced_calls']} "
          f"wall {coal['wall_serial_s']}s -> {coal['wall_coalesced_s']}s")

    section = {
        "schema": 1,
        "quick": args.quick,
        "jax_backend": jax.default_backend(),
        "wall_s": round(time.time() - t0, 1),
        "convergence": conv,
        "coalescing": coal,
    }
    failures = fails_c + fails_k
    if args.check_against:
        failures += check_against(section, args.check_against,
                                  args.regress_margin)
    if args.write or not args.check_against:
        path = Path(args.out)
        doc = json.loads(path.read_text()) if path.exists() else {}
        doc["whatif"] = section
        path.write_text(json.dumps(doc, indent=1) + "\n")
        print(f"wrote {args.out} (whatif section)")
    if failures:
        print("WHATIF BENCH FAILURES:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        return 1
    print("whatif_bench: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
