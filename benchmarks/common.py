"""Shared benchmark plumbing: CSV cache (resumable sweeps) + table printing."""
from __future__ import annotations

import csv
import os
from typing import Callable, Dict, Iterable, List

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "bench_cache")


def _load_cache(name: str, keys: List[str],
                force: bool) -> "tuple[str, Dict[tuple, Dict]]":
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, f"{name}.csv")
    cache: Dict[tuple, Dict] = {}
    if os.path.exists(path) and not force:
        with open(path) as f:
            for row in csv.DictReader(f):
                # rows from an older cache layout (missing a key column)
                # are treated as misses and recomputed
                if any(row.get(k) in (None, "") for k in keys):
                    continue
                cache[tuple(row[k] for k in keys)] = row
    return path, cache


def cached_sweep(name: str, keys: List[str], points: Iterable[tuple],
                 fn: Callable[..., Dict], force: bool = False) -> List[Dict]:
    """Run ``fn(*point) -> dict`` per point, caching rows to a CSV keyed by
    the point tuple — re-running a partially completed sweep only computes
    the missing cells."""
    path, cache = _load_cache(name, keys, force)
    rows = []
    for point in points:
        key = tuple(str(p) for p in point)
        if key in cache:
            rows.append(cache[key])
            continue
        out = fn(*point)
        row = {**dict(zip(keys, key)), **{k: str(v) for k, v in out.items()}}
        rows.append(row)
        cache[key] = row
        _write(path, keys, cache)
    return rows


SCENARIO_KEYS = ["system", "n_nodes", "victim", "aggressor", "vector_bytes",
                 "profile"]

# Cache-key columns per points-based (non-grid) scenario family — the
# single source of truth shared by each family's driver and the
# registry-completeness test, so CSV key drift stays caught (same role
# expected_grid_keys plays for grid scenarios).
POINT_KEYS: Dict[str, List[str]] = {
    "fig1_breakdown": ["vector_bytes"],
    "fig3_sawtooth": ["system", "vector_bytes"],
    "fig4_nslb": ["mode", "vector_bytes"],
    "collective_bench": ["size"],
    "fleet_replay": ["system", "n_nodes", "n_seeds"],
}


def expected_point_keys(scenario) -> "tuple[List[str], List[tuple]]":
    """(key columns, cache-key tuples in declaration order) for one
    points-based scenario."""
    keys = POINT_KEYS[scenario.name]
    pts = [tuple(str(p) for p in pt) for pt in scenario.points]
    for pt in pts:
        if len(pt) != len(keys):
            raise ValueError(
                f"{scenario.name}: point {pt} does not match key "
                f"columns {keys}")
    return keys, pts


def _grid_victim_label(grid) -> str:
    from repro.core import bench

    return bench.resolve_victim_label(grid.victim, grid.phased,
                                      list(grid.jobs) or None)


def expected_grid_keys(grid) -> "List[tuple]":
    """The exact cache-key tuples one grid's rows will carry, in result
    order — the single source of truth shared by the CSV cache and the
    registry-completeness test (so key layout and result_row cannot
    drift apart). Scale-batched grids expand their (system, n_nodes)
    cells; plain grids are the one-cell special case."""
    vic = _grid_victim_label(grid)
    cells = list(getattr(grid, "cells", ()) or ()) \
        or [(grid.system, grid.n_nodes)]
    return [(s, str(n), vic, grid.aggressor or "none", str(float(v)),
             p.label())
            for (s, n) in cells for v in grid.sizes for p in grid.profiles]


def scenario_rows(scenario, force: bool = False) -> List[Dict]:
    """Run a registered scenario with grid-level CSV caching: a grid whose
    cells are all cached is skipped; otherwise the whole grid re-runs in
    one batched bench.run_grid call (that is the unit of compute now)."""
    from repro.core import scenarios as scen

    path, cache = _load_cache(scenario.name, SCENARIO_KEYS, force)
    rows = []
    for grid in scenario.grids:
        expected = expected_grid_keys(grid)
        if all(k in cache for k in expected):
            rows.extend(cache[k] for k in expected)
            continue
        for r in scen.run_grid_spec(scenario, grid):
            row = {k: str(v) for k, v in scen.result_row(grid, r).items()}
            cache[tuple(row[k] for k in SCENARIO_KEYS)] = row
            rows.append(row)
        _write(path, SCENARIO_KEYS, cache)
    return rows


def _write(path: str, keys: List[str], cache: Dict[tuple, Dict]):
    fields: List[str] = []
    for row in cache.values():
        for k in row:
            if k not in fields:
                fields.append(k)
    tmp = path + ".tmp"
    with open(tmp, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields)
        w.writeheader()
        for row in cache.values():
            w.writerow(row)
    os.replace(tmp, path)


def heatmap(rows: List[Dict], x: str, y: str, val: str,
            fmt: str = "{:>7.2f}") -> str:
    xs = sorted({r[x] for r in rows}, key=_num)
    ys = sorted({r[y] for r in rows}, key=_num)
    grid = {(r[y], r[x]): float(r[val]) for r in rows}
    out = [" " * 12 + "".join(f"{str(v):>8}" for v in xs)]
    for yy in ys:
        line = f"{str(yy):>12}"
        for xx in xs:
            v = grid.get((yy, xx))
            line += fmt.format(v) if v is not None else " " * 7 + "-"
        out.append(line)
    return "\n".join(out)


def _num(s):
    try:
        return float(s)
    except (TypeError, ValueError):
        return s


def size_label(b: float) -> str:
    b = float(b)
    for unit, div in (("GiB", 2 ** 30), ("MiB", 2 ** 20), ("KiB", 2 ** 10)):
        if b >= div:
            return f"{b / div:g}{unit}"
    return f"{b:g}B"
