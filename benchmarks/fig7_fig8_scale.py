"""Paper Fig. 7 (CRESCO8, 128 nodes) and Fig. 8 (LUMI, 256 nodes): bursty
congestion at larger scale. Includes the paper's 64 vs 128-node CRESCO8
Incast comparison (wider congestion tree -> milder collapse)."""
from __future__ import annotations

import argparse

from benchmarks.common import cached_sweep, heatmap, size_label
from repro.core import bench, congestion as cong
from repro.core.fabric import systems

BURSTS_MS = (0.5, 2.0, 8.0)
PAUSES_MS = (0.2, 1.0, 8.0)


def run_point(system: str, n_nodes: int, aggr: str, vector_bytes: float,
              burst_ms: float, pause_ms: float) -> dict:
    r = bench.run_point(systems.get_system(system), int(n_nodes),
                        "ring_allgather", aggr, float(vector_bytes),
                        cong.bursty(float(burst_ms) * 1e-3,
                                    float(pause_ms) * 1e-3),
                        n_iters=20, warmup=4)
    return {"ratio": round(r.ratio, 4)}


def main(force: bool = False, quick: bool = False):
    cells = [("cresco8", 64), ("cresco8", 128), ("lumi", 256)]
    sizes = (2 * 2 ** 20,) if quick else (32 * 2 ** 10, 2 * 2 ** 20)
    bursts = (2.0,) if quick else BURSTS_MS
    pauses = (0.2, 8.0) if quick else PAUSES_MS
    points = [(s, n, a, v, b, p) for (s, n) in cells
              for a in ("alltoall", "incast")
              for v in sizes for b in bursts for p in pauses]
    rows = cached_sweep(
        "fig7_fig8_scale",
        ["system", "n_nodes", "aggressor", "vector_bytes", "burst_ms",
         "pause_ms"], points, run_point, force=force)
    for (s, n) in cells:
        for a in ("alltoall", "incast"):
            sub = [r for r in rows if r["system"] == s
                   and int(r["n_nodes"]) == n and r["aggressor"] == a]
            if not sub:
                continue
            print(f"\n# Fig. 7/8 — {s} {n} nodes, {a} aggressor "
                  "(rows: burst ms, cols: pause ms; ratio over sizes=min)")
            best = {}
            for r in sub:
                k = (r["burst_ms"], r["pause_ms"])
                best[k] = min(best.get(k, 1e9), float(r["ratio"]))
            flat = [{"burst_ms": b, "pause_ms": p, "ratio": v}
                    for (b, p), v in best.items()]
            print(heatmap(flat, x="pause_ms", y="burst_ms", val="ratio"))
    # paper: CRESCO8 Incast bursts LESS harmful at 128 than 64 nodes
    def worst(s, n):
        sub = [float(r["ratio"]) for r in rows if r["system"] == s
               and int(r["n_nodes"]) == n and r["aggressor"] == "incast"]
        return min(sub) if sub else float("nan")

    w64, w128 = worst("cresco8", 64), worst("cresco8", 128)
    print(f"\n# Fig.7 check: cresco8 incast worst ratio 64n={w64:.3f} vs "
          f"128n={w128:.3f} (paper: 128 nodes less affected) -> "
          f"{'REPRODUCED' if w128 > w64 else 'MISMATCH'}")
    lumi_min = min(float(r["ratio"]) for r in rows if r["system"] == "lumi")
    print(f"# Fig.8 check: LUMI 256n worst ratio {lumi_min:.3f} "
          f"(paper: near-baseline everywhere) -> "
          f"{'REPRODUCED' if lumi_min > 0.85 else 'MISMATCH'}")
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--force", action="store_true")
    p.add_argument("--quick", action="store_true")
    a = p.parse_args()
    main(force=a.force, quick=a.quick)
