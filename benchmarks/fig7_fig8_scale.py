"""Paper Fig. 7 (CRESCO8, 128 nodes) and Fig. 8 (LUMI, 256 nodes): bursty
congestion at larger scale. Includes the paper's 64 vs 128-node CRESCO8
Incast comparison (wider congestion tree -> milder collapse).

Routed through the scenario registry: each (system, nodes, aggressor)
grid runs as ONE batched bench.run_grid call."""
from __future__ import annotations

import argparse

from benchmarks.common import heatmap, scenario_rows
from repro.core import scenarios


def main(force: bool = False, quick: bool = False):
    cells = [("cresco8", 64), ("cresco8", 128), ("lumi", 256)]
    rows = scenario_rows(scenarios.get("fig7_fig8_scale", quick),
                         force=force)
    for (s, n) in cells:
        for a in ("alltoall", "incast"):
            sub = [r for r in rows if r["system"] == s
                   and int(r["n_nodes"]) == n and r["aggressor"] == a]
            if not sub:
                continue
            print(f"\n# Fig. 7/8 — {s} {n} nodes, {a} aggressor "
                  "(rows: burst ms, cols: pause ms; ratio over sizes=min)")
            best = {}
            for r in sub:
                k = (r["burst_ms"], r["pause_ms"])
                best[k] = min(best.get(k, 1e9), float(r["ratio"]))
            flat = [{"burst_ms": b, "pause_ms": p, "ratio": v}
                    for (b, p), v in best.items()]
            print(heatmap(flat, x="pause_ms", y="burst_ms", val="ratio"))
    # paper: CRESCO8 Incast bursts LESS harmful at 128 than 64 nodes
    def worst(s, n):
        sub = [float(r["ratio"]) for r in rows if r["system"] == s
               and int(r["n_nodes"]) == n and r["aggressor"] == "incast"]
        return min(sub) if sub else float("nan")

    w64, w128 = worst("cresco8", 64), worst("cresco8", 128)
    print(f"\n# Fig.7 check: cresco8 incast worst ratio 64n={w64:.3f} vs "
          f"128n={w128:.3f} (paper: 128 nodes less affected) -> "
          f"{'REPRODUCED' if w128 > w64 else 'MISMATCH'}")
    lumi_min = min(float(r["ratio"]) for r in rows if r["system"] == "lumi")
    print(f"# Fig.8 check: LUMI 256n worst ratio {lumi_min:.3f} "
          f"(paper: near-baseline everywhere) -> "
          f"{'REPRODUCED' if lumi_min > 0.85 else 'MISMATCH'}")
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--force", action="store_true")
    p.add_argument("--quick", action="store_true")
    a = p.parse_args()
    main(force=a.force, quick=a.quick)
