"""Paper Fig. 7 (CRESCO8, 128 nodes) and Fig. 8 (LUMI, 256 nodes): bursty
congestion at larger scale. Includes the paper's 64 vs 128-node CRESCO8
Incast comparison (wider congestion tree -> milder collapse).

Routed through the scenario registry AND the scale-batched geometry
engine: each aggressor's whole (system x n_nodes) ladder runs as ONE
bench.run_scale_grid call — geometries padded into buckets, one compile
per bucket instead of one per scale. The driver reports the compile
count; ``--compare`` additionally times the legacy per-scale loop and
prints the wall-clock speedup."""
from __future__ import annotations

import argparse
import time

from benchmarks.common import heatmap, scenario_rows
from repro.core import scenarios
from repro.core.fabric import simulator as sim_lib


def _run_sequential(scenario) -> float:
    """The pre-bucket path: one bench.run_grid call per (system, scale),
    timed for the speedup report (results discarded)."""
    from repro.core import bench
    from repro.core.fabric import systems

    t0 = time.time()
    for grid in scenario.grids:
        for s, n in (grid.cells or ((grid.system, grid.n_nodes),)):
            bench.run_grid(systems.get_system(s), int(n), grid.victim,
                           grid.aggressor, grid.sizes, grid.profiles,
                           n_iters=scenario.n_iters, warmup=scenario.warmup)
    return time.time() - t0


def _run_batched(scenario) -> float:
    """One scale-batched call per grid, timed fresh (no CSV cache), so
    the --compare speedup is compute-vs-compute — never compute vs a
    cached file read."""
    from repro.core import scenarios as scen

    t0 = time.time()
    for grid in scenario.grids:
        scen.run_grid_spec(scenario, grid)
    return time.time() - t0


def main(force: bool = False, quick: bool = False, compare: bool = False):
    scenario = scenarios.get("fig7_fig8_scale", quick)
    cells = []
    for grid in scenario.grids:
        for c in grid.cells:
            if c not in cells:
                cells.append(c)

    compiles0 = sim_lib.trace_count("run_cells_hetero")
    t0 = time.time()
    rows = scenario_rows(scenario, force=force)
    t_batched = time.time() - t0
    n_compiles = sim_lib.trace_count("run_cells_hetero") - compiles0

    for (s, n) in cells:
        for a in ("alltoall", "incast"):
            sub = [r for r in rows if r["system"] == s
                   and int(r["n_nodes"]) == n and r["aggressor"] == a]
            if not sub:
                continue
            print(f"\n# Fig. 7/8 — {s} {n} nodes, {a} aggressor "
                  "(rows: burst ms, cols: pause ms; ratio over sizes=min)")
            best = {}
            for r in sub:
                k = (r["burst_ms"], r["pause_ms"])
                best[k] = min(best.get(k, 1e9), float(r["ratio"]))
            flat = [{"burst_ms": b, "pause_ms": p, "ratio": v}
                    for (b, p), v in best.items()]
            print(heatmap(flat, x="pause_ms", y="burst_ms", val="ratio"))
    # paper: CRESCO8 Incast bursts LESS harmful at 128 than 64 nodes
    def worst(s, n):
        sub = [float(r["ratio"]) for r in rows if r["system"] == s
               and int(r["n_nodes"]) == n and r["aggressor"] == "incast"]
        return min(sub) if sub else float("nan")

    w64, w128 = worst("cresco8", 64), worst("cresco8", 128)
    if w64 == w64 and w128 == w128:  # NaN-safe: incast rows may be absent
        print(f"\n# Fig.7 check: cresco8 incast worst ratio 64n={w64:.3f} "
              f"vs 128n={w128:.3f} (paper: 128 nodes less affected) -> "
              f"{'REPRODUCED' if w128 > w64 else 'MISMATCH'}")
    lumi = [float(r["ratio"]) for r in rows if r["system"] == "lumi"]
    if lumi:
        lumi_min = min(lumi)
        print(f"# Fig.8 check: LUMI worst ratio {lumi_min:.3f} "
              f"(paper: near-baseline everywhere) -> "
              f"{'REPRODUCED' if lumi_min > 0.85 else 'MISMATCH'}")

    n_scales = len(cells) * len(scenario.grids)
    print(f"\n# scale-batched engine: {n_compiles} simulator compile(s) "
          f"for {n_scales} (system x scale x aggressor) cells in "
          f"{t_batched:.1f}s"
          + (" (all cells cached)" if n_compiles == 0 and t_batched < 5
             else ""))
    if compare:
        # both sides timed as real compute in this process (the
        # scenario_rows pass above may have been a cached CSV read);
        # run in a fresh process for fully cold-vs-cold numbers
        t_fresh = _run_batched(scenario)
        t_seq = _run_sequential(scenario)
        print(f"# --compare: batched {t_fresh:.1f}s vs per-scale loop "
              f"{t_seq:.1f}s -> speedup {t_seq / max(t_fresh, 1e-9):.2f}x")
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--force", action="store_true")
    p.add_argument("--quick", action="store_true")
    p.add_argument("--compare", action="store_true",
                   help="also time the legacy per-scale loop and report "
                        "the wall-clock speedup of the batched path")
    a = p.parse_args()
    main(force=a.force, quick=a.quick, compare=a.compare)
