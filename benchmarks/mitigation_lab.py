"""Mitigation lab driver: search the CC / load-balancing space across a
multi-scenario panel and report the Pareto frontier + per-fabric winner.

``PYTHONPATH=src python -m benchmarks.mitigation_lab [--quick] [--grad]``

--quick (the CI smoke) runs a small candidate space against the
2-scenario quick panel and asserts the two headline claims:

* NSLB flat-lines the Fig. 4 leaf-spine cell while ECMP collapses
  (ratio > 0.9 vs < 0.85 — the paper's Fig. 4 contrast, now produced by
  ONE geometry with the routing policy swept as traced data);
* a searched CC config beats the fabric default on at least one bursty
  scenario without degrading the uncongested baseline, and the AI-ECN
  upgrade candidate shrinks the CE8850 sawtooth amplitude (Fig. 3 CV).

Exit code is non-zero if a claim fails, so CI catches regressions.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List

from repro.core.fabric.routing import (POLICY_ADAPTIVE, POLICY_ECMP,
                                       POLICY_FLOWLET, POLICY_NSLB)
from repro.core.mitigation import score, search
from repro.core.mitigation.search import Candidate

# The CE9855-style firmware upgrade for the CE8850: AI-ECN proportional
# marking against an adaptive threshold instead of bang-bang DCQCN.
AI_ECN_UPGRADE = Candidate(
    cc=(("kind", 3), ("thresh_adapt", 1.0), ("md", 0.85),
        ("rai_frac", 0.05), ("kmin", 0.1), ("kmax", 0.7)),
    name="ai_ecn_upgrade")


def candidate_space(quick: bool) -> List[Candidate]:
    """Grid tier: routing policies x CC configs (bounded knobs)."""
    routing = search.RoutingSpace(
        policies=(None, POLICY_ECMP, POLICY_NSLB, POLICY_ADAPTIVE,
                  POLICY_FLOWLET),
        flowlet_gaps_s=(100e-6,) if quick else (50e-6, 200e-6))
    cands = [Candidate(policy=r["policy"], flowlet_gap_s=r["flowlet_gap_s"])
             for r in routing.grid() if r["policy"] is not None]
    # CC axis (native routing, so fabrics keep their own load balancing).
    # hol_factor is the congestion-tree isolation knob (finer credit
    # granularity / per-flow buffering) — the lever behind the paper's
    # IB-generation ordering (Obs. 2).
    cc_space = search.CCSpace.of(
        hol_factor=(0.45, 0.9), md=(0.85,), rai_frac=(0.05,)) if quick \
        else search.CCSpace.of(md=(0.5, 0.85), rai_frac=(0.02, 0.05),
                               kmin=(0.15, 0.3), hol_factor=(0.45, 0.9))
    cands += [Candidate(cc=tuple(sorted(c.items())))
              for c in cc_space.grid()]
    cands.append(AI_ECN_UPGRADE)
    return cands


def print_table(scores: List[score.CandidateScore]) -> None:
    print(f"{'candidate':>38} {'ratio_min':>9} {'ratio_mean':>10} "
          f"{'aggr Gb/s':>9} {'jain':>6} {'base_rel':>8}")
    for s in sorted(scores, key=lambda s: -s.ratio_min):
        print(f"{s.candidate:>38} {s.ratio_min:>9.3f} {s.ratio_mean:>10.3f} "
              f"{s.aggr_gbps:>9.1f} {s.jain:>6.3f} "
              f"{s.t_base_worst_rel:>8.3f}")


def _cell_ratio(runs, cell_substr: str, cand: str) -> float:
    vals = [r.ratio for r in runs
            if cell_substr in r.cell and r.candidate == cand]
    return min(vals) if vals else float("nan")


def main(quick: bool = False, grad: bool = False) -> Dict:
    t0 = time.time()
    panel = score.panel_from_scenario(quick=quick)
    cands = candidate_space(quick)
    print(f"# mitigation lab: {len(cands) + 1} candidates x "
          f"{len(panel)} panel scenarios (one vmapped batch)")
    scores = score.score_table(panel, cands, n_iters=10 if quick else 15,
                               warmup=2 if quick else 3,
                               max_steps=120_000 if quick else 200_000)
    runs = [r for s in scores for r in s.cells]
    print_table(scores)

    front = score.pareto_frontier(scores)
    print("\n# Pareto frontier (maximize victim ratio, aggressor goodput, "
          "fairness):")
    for s in front:
        print(f"  {s.candidate}: ratio_min={s.ratio_min:.3f} "
              f"aggr={s.aggr_gbps:.1f}Gb/s jain={s.jain:.3f}")
    winner = score.pick_winner(scores)
    print(f"\n# per-fabric winner (baseline-guarded): {winner.candidate} "
          f"(ratio_min={winner.ratio_min:.3f})")

    # ---- claim 1: NSLB flat-lines the Fig. 4 leaf-spine cell vs ECMP ----
    fig4 = "nanjing"
    r_nslb = _cell_ratio(runs, fig4, "nslb")
    r_ecmp = _cell_ratio(runs, fig4, "ecmp")
    ok_fig4 = r_nslb > 0.9 and r_ecmp < 0.85
    print(f"\n# Fig.4 check: NSLB ratio {r_nslb:.2f} (paper: ~1.0) vs "
          f"ECMP {r_ecmp:.2f} (paper: ~0.67) -> "
          f"{'REPRODUCED' if ok_fig4 else 'MISMATCH'}")

    # ---- claim 2: a searched CC config beats the fabric default on a
    # bursty scenario without degrading the uncongested baseline ----
    default = next(s for s in scores if s.candidate == "default")
    bursty_cells = {r.cell for r in default.cells if "bursty" in r.cell}
    best_cc, best_gain = None, 0.0
    for s in scores:
        # CC-axis candidates keep the fabric's native routing — routing
        # wins are claim 1's business
        if not (s.candidate.startswith("native|")
                or s.candidate == AI_ECN_UPGRADE.name):
            continue
        if s.t_base_worst_rel > 1.02:
            continue
        for cell in bursty_cells:
            gain = _cell_ratio(runs, cell, s.candidate) \
                - _cell_ratio(runs, cell, "default")
            if gain > best_gain:
                best_cc, best_gain, best_cell = s.candidate, gain, cell
    ok_cc = best_cc is not None and best_gain > 0.02
    if ok_cc:
        print(f"# CC-search check: {best_cc} beats default by "
              f"+{best_gain:.2f} ratio on {best_cell} with no baseline "
              f"cost -> REPRODUCED")
    else:
        print("# CC-search check: no candidate beat the default on a "
              "bursty scenario -> MISMATCH")

    # ---- claim 3: AI-ECN upgrade shrinks the CE8850 sawtooth (Fig. 3) ----
    v = 64 << 20
    cv_default = search.sawtooth_cv("haicgu_ce8850", 4, "ring_allgather", v,
                                    search.default_candidate())
    cv_tuned = search.sawtooth_cv("haicgu_ce8850", 4, "ring_allgather", v,
                                  AI_ECN_UPGRADE)
    ok_saw = cv_tuned < 0.5 * cv_default
    print(f"# sawtooth check: CE8850 goodput CV {cv_default:.2f} -> "
          f"{cv_tuned:.2f} with tuned AI-ECN -> "
          f"{'REPRODUCED' if ok_saw else 'MISMATCH'}")

    if grad:
        print("\n# gradient tier (victim slowdown differentiated through "
              "the fluid scan):")
        from repro.core import bench, congestion as cong
        from repro.core.fabric import systems
        case = bench.build_case(systems.get_system("haicgu_ce8850"), 8,
                                "ring_allgather", "incast")
        dt = bench.choose_dt(case.topo, case.n_victims, 8 << 20, case.lat())
        params = case.cell_params(8 << 20, cong.steady(), dt)
        out = search.gradient_refine(case.geom, params,
                                     ["md", "rai_frac", "kmin"],
                                     steps=4 if quick else 10)
        print(f"  refined knobs: {out['knobs']}")
        print(f"  objective history: "
              f"{[f'{h:.3g}' for h in out['history']]}")

    print(f"\n[mitigation_lab] done in {time.time() - t0:.0f}s")
    ok = ok_fig4 and ok_cc and ok_saw
    if not ok:
        print("[mitigation_lab] FAILED checks", file=sys.stderr)
        sys.exit(1)
    return {"scores": scores, "frontier": front, "winner": winner}


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    p.add_argument("--grad", action="store_true",
                   help="run the gradient-descent refinement tier")
    a = p.parse_args()
    main(quick=a.quick, grad=a.grad)
