"""Paper Fig. 4: NSLB on/off under steady AlltoAll congestion (4 victim +
4 aggressor nodes on the Nanjing CE9855 leaf-spine)."""
from __future__ import annotations

from benchmarks.common import cached_sweep, size_label
from repro.core import bench, congestion as cong
from repro.core.fabric import systems


def run_point(mode: str, vector_bytes: float) -> dict:
    sysp = systems.get_system("nanjing_nslb" if mode == "nslb"
                              else "nanjing_ecmp")
    r = bench.run_point(sysp, 8, "alltoall", "alltoall", vector_bytes,
                        cong.steady(), n_iters=25, warmup=5)
    return {
        "gbps_uncongested": 8e-9 * vector_bytes * (3 / 4)
        / r.t_uncongested_s,
        "gbps_congested": 8e-9 * vector_bytes * (3 / 4) / r.t_congested_s,
        "ratio": r.ratio,
    }


def main(force: bool = False, quick: bool = False):
    from repro.core import scenarios
    points = list(scenarios.get("fig4_nslb", quick).points)
    rows = cached_sweep("fig4_nslb", ["mode", "vector_bytes"], points,
                        run_point, force=force)
    print("\n# Fig. 4 — NSLB under steady AlltoAll congestion (4+4 nodes)")
    print(f"{'mode':>6} {'size':>8} {'uncong Gb/s':>12} {'cong Gb/s':>10} "
          f"{'ratio':>6}")
    for r in rows:
        print(f"{r['mode']:>6} {size_label(r['vector_bytes']):>8} "
              f"{float(r['gbps_uncongested']):>12.0f} "
              f"{float(r['gbps_congested']):>10.0f} "
              f"{float(r['ratio']):>6.2f}")
    on = min(float(r["ratio"]) for r in rows if r["mode"] == "nslb")
    off = max(float(r["ratio"]) for r in rows if r["mode"] == "ecmp")
    print(f"# Fig.4 check: NSLB worst ratio {on:.2f} (paper: ~1.0), "
          f"ECMP best {off:.2f} (paper: ~0.67) -> "
          f"{'REPRODUCED' if on > 0.9 and off < 0.85 else 'MISMATCH'}")
    return rows


if __name__ == "__main__":
    main()
