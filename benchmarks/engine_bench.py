"""Engine perf trajectory: step-core kernel vs XLA lax path (ISSUE 6).

Benchmarks the simulator's per-step hot core at LUMI-scale pruned
geometries (16 -> 4096 nodes) and records the trajectory artifact
``BENCH_engine.json`` (ROADMAP item 2): per-cell wall-clock, steps/sec,
compile time, and the kernel-vs-lax step-time ratio, so later PRs can
prove (or catch regressions in) engine speedups.

Per scale it measures:

* ``cell`` — a real ``run_cell`` call on the production backend for this
  host (CPU container -> ref): wall-clock, executed steps, steps/sec and
  compile time. This is the number a characterization sweep pays per
  grid cell.
* ``step`` — a fixed-length jitted ``lax.scan`` of the step under each
  backend (``ref`` = XLA scatter path, ``pallas`` = fused kernel), best
  of ``--repeats``; ``kernel_vs_lax = ref_s / pallas_s`` (> 1 means the
  kernel wins). Off-TPU the kernel runs through the Pallas INTERPRETER,
  so the CPU ratio only tracks relative drift — the ``interpret`` flag
  is recorded so readers do not mistake it for TPU performance.
* ``parity`` — lock-step state comparison ref vs pallas (fp32-allclose,
  DESIGN.md §13); any mismatch fails the run (exit 1).

``--check-against BENCH_engine.json`` compares the hardware-normalized
``kernel_vs_lax`` ratio per scale against the committed artifact and
fails on > ``--regress-margin`` (default 10%) relative regression — the
CI smoke gate. Checking never rewrites the artifact; a plain run (or
``--write``) does.

Usage:
  PYTHONPATH=src python -m benchmarks.engine_bench            # full, writes
  PYTHONPATH=src python -m benchmarks.engine_bench --quick \
      --check-against BENCH_engine.json                       # CI smoke
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bench, congestion as cong
from repro.core.fabric import simulator as sim
from repro.core.fabric import systems

SCALES_FULL = (16, 64, 256, 1024, 4096)
SCALES_QUICK = (16, 64)
VECTOR_BYTES = 4 * 2 ** 20
# step-scan lengths tapered with scale: interpret-mode Pallas on CPU is
# emulation, the large scales only need enough steps for a stable ratio;
# the small (CI-gated) scales get long scans so the ratio is low-noise
N_STEPS = {16: 1024, 64: 512, 256: 64, 1024: 16, 4096: 8}
CELL_CHUNKS = {16: 12, 64: 12, 256: 8, 1024: 4, 4096: 2}
PARITY_STEPS = 8
FS_TOL = dict(rtol=2e-4, atol=1.0)


def _build(sysp, n_nodes):
    """LUMI allocation at ``n_nodes``; beyond the machine (4096 > 2978)
    a synthetic same-family fabric is built at the requested size."""
    machine = sysp.machine_nodes or n_nodes
    if n_nodes > machine:
        case = bench.build_case(sysp, n_nodes, "ring_allreduce", "incast",
                                topo=sysp.make_topology(n_nodes),
                                nodes=np.arange(n_nodes))
    else:
        case = bench.build_case(sysp, n_nodes, "ring_allreduce", "incast")
    dt = bench.choose_dt(case.topo, case.n_victims, VECTOR_BYTES,
                         case.lat(), case.max_phases)
    params = case.cell_params(VECTOR_BYTES, cong.steady(), dt)
    return case.geom, params, dt


def _time_best(fn, repeats):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _step_scan(geom, params, backend, n_steps):
    @jax.jit
    def run(state):
        return jax.lax.scan(
            lambda s, _: sim.step(geom, params, s, backend=backend),
            state, None, length=n_steps)
    return run


def _measure_step(geom, params, backend, n_steps, repeats):
    run = _step_scan(geom, params, backend, n_steps)
    state = sim.init_state(geom, params)
    t0 = time.perf_counter()
    out = run(state)
    jax.block_until_ready(out)
    first = time.perf_counter() - t0
    steady = _time_best(
        lambda: jax.block_until_ready(run(state)), repeats)
    return {"total_s": round(steady, 6),
            "per_step_s": round(steady / n_steps, 9),
            "compile_s": round(max(first - steady, 0.0), 3),
            "n_steps": n_steps}


def _measure_cell(geom, params, n_nodes, repeats):
    kw = dict(chunk=256, max_chunks=CELL_CHUNKS[n_nodes], stride=8)
    n_iters = jnp.asarray(4, jnp.int32)

    def go():
        return jax.block_until_ready(
            sim.run_cell(geom, params, n_iters, **kw))
    t0 = time.perf_counter()
    out = go()
    first = time.perf_counter() - t0
    steady = _time_best(go, repeats)
    steps = int(np.asarray(out["chunks"])) * kw["chunk"]
    return {"wall_s": round(steady, 4),
            "compile_s": round(max(first - steady, 0.0), 3),
            "steps": steps,
            "steps_per_sec": round(steps / steady, 1)}


def _check_parity(geom, params):
    s_ref = jax.jit(lambda s: sim.step_debug(geom, params, s,
                                             backend="ref"))
    s_pal = jax.jit(lambda s: sim.step_debug(geom, params, s,
                                             backend="pallas"))
    state = sim.init_state(geom, params)
    for i in range(PARITY_STEPS):
        nr, gr, ar = s_ref(state)
        npal, gpal, apal = s_pal(state)
        for k in nr:
            if not np.allclose(np.asarray(npal[k]), np.asarray(nr[k]),
                               **FS_TOL):
                return f"MISMATCH state[{k}] step {i}"
        for k in ar:
            if not np.allclose(np.asarray(apal[k]), np.asarray(ar[k]),
                               **FS_TOL):
                return f"MISMATCH aux[{k}] step {i}"
        state = nr
    return "OK"


def run_scales(scales, repeats):
    sysp = systems.get_system("lumi")
    rows = []
    for n in scales:
        geom, params, dt = _build(sysp, n)
        dims = sim.geometry_dims(geom)
        n_steps = N_STEPS[n]
        parity = _check_parity(geom, params)
        cell = _measure_cell(geom, params, n, repeats)
        step_ref = _measure_step(geom, params, "ref", n_steps, repeats)
        step_pal = _measure_step(geom, params, "pallas", n_steps, repeats)
        ratio = step_ref["per_step_s"] / step_pal["per_step_s"]
        rows.append({
            "n_nodes": n, "dt_s": dt,
            "dims": {"n_flows": dims.n_flows, "n_links": dims.n_links,
                     "k_max": dims.k_max, "max_hops": dims.max_hops,
                     "n_sw": dims.n_sw, "n_src": dims.n_src},
            "cell": cell,
            "step": {"ref_per_step_s": step_ref["per_step_s"],
                     "pallas_per_step_s": step_pal["per_step_s"],
                     "ref_compile_s": step_ref["compile_s"],
                     "pallas_compile_s": step_pal["compile_s"],
                     "n_steps": n_steps,
                     "kernel_vs_lax": round(ratio, 4)},
            "parity": parity,
        })
        print(f"  n={n:5d}  F={dims.n_flows:5d} L={dims.n_links:6d} "
              f"cell={cell['wall_s']:.3f}s ({cell['steps_per_sec']:.0f} "
              f"steps/s)  step ref={step_ref['per_step_s']*1e3:.3f}ms "
              f"pallas={step_pal['per_step_s']*1e3:.3f}ms "
              f"ratio={ratio:.3f}  parity={parity}")
    return rows


def check_against(rows, committed_path, margin):
    """Compare the hardware-normalized kernel_vs_lax ratio per scale;
    absolute times are machine-dependent and never gated."""
    committed = json.loads(Path(committed_path).read_text())
    old = {r["n_nodes"]: r["step"]["kernel_vs_lax"]
           for r in committed["scales"]}
    failures = []
    for r in rows:
        n = r["n_nodes"]
        if n not in old:
            continue
        new = r["step"]["kernel_vs_lax"]
        if new < old[n] * (1.0 - margin):
            failures.append(f"n={n}: kernel_vs_lax {new:.3f} < committed "
                            f"{old[n]:.3f} - {margin:.0%}")
        else:
            print(f"  n={n}: kernel_vs_lax {new:.3f} vs committed "
                  f"{old[n]:.3f} — OK")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="small scales only (CI smoke)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="timing repeats (best-of)")
    ap.add_argument("--out", default="BENCH_engine.json")
    ap.add_argument("--check-against", default=None, metavar="JSON",
                    help="compare kernel_vs_lax per scale against a "
                    "committed artifact; fail on regression")
    ap.add_argument("--regress-margin", type=float, default=0.10,
                    help="allowed relative ratio regression (default 10%%)")
    ap.add_argument("--write", action="store_true",
                    help="write --out even in --check-against mode")
    ap.add_argument("--sharded", action="store_true",
                    help="also run the sharded-launch smoke "
                    "(launch/sweep.py): single vs sharded cold vs "
                    "sharded warm children; rows land under "
                    "result['sharded']")
    ap.add_argument("--sharded-devices", type=int, default=8,
                    help="forced host device count for --sharded")
    args = ap.parse_args(argv)

    scales = SCALES_QUICK if args.quick else SCALES_FULL
    print(f"engine_bench: lumi scales={scales} "
          f"backend={jax.default_backend()} (pallas interpret="
          f"{jax.default_backend() != 'tpu'})")
    t0 = time.time()
    rows = run_scales(scales, args.repeats)
    result = {
        "schema": 1,
        "system": "lumi",
        "victim_coll": "ring_allreduce",
        "aggressor": "incast",
        "vector_bytes": VECTOR_BYTES,
        "jax_backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() != "tpu",
        "quick": args.quick,
        "wall_s": round(time.time() - t0, 1),
        "scales": rows,
    }

    sharded_ok = True
    if args.sharded:
        # children force their own host-device count; this process keeps
        # its backend untouched (run_smoke only orchestrates subprocesses)
        from repro.launch.sweep import run_smoke
        print(f"sharded-launch smoke: {args.sharded_devices} host devices "
              "(single vs sharded-cold vs sharded-warm children)")
        report = run_smoke(args.sharded_devices)
        result["sharded"] = report
        sharded_ok = report["ok"]
        for k in ("single", "sharded_cold", "sharded_warm"):
            r = report[k]
            print(f"  {k:13s} dev={r['n_devices']} "
                  f"wall={r['wall_first_s']:.2f}s "
                  f"steady={r['wall_second_s']:.2f}s "
                  f"compile={r['compile_s']:.2f}s "
                  f"cache={r['cache_hits']}h/{r['cache_misses']}m")
        print(f"  checks: {report['checks']}")

    bad_parity = [r["n_nodes"] for r in rows if r["parity"] != "OK"]
    failures = []
    if args.check_against:
        failures = check_against(rows, args.check_against,
                                 args.regress_margin)
    if args.write or not args.check_against:
        Path(args.out).write_text(json.dumps(result, indent=1) + "\n")
        print(f"wrote {args.out}")
    if bad_parity:
        print(f"PARITY MISMATCH at scales {bad_parity}", file=sys.stderr)
        return 1
    if failures:
        print("PERF REGRESSION:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        return 1
    if not sharded_ok:
        print("SHARDED-LAUNCH SMOKE FAILED (see checks above)",
              file=sys.stderr)
        return 1
    print("engine_bench: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
