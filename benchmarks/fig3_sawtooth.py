"""Paper Fig. 3 / Obs. 1: CE8850 self-congestion sawtooth on large-message
AllGather; EDR InfiniBand (same nodes) and CE9855 stay stable."""
from __future__ import annotations

import numpy as np

from benchmarks.common import cached_sweep, size_label
from repro.core import bench
from repro.core.fabric import systems

SYSTEMS = ("haicgu_ce8850", "haicgu_ib", "nanjing_nslb")


def _spark(tr: np.ndarray, width: int = 64) -> str:
    if len(tr) == 0:
        return ""
    idx = np.linspace(0, len(tr) - 1, width).astype(int)
    t = tr[idx]
    lo, hi = t.min(), t.max()
    blocks = "▁▂▃▄▅▆▇█"
    span = max(hi - lo, 1e-9)
    return "".join(blocks[int((v - lo) / span * 7.999)] for v in t)


def run_point(system: str, vector_bytes: float) -> dict:
    res = bench.goodput_trace(systems.get_system(system), 4,
                              "ring_allgather", vector_bytes, n_iters=25)
    tr = res.victim_rate_trace
    tr = tr[len(tr) // 3:]
    tr = tr[tr > 0]
    return {
        "goodput_gbps": float(tr.mean() * 8 / 1e9) if len(tr) else 0.0,
        "cv": float(tr.std() / tr.mean()) if len(tr) else 0.0,
        "spark": _spark(tr),
    }


def main(force: bool = False, quick: bool = False):
    from repro.core import scenarios
    points = list(scenarios.get("fig3_sawtooth", quick).points)
    rows = cached_sweep("fig3_sawtooth", ["system", "vector_bytes"], points,
                        run_point, force=force)
    print("\n# Fig. 3 — self-congestion stability, 4-node AllGather")
    print(f"{'system':>16} {'size':>8} {'Gb/s':>7} {'CV':>6}  goodput trace")
    for r in rows:
        print(f"{r['system']:>16} {size_label(r['vector_bytes']):>8} "
              f"{float(r['goodput_gbps']):>7.0f} {float(r['cv']):>6.3f}  "
              f"{r['spark']}")
    ce = max(float(r["cv"]) for r in rows if r["system"] == "haicgu_ce8850")
    others = max(float(r["cv"]) for r in rows
                 if r["system"] != "haicgu_ce8850")
    print(f"# Obs.1 check: CE8850 CV {ce:.3f} vs others max {others:.3f} "
          f"-> sawtooth {'REPRODUCED' if ce > 2.5 * others else 'ABSENT'}")
    return rows


if __name__ == "__main__":
    main()
