"""whisper-tiny — encoder-decoder audio backbone. [arXiv:2212.04356]

Conv audio frontend is a stub per the brief: ``input_specs()`` supplies
precomputed frame embeddings (B, 1500, 384). The assigned shapes apply to the
decoder token stream (stress-lowering configs; Whisper's real max is 448 —
noted in DESIGN.md). 6 heads do not divide 16-way TP: heads replicated.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    act="gelu",
    enc_layers=4,
    n_frontend_tokens=1500,
    source="arXiv:2212.04356; unverified",
)
