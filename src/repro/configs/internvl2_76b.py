"""internvl2-76b — InternViT + InternLM2 VLM. [arXiv:2404.16821; unverified]

Backbone only per the brief: the ViT frontend is a stub; ``input_specs()``
supplies precomputed patch embeddings prepended to the token stream.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    act="swiglu",
    n_frontend_tokens=256,
    pod_param_sharding="fsdp",
    optimizer="adafactor_m",
    source="arXiv:2404.16821; unverified",
)
