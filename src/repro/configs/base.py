"""Architecture and input-shape configuration for the repro framework.

Every assigned architecture is expressed as an :class:`ArchConfig`.  The full
configs are exercised only through the multi-pod dry-run (abstract lowering —
no allocation); smoke tests use :meth:`ArchConfig.reduced`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# --------------------------------------------------------------------------
# Shapes
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (seq_len x global_batch) and which step it lowers."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# --------------------------------------------------------------------------
# Architectures
# --------------------------------------------------------------------------


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    act: str = "swiglu"  # swiglu | relu2 | gelu
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_sharding: str = "ep"  # "ep" (experts over dp, all-to-all) | "2d" (TP)
    capacity_factor: float = 1.25
    # --- SSM (mamba / hybrid) ---
    ssm_state: int = 0
    d_inner: int = 0  # 0 -> 2*d_model when ssm is used
    conv_width: int = 4
    dt_rank: int = 0  # 0 -> ceil(d_model/16)
    # --- hybrid ---
    sliding_window: int = 0  # >0: SWA attention (enables long-context decode)
    # --- frontends (stubs per the brief) ---
    n_frontend_tokens: int = 0  # vlm patches / audio frames
    enc_layers: int = 0  # >0: encoder-decoder (whisper)
    # --- system knobs ---
    long_context_ok: bool = False  # whether long_500k applies
    pod_param_sharding: str = "replicate"  # "replicate" | "fsdp"
    optimizer: str = "adamw"  # "adamw" | "adafactor_m"
    remat: str = "full"  # "full" | "dots" | "none"
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_chunk: int = 1024  # KV chunk for the blockwise (flash) attention path
    score_dtype: str = "float32"  # attention score/probability dtype
    seq_shard: bool = False  # sequence-sharded residual stream (SP)
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // max(self.n_heads, 1)

    @property
    def resolved_d_inner(self) -> int:
        if self.ssm_state == 0:
            return 0
        return self.d_inner or 2 * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        if self.ssm_state == 0:
            return 0
        return self.dt_rank or _round_up(self.d_model // 16, 16)

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 so it shards over 16-way TP."""
        return _round_up(self.vocab_size, 256)

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def shapes(self) -> Tuple[ShapeConfig, ...]:
        """The shape cells that apply to this architecture.

        ``long_500k`` is skipped for pure full-attention archs per the brief
        (sub-quadratic attention is not part of those archs' definitions);
        the skip list is documented in DESIGN.md §6.
        """
        out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
        if self.long_context_ok:
            out.append(SHAPES["long_500k"])
        return tuple(out)

    def all_cells(self) -> Tuple[Tuple[str, str], ...]:
        """(arch, shape) pairs including documented skips."""
        return tuple((self.name, s.name) for s in SHAPES.values())

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_padded * d * (1 if self.family == "ssm" else 2)
        per_layer = 0
        if self.family != "ssm":
            # attention (q, k, v, o)
            per_layer += d * self.n_heads * hd * 2  # q + o
            per_layer += d * self.n_kv_heads * hd * 2  # k + v
        if self.n_experts:
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * 3 * d * self.d_ff
        elif self.d_ff:
            n_mats = 3 if self.act == "swiglu" else 2
            per_layer += n_mats * d * self.d_ff
        if self.ssm_state:
            di, r, n = self.resolved_d_inner, self.resolved_dt_rank, self.ssm_state
            per_layer += d * 2 * di  # in_proj (x, z)
            per_layer += di * self.conv_width  # conv
            per_layer += di * (r + 2 * n)  # x_proj
            per_layer += r * di + di  # dt_proj
            per_layer += di * n + di  # A_log, D
            per_layer += di * d  # out_proj
        total = emb + self.n_layers * per_layer
        if self.is_encdec:
            # encoder layers (full attn + mlp) + decoder cross-attention
            enc_layer = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
            enc_layer += (3 if self.act == "swiglu" else 2) * d * self.d_ff
            cross = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
            total += self.enc_layers * enc_layer + self.n_layers * cross
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        moe = self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff
        active = self.n_layers * self.top_k * 3 * self.d_model * self.d_ff
        return full - moe + active

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        return dataclasses.replace(
            self,
            n_layers=2,
            enc_layers=min(self.enc_layers, 2),
            d_model=64,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 8),
            d_inner=128 if self.ssm_state else 0,
            dt_rank=8 if self.ssm_state else 0,
            sliding_window=min(self.sliding_window, 32),
            n_frontend_tokens=min(self.n_frontend_tokens, 8),
            remat="none",
            param_dtype="float32",
            compute_dtype="float32",
            attn_chunk=16,
        )
