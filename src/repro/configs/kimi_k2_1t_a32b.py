"""kimi-k2-1t-a32b — 384-expert top-8 trillion-param MoE. [arXiv:2501.kimi2]

The paper-representative cell: EP expert dispatch is an explicit all-to-all
over the data-parallel axis (the paper's AlltoAll congestion pattern).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163840,
    head_dim=112,
    act="swiglu",
    n_experts=384,
    top_k=8,
    moe_sharding="ep",
    pod_param_sharding="fsdp",
    optimizer="adafactor_m",
    source="arXiv:2501.kimi2; unverified",
)
