"""Architecture registry: ``--arch <id>`` resolves through :func:`get_config`."""
from __future__ import annotations

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig
from repro.configs import (
    falcon_mamba_7b,
    granite_20b,
    grok_1_314b,
    hymba_1_5b,
    internvl2_76b,
    kimi_k2_1t_a32b,
    nemotron_4_15b,
    phi3_mini_3_8b,
    whisper_tiny,
    yi_6b,
)

_ALL = (
    grok_1_314b.CONFIG,
    kimi_k2_1t_a32b.CONFIG,
    phi3_mini_3_8b.CONFIG,
    yi_6b.CONFIG,
    granite_20b.CONFIG,
    nemotron_4_15b.CONFIG,
    internvl2_76b.CONFIG,
    hymba_1_5b.CONFIG,
    whisper_tiny.CONFIG,
    falcon_mamba_7b.CONFIG,
)

REGISTRY: dict[str, ArchConfig] = {c.name: c for c in _ALL}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def all_arch_names() -> tuple[str, ...]:
    return tuple(REGISTRY)


__all__ = [
    "ArchConfig",
    "ShapeConfig",
    "SHAPES",
    "REGISTRY",
    "get_config",
    "all_arch_names",
]
