"""falcon-mamba-7b — attention-free mamba-1. [arXiv:2410.05355; unverified]

Attention-free: the paper's attention-side congestion patterns are
inapplicable (DESIGN.md §6); O(1) decode state makes ``long_500k`` runnable.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    d_inner=8192,
    conv_width=4,
    long_context_ok=True,
    source="arXiv:2410.05355; unverified",
)
