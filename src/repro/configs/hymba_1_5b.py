"""hymba-1.5b — parallel attention + mamba heads, SWA. [arXiv:2411.13676; hf]

25 heads do not divide the 16-wide TP axis: attention heads are replicated
over ``model`` (only FFN/SSM inner dims are TP-sharded). SSM state + sliding
window attention make ``long_500k`` runnable (sub-quadratic).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    act="swiglu",
    ssm_state=16,
    d_inner=3200,
    sliding_window=1024,
    long_context_ok=True,
    source="arXiv:2411.13676; hf",
)
