"""Beyond-paper optimization variants (EXPERIMENTS.md §Perf).

``--variant opt`` on the dry-run applies these per-arch config overrides on
top of the paper-faithful baseline; results land in artifacts/dryrun_opt/.
Code-level improvements (flash-attention chunk remat W1, redundant-where
elimination, iota-select cross-entropy W5, S-shard-pinned QKV projections
K4/G5, bf16-wire MoE reductions G4) apply to the baseline path as well and
are measured step-by-step in the §Perf iteration log.

Measured deltas on the train_4k bound (single-pod, consistent accounting):
    kimi-k2:  62.6s -> 39.6s  (collective 62.6 -> 14.4s)
    grok-1:   39.6s -> 37.8s  (compute 10.7 -> 7.7s)
    phi3:     15.7s ->  7.2s  (fits HBM: 39 GB -> 9 GB)
    yi-6b:     9.5s ->  6.5s
    granite:  25.4s -> 17.5s
    internvl2:56.6s -> 41.9s
Refuted along the way (kept out): bf16 attention scores (convert
boundaries cost more than they save on the XLA path), remat="none"
(scan-residual stacking), "2d_full" full-d dispatch for grok (16x per-rank
up-projection flops).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig

# per-arch overrides for the "opt" variant
_OPT: Dict[str, dict] = {
    # K-series: full-EP MoE with sequence-sharded tokens (a2a payload
    # shrinks 16x, the fp32 TP reduce-scatter disappears); factored
    # optimizer for the 1T-param state
    "kimi-k2-1t-a32b": dict(moe_sharding="ep_sp", seq_shard=True,
                            optimizer="adafactor_m"),
    # G-series: sequence-sharded residual stream; MoE stays "2d" with the
    # (code-level) bf16-wire psums
    "grok-1-314b": dict(seq_shard=True),
    # SSM state is sequential along S — seq_shard inapplicable
    "falcon-mamba-7b": dict(),
    "hymba-1.5b": dict(),
    # enc-dec path gets its sequence-TP attention pins at code level
    "whisper-tiny": dict(),
}

# dense / vlm LMs: sequence-sharded residual stream is a pure win
# (W4-style: activations, attention traffic and qkv backward all drop)
_DEFAULT = dict(seq_shard=True)

VARIANTS = {"opt": (_OPT, _DEFAULT)}


def apply_variant(cfg: ArchConfig, variant: str) -> ArchConfig:
    per_arch, default = VARIANTS[variant]
    return dataclasses.replace(cfg, **per_arch.get(cfg.name, default))
