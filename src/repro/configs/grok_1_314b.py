"""grok-1-314b — 8-expert top-2 MoE. [hf:xai-org/grok-1; unverified]

8 experts do not divide the 16-wide ``data`` axis, so EP all-to-all sharding is
inapplicable; experts use 2D TP (d_model->data, d_ff->model). See DESIGN.md §6.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    act="swiglu",
    n_experts=8,
    top_k=2,
    moe_sharding="2d",
    pod_param_sharding="fsdp",
    optimizer="adafactor_m",
    source="hf:xai-org/grok-1; unverified",
)
