"""Fault tolerance for 1000+-node runs: step monitoring, straggler
detection, failure simulation, and elastic rescale planning.

The single-host container cannot kill real nodes, so the machinery is
exercised through injectable clocks/failure hooks (tests/test_runtime.py);
the decision logic — what a production deployment would run on the
coordinator — is the real, tested artifact.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple


@dataclasses.dataclass
class StepStats:
    step: int
    duration_s: float
    flagged: bool


class StepMonitor:
    """EMA-based per-step deadline monitor (straggler detection).

    A step slower than ``threshold`` x the EMA flags a straggler;
    ``trip_after`` consecutive flags trips the monitor (the signal a
    coordinator would use to trigger elastic rescale or node replacement).
    """

    def __init__(self, threshold: float = 2.5, trip_after: int = 3,
                 ema: float = 0.9, clock: Callable[[], float] = time.monotonic):
        self.threshold = threshold
        self.trip_after = trip_after
        self.ema_factor = ema
        self.clock = clock
        self.ema_s: Optional[float] = None
        self.consecutive = 0
        self.history: List[StepStats] = []
        self._t0: Optional[float] = None

    def start_step(self):
        self._t0 = self.clock()

    def end_step(self, step: int) -> StepStats:
        assert self._t0 is not None, "start_step not called"
        dur = self.clock() - self._t0
        self._t0 = None
        flagged = False
        if self.ema_s is not None and dur > self.threshold * self.ema_s:
            flagged = True
            self.consecutive += 1
            # a straggling step must not poison the baseline
        else:
            self.consecutive = 0
            self.ema_s = (dur if self.ema_s is None
                          else self.ema_factor * self.ema_s
                          + (1 - self.ema_factor) * dur)
        st = StepStats(step, dur, flagged)
        self.history.append(st)
        return st

    @property
    def tripped(self) -> bool:
        return self.consecutive >= self.trip_after

    def reset(self, rebaseline: bool = True, window: int = 5):
        """Clear the tripped state after a coordinator action (elastic
        rescale, node swap).

        Flagged steps never feed the EMA (a straggler must not poison the
        baseline), so after a rescale to a *legitimately* slower steady
        state every step keeps flagging against the stale pre-rescale
        baseline and the monitor stays tripped forever. ``rebaseline``
        re-seeds the EMA from the mean of the last ``window`` recorded
        steps — the new steady state; ``rebaseline=False`` cold-starts
        the baseline like a fresh monitor.
        """
        self.consecutive = 0
        self._t0 = None
        if not rebaseline:
            self.ema_s = None
        elif self.history:
            recent = [st.duration_s for st in self.history[-window:]]
            self.ema_s = float(sum(recent) / len(recent))


class FailureInjector:
    """Deterministic failure schedule for tests: fail at given steps."""

    def __init__(self, fail_at: Tuple[int, ...] = ()):
        self.fail_at = set(fail_at)
        self.failures = 0

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failures += 1
            raise NodeFailure(f"injected node failure at step {step}")


class NodeFailure(RuntimeError):
    pass


def elastic_plan(n_healthy: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid on the surviving devices.

    Keeps the model axis intact (weights are TP-sharded across it; shrinking
    it would need a different weight partitioning), and drops data-parallel
    replicas to the largest multiple that fits — the standard elastic
    response to losing hosts.
    """
    if n_healthy < model_parallel:
        raise ValueError(
            f"cannot keep model_parallel={model_parallel} with only "
            f"{n_healthy} devices")
    return n_healthy // model_parallel, model_parallel


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 10
    backoff_s: float = 0.0  # container tests keep this 0

    def __post_init__(self):
        self.restarts = 0

    def should_restart(self) -> bool:
        # check before mutating: a denied call must not burn budget, so
        # probing the policy after exhaustion stays False forever instead
        # of sliding restarts past max_restarts
        if self.restarts >= self.max_restarts:
            return False
        self.restarts += 1
        if self.backoff_s:
            time.sleep(self.backoff_s * min(self.restarts, 5))
        return True
