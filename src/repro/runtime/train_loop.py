"""Production training loop: microbatching, async checkpoint/restart,
straggler monitoring, failure recovery with elastic rescale.

The loop is deliberately host-driven (python around a jitted step) — the
structure a real multi-pod launcher has — with every policy injectable so
the integration tests can run it end-to-end on CPU in seconds.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh, rules_for
from repro.launch.steps import (init_train_state, make_train_step,
                                train_state_specs)
from repro.models.api import build_model
from repro.optim.adamw import OptConfig, get_optimizer
from repro.runtime import fault


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    microbatches: int = 1  # gradient-accumulation factor
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    log_every: int = 10
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)
    seed: int = 0
    straggler_threshold: float = 4.0
    max_restarts: int = 4


def make_microbatched_train_step(model, optimizer, n_micro: int):
    """Gradient accumulation: scan over microbatches, then one update.

    The batch's leading dim is split (n_micro, B/n_micro, ...); gradients
    accumulate in fp32. Peak activation memory drops ~n_micro-fold while
    the optimizer still sees the full-batch gradient.
    """
    if n_micro == 1:
        return make_train_step(model, optimizer)

    def split(x):
        B = x.shape[0]
        assert B % n_micro == 0, (B, n_micro)
        return x.reshape((n_micro, B // n_micro) + x.shape[1:])

    def train_step(state, batch):
        micro = jax.tree.map(split, batch)
        params = state["params"]
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def one(carry, mb):
            acc, aux_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                               acc, grads)
            return (acc, aux_acc + loss), metrics

        (gsum, loss_sum), metrics = jax.lax.scan(
            one, (zeros, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        new_params, new_opt, gnorm = optimizer.update(
            grads, state["opt"], params, state["step"])
        out_metrics = {
            "loss": metrics["loss"].mean(),
            "aux_loss": metrics["aux_loss"].mean(),
            "total_loss": loss_sum / n_micro,
            "grad_norm": gnorm,
        }
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, out_metrics

    return train_step


class Trainer:
    """Drives one model on one mesh; survives injected failures by
    restoring the latest checkpoint (optionally on a smaller mesh)."""

    def __init__(self, arch_cfg, tc: TrainConfig, mesh=None,
                 dataset=None, failure_injector=None):
        self.arch_cfg = arch_cfg
        self.tc = tc
        self.mesh = mesh or make_host_mesh()
        self.failure_injector = failure_injector or fault.FailureInjector()
        self.monitor = fault.StepMonitor(threshold=tc.straggler_threshold)
        self.dataset = dataset
        self.metrics_log: List[Dict] = []
        self.restarts = 0
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        cfg, tc = self.arch_cfg, self.tc
        self.rules = rules_for(cfg, self.mesh)
        self.model = build_model(cfg, self.rules, self.mesh)
        self.optimizer = get_optimizer(cfg.optimizer, tc.opt)
        self.step_fn = jax.jit(make_microbatched_train_step(
            self.model, self.optimizer, tc.microbatches))
        self.state_specs = train_state_specs(self.model, self.optimizer)
        if self.dataset is None:
            self.dataset = SyntheticLM(DataConfig(
                vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                seed=tc.seed))
        self.ckpt = (ckpt.AsyncCheckpointer(tc.ckpt_dir, keep=tc.ckpt_keep)
                     if tc.ckpt_dir else None)

    def _init_or_restore(self):
        tc = self.tc
        start = 0
        if tc.ckpt_dir and (s := ckpt.latest_step(tc.ckpt_dir)) is not None:
            shapes = {
                "params": self.model.param_shapes,
                "opt": self.optimizer.state_shapes(self.model.param_shapes),
                "step": jax.ShapeDtypeStruct((), jnp.int32)}
            state = ckpt.restore(tc.ckpt_dir, shapes, mesh=self.mesh,
                                 specs=self.state_specs)
            start = int(state["step"])
        else:
            state = init_train_state(self.model, self.optimizer,
                                     jax.random.PRNGKey(tc.seed))
        return state, start

    # ------------------------------------------------------------------
    def run(self) -> Dict:
        tc = self.tc
        policy = fault.RestartPolicy(max_restarts=tc.max_restarts)
        while True:
            try:
                return self._run_once()
            except fault.NodeFailure:
                self.restarts += 1
                if not policy.should_restart():
                    raise
                # recovery: wait for in-flight checkpoint, rebuild, resume
                if self.ckpt:
                    self.ckpt.wait()

    def _run_once(self) -> Dict:
        tc = self.tc
        state, start = self._init_or_restore()
        with jax.set_mesh(self.mesh):
            for step in range(start, tc.total_steps):
                self.failure_injector.check(step)
                self.monitor.start_step()
                batch = jax.tree.map(jnp.asarray,
                                     self.dataset.batch_at(step))
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["total_loss"])  # sync point
                st = self.monitor.end_step(step)
                if not np.isfinite(loss):
                    raise FloatingPointError(f"loss diverged at {step}")
                rec = {"step": step, "loss": loss,
                       "grad_norm": float(metrics["grad_norm"]),
                       "step_s": st.duration_s,
                       "straggler": st.flagged}
                self.metrics_log.append(rec)
                next_step = step + 1
                if self.ckpt and (next_step % tc.ckpt_every == 0
                                  or next_step == tc.total_steps):
                    self.ckpt.save(next_step,
                                   dict(state, step=jnp.int32(next_step)),
                                   specs=self.state_specs,
                                   extra_meta={"loss": loss})
        if self.ckpt:
            self.ckpt.wait()
        losses = [m["loss"] for m in self.metrics_log]
        return {"final_loss": losses[-1] if losses else float("nan"),
                "first_loss": losses[0] if losses else float("nan"),
                "steps_run": len(self.metrics_log),
                "restarts": self.restarts,
                "stragglers": sum(m["straggler"] for m in self.metrics_log),
                "log": self.metrics_log}
