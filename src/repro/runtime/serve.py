"""Batched serving runtime: KV-cache slot pool, wave scheduling, greedy /
temperature sampling, continuous request admission.

The model API decodes a whole batch at one shared position, so requests are
scheduled in *waves*: a wave admits up to ``max_batch`` queued requests,
right-pads their prompts to the wave's prompt length, prefills once, then
decodes until every member finishes (EOS or its token budget). Per-request
bookkeeping (actual prompt length, emitted tokens, finish reason) is
tracked by the slot pool. This wave design is noted in DESIGN.md — a
per-request-position decode (paged attention) is the natural next step on
real hardware, but the wave scheduler already exercises the serving-side
collectives the paper's Incast pattern maps to (batched fan-in at the
coordinator).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    temperature: float = 0.0
    submitted_s: float = 0.0
    # filled at completion
    tokens: Optional[np.ndarray] = None
    finish_reason: str = ""
    latency_s: float = 0.0


@dataclasses.dataclass
class ServerStats:
    requests_done: int = 0
    tokens_generated: int = 0
    waves: int = 0
    decode_steps: int = 0
    wall_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_generated / self.wall_s if self.wall_s else 0.0


class BatchedServer:
    def __init__(self, model, params, *, max_batch: int = 8,
                 max_seq: int = 512, eos_id: int = -1, pad_id: int = 0,
                 seed: int = 0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.queue: Deque[Request] = deque()
        self.done: List[Request] = []
        self.stats = ServerStats()
        self._uid = 0
        self._rng = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 32,
               temperature: float = 0.0) -> int:
        self._uid += 1
        self.queue.append(Request(
            uid=self._uid, prompt=np.asarray(prompt, np.int32),
            max_new_tokens=max_new_tokens, temperature=temperature,
            submitted_s=time.monotonic()))
        return self._uid

    # ------------------------------------------------------------------
    def _pad_cache(self, cache, prompt_len: int, target_len: int):
        cfg = self.model.cfg
        extra = target_len - prompt_len
        if extra <= 0 or cfg.sliding_window:
            return cache

        def pad(path, x):
            key = str(getattr(path[-1], "key", path[-1]))
            if key in ("k", "v") and x.ndim == 5 \
                    and x.shape[2] == prompt_len:
                return jnp.pad(
                    x, ((0, 0), (0, 0), (0, extra), (0, 0), (0, 0)))
            return x

        return jax.tree_util.tree_map_with_path(pad, cache)

    def _sample(self, logits, temperature: float):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        self._rng, k = jax.random.split(self._rng)
        return jax.random.categorical(k, logits / temperature, axis=-1)

    def _make_batch_inputs(self, wave: List[Request], S: int) -> dict:
        B = len(wave)
        toks = np.full((B, S), self.pad_id, np.int32)
        for i, r in enumerate(wave):
            toks[i, : len(r.prompt)] = r.prompt[:S]
        batch = {"tokens": jnp.asarray(toks),
                 "labels": jnp.asarray(toks)}
        cfg = self.model.cfg
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        return batch

    # ------------------------------------------------------------------
    def step_wave(self) -> int:
        """Admit up to max_batch requests, run one full wave. Returns the
        number of requests completed."""
        if not self.queue:
            return 0
        t0 = time.monotonic()
        wave: List[Request] = []
        while self.queue and len(wave) < self.max_batch:
            wave.append(self.queue.popleft())
        B = len(wave)
        S = max(len(r.prompt) for r in wave)
        budget = max(r.max_new_tokens for r in wave)
        budget = min(budget, self.max_seq - S)
        batch = self._make_batch_inputs(wave, S)

        logits, cache = self._prefill(self.params, batch)
        cache = self._pad_cache(cache, S, S + budget)
        n_front = (self.model.cfg.n_frontend_tokens
                   if self.model.cfg.family == "vlm" else 0)

        out_tokens = np.full((B, budget), self.pad_id, np.int32)
        alive = np.ones((B,), bool)
        temperature = max(r.temperature for r in wave)
        next_tok = self._sample(logits, temperature)
        for t in range(budget):
            tok_np = np.asarray(next_tok, np.int32)
            for i, r in enumerate(wave):
                if alive[i]:
                    out_tokens[i, t] = tok_np[i]
                    if tok_np[i] == self.eos_id \
                            or t + 1 >= r.max_new_tokens:
                        alive[i] = False
                        r.finish_reason = ("eos" if tok_np[i] == self.eos_id
                                           else "length")
            self.stats.decode_steps += 1
            if not alive.any():
                break
            pos = jnp.int32(S + n_front + t)
            logits, cache = self._decode(
                self.params, cache, next_tok[:, None].astype(jnp.int32), pos)
            next_tok = self._sample(logits, temperature)

        wall = time.monotonic() - t0
        for i, r in enumerate(wave):
            n_gen = int((out_tokens[i] != self.pad_id).sum())
            r.tokens = out_tokens[i][: max(n_gen, 1)]
            r.latency_s = time.monotonic() - r.submitted_s
            if not r.finish_reason:
                r.finish_reason = "length"
            self.done.append(r)
            self.stats.requests_done += 1
            self.stats.tokens_generated += len(r.tokens)
        self.stats.waves += 1
        self.stats.wall_s += wall
        return B

    def run_until_drained(self, max_waves: int = 100) -> ServerStats:
        for _ in range(max_waves):
            if not self.queue:
                break
            self.step_wave()
        return self.stats
