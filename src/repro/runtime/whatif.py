"""Batched what-if serving layer: concurrent "tune my fabric" queries
coalesced into shared simulator waves (ROADMAP item 5, DESIGN.md §17).

This reworks the wave-batching shape of :mod:`repro.runtime.serve`
(queue -> admit -> pad -> one batched call -> per-request bookkeeping)
around the mitigation lab instead of a token decoder. A
:class:`WhatIfQuery` names a fabric question — system, scale, scenario
panel (victim/aggressor collectives, congestion profiles), a knob
subspace, and an evaluation budget — and the :class:`WhatIfServer`
answers it with the panel winner + Pareto frontier.

The economics: a single query's candidate generation underfills the
vmapped engine (``search.run_candidate_rows`` lanes are cheap compared
to a dispatch). The server therefore coalesces every active query's
next generation into ONE ``run_candidate_rows`` call per wave — queries
stack on the *cell* axis, their candidate batches ride the *lane* axis
(padded to the wave's widest row by repeating the last candidate; lanes
are independent under vmap, so padding is inert). Per-(cell, candidate)
results are BIT-IDENTICAL to running each query alone — asserted in
tests/test_whatif.py — because lane construction is per-(cell,
candidate) and the engine's vmapped ``while_loop`` lanes never
interact. Mixed-scale queries land in different power-of-two geometry
buckets inside the same call (bench.bucket_stack), reusing each
bucket's jit executable across waves.

Two candidate tiers per query:

* ``agent="grid"`` — a fixed candidate list (explicit, or the bounded
  ``agents.grid_candidates`` grid over the query's knobs), drained
  batch-by-batch.
* ``agent in agents.AGENTS`` — a learned search agent proposes each
  generation and observes scores; the server memoizes per-query scores
  by candidate label so re-proposals cost no lanes.

Budget exhaustion returns best-so-far (``finish_reason="budget"``);
a drained grid or converged agent returns ``"drained"``. Multi-device
meshes plug in via ``launch.sweep.whatif_launcher`` (lane sharding);
``cache_dir`` promotes the persistent XLA compile cache so a restarted
service skips compilation.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.core import congestion as cong
from repro.core.fabric.systems import get_system
from repro.core.mitigation import agents as agents_lib
from repro.core.mitigation import score as score_lib
from repro.core.mitigation import search
from repro.core.mitigation.agents import AGENT_KNOBS
from repro.core.mitigation.score import CandidateScore
from repro.core.mitigation.search import Candidate, CellRun, PanelCell


@dataclasses.dataclass(frozen=True)
class WhatIfQuery:
    """One "tune my fabric" question. ``profiles=()`` means a single
    steady-congestion panel cell; each extra profile adds a cell (the
    candidate must win across all of them)."""

    system: str
    n_nodes: int
    victim: str = "ring_allgather"
    aggressor: str = "incast"
    vector_bytes: float = float(2 << 20)
    profiles: Tuple[cong.Profile, ...] = ()
    jobs: tuple = ()
    agent: str = "grid"  # "grid" | agents.AGENTS key
    candidates: Optional[Tuple[Candidate, ...]] = None
    knobs: Tuple[str, ...] = AGENT_KNOBS
    budget: int = 24
    batch: int = 8
    seed: int = 0

    def __post_init__(self):
        if self.agent != "grid" and self.agent not in agents_lib.AGENTS:
            raise KeyError(f"unknown agent {self.agent!r}; choose 'grid' "
                           f"or one of {sorted(agents_lib.AGENTS)}")
        if self.budget < 1:
            raise ValueError("budget must be >= 1")
        if self.batch < 1:
            raise ValueError("batch must be >= 1")
        get_system(self.system)  # fail fast on unknown fabric


@dataclasses.dataclass
class WhatIfResult:
    """Per-query answer: the scalar winner under the baseline-tax guard,
    the Pareto frontier, and the full scorecard table."""

    uid: int
    query: WhatIfQuery
    winner: CandidateScore
    winner_candidate: Optional[Candidate]
    objective: float
    frontier: List[CandidateScore]
    scores: List[CandidateScore]
    evals: int
    finish_reason: str  # "budget" | "drained"
    wall_s: float


@dataclasses.dataclass
class WhatIfStats:
    queries_done: int = 0
    waves: int = 0
    coalesced_calls: int = 0  # run_candidate_rows invocations
    lanes: int = 0  # engine lanes dispatched (cells x width x 2)
    evals: int = 0  # fresh candidate evaluations charged to queries
    table_hits: int = 0
    wall_s: float = 0.0


class _QueryState:
    """Server-side bookkeeping for one in-flight query."""

    def __init__(self, query: WhatIfQuery, uid: int):
        self.query = query
        self.uid = uid
        self.submitted_s = time.monotonic()
        profiles = query.profiles or (cong.steady(),)
        system = get_system(query.system)
        # the uid prefix keeps coalesced cell names collision-free even
        # when two queries ask about the identical scenario
        self.cells = [PanelCell(
            name=(f"q{uid}:{query.system}-{query.n_nodes}"
                  f"/{query.aggressor}/{prof.label()}"
                  f"/{int(query.vector_bytes)}"),
            system=system, n_nodes=query.n_nodes, victim=query.victim,
            aggressor=query.aggressor, vector_bytes=query.vector_bytes,
            profile=prof, jobs=query.jobs) for prof in profiles]
        self.agent: Optional[agents_lib.SearchAgent] = None
        self.pending: Deque[Candidate] = deque()
        if query.agent == "grid":
            cands = query.candidates or tuple(
                agents_lib.grid_candidates(query.knobs))
            self.pending.extend(cands)
        else:
            self.agent = agents_lib.make_agent(
                query.agent, knobs=query.knobs, batch=query.batch,
                seed=query.seed)
        self.cell_runs: List[CellRun] = []
        self._seen_runs: set = set()
        self.cand_by_label: Dict[str, Candidate] = {}
        self.table: Dict[str, CandidateScore] = {}
        self.evals = 0
        self.started = False  # default candidate rides the first wave
        self.last_props: List[Candidate] = []
        self.stalls = 0

    # ---- wave participation -------------------------------------------
    def next_row(self) -> List[Candidate]:
        """The candidates this query contributes to the next wave (fresh
        points only; known labels are served from the memo table when
        the scores come back)."""
        if self.agent is None:
            props = [self.pending.popleft()
                     for _ in range(min(self.query.batch,
                                        len(self.pending)))]
        else:
            props = list(self.agent.propose(self.agent.history))
        self.last_props = props
        fresh, labels = [], set(self.table)
        for c in props:
            lab = c.label()
            if lab not in labels:
                fresh.append(c)
                labels.add(lab)
        row = list(fresh)
        if not self.started:
            row.insert(0, search.default_candidate())
        return row

    def absorb(self, runs: Sequence[CellRun], n_fresh: int) -> None:
        """Fold a wave's sliced-out runs into this query's scorecards.
        Padding duplicates (same cell+candidate) are dropped — they are
        bit-identical copies by construction."""
        for r in runs:
            key = (r.cell, r.candidate)
            if key not in self._seen_runs:
                self._seen_runs.add(key)
                self.cell_runs.append(r)
        self.table = {s.candidate: s
                      for s in score_lib.aggregate(self.cell_runs)}
        self.evals += n_fresh
        self.started = True

    def observe(self) -> None:
        if self.agent is None or not self.last_props:
            return
        obs = [agents_lib.Observation(c, agents_lib.objective(
            self.table[c.label()]), self.table[c.label()])
            for c in self.last_props if c.label() in self.table]
        if obs:
            self.agent.observe(obs)

    def finished(self) -> Optional[str]:
        if self.evals >= self.query.budget:
            return "budget"
        if self.agent is None and not self.pending:
            return "drained"
        if self.stalls >= 3:  # agent converged onto known points only
            return "drained"
        return None

    def finalize(self, reason: str) -> WhatIfResult:
        scores = [s for s in self.table.values()]
        winner = score_lib.pick_winner(scores)
        return WhatIfResult(
            uid=self.uid, query=self.query, winner=winner,
            winner_candidate=self.cand_by_label.get(winner.candidate),
            objective=agents_lib.objective(winner),
            frontier=score_lib.pareto_frontier(scores), scores=scores,
            evals=self.evals, finish_reason=reason,
            wall_s=time.monotonic() - self.submitted_s)


class WhatIfServer:
    """Wave scheduler over concurrent what-if queries: admit up to
    ``max_batch`` queries, coalesce their next candidate generations
    into one ``run_candidate_rows`` call, stream results back per query
    as budgets drain."""

    def __init__(self, *, max_batch: int = 4, n_iters: int = 12,
                 warmup: int = 3, max_steps: int = 200_000,
                 chunk: int = 2048, stride: int = 8, mesh=None,
                 launcher=None, cache_dir: Optional[str] = None):
        if cache_dir:
            from repro.core.fabric import simulator as sim

            sim.ensure_compile_cache(cache_dir)
        self.max_batch = int(max_batch)
        self.run_kw = dict(n_iters=n_iters, warmup=warmup,
                           max_steps=max_steps, chunk=chunk, stride=stride,
                           mesh=mesh, launcher=launcher)
        self.queue: Deque[_QueryState] = deque()
        self.active: List[_QueryState] = []
        self.results: Dict[int, WhatIfResult] = {}
        self.stats = WhatIfStats()
        self._uid = 0

    # ------------------------------------------------------------------
    def submit(self, query: WhatIfQuery) -> int:
        self._uid += 1
        self.queue.append(_QueryState(query, self._uid))
        return self._uid

    def poll(self, uid: int) -> Optional[WhatIfResult]:
        return self.results.get(uid)

    def result(self, uid: int) -> WhatIfResult:
        if uid not in self.results:
            raise KeyError(f"query {uid} not finished "
                           f"(pending={len(self.queue)}, "
                           f"active={len(self.active)})")
        return self.results[uid]

    # ------------------------------------------------------------------
    def step_wave(self) -> int:
        """Admit queries, run one coalesced wave, retire finished
        queries. Returns the number of queries that made progress."""
        while self.queue and len(self.active) < self.max_batch:
            self.active.append(self.queue.popleft())
        if not self.active:
            return 0
        t0 = time.monotonic()

        plans = []  # (state, row, n_fresh)
        for st in self.active:
            row = st.next_row()
            for c in row:
                st.cand_by_label.setdefault(c.label(), c)
            n_fresh = len(row) - (0 if st.started else 1)
            if row:
                plans.append((st, row, n_fresh))
            else:
                # every proposal was already scored: the agent observes
                # from the memo table without costing lanes
                self.stats.table_hits += len(st.last_props)
                st.stalls += 1

        if plans:
            width = max(len(row) for _, row, _ in plans)
            all_cells: List[PanelCell] = []
            all_rows: List[List[Candidate]] = []
            for st, row, _ in plans:
                padded = row + [row[-1]] * (width - len(row))
                all_cells.extend(st.cells)
                all_rows.extend([padded] * len(st.cells))
            runs = search.run_candidate_rows(all_cells, all_rows,
                                             **self.run_kw)
            self.stats.coalesced_calls += 1
            self.stats.lanes += 2 * width * len(all_cells)
            by_query: Dict[int, List[CellRun]] = {}
            for r in runs:
                uid = int(r.cell.split(":", 1)[0][1:])
                by_query.setdefault(uid, []).append(r)
            for st, row, n_fresh in plans:
                st.absorb(by_query.get(st.uid, []), n_fresh)
                st.stalls = 0
                self.stats.evals += n_fresh

        progressed = 0
        still_active: List[_QueryState] = []
        for st in self.active:
            st.observe()
            reason = st.finished()
            if reason is not None:
                self.results[st.uid] = st.finalize(reason)
                self.stats.queries_done += 1
            else:
                still_active.append(st)
            progressed += 1
        self.active = still_active
        self.stats.waves += 1
        self.stats.wall_s += time.monotonic() - t0
        return progressed

    def run_until_drained(self, max_waves: int = 200) -> WhatIfStats:
        for _ in range(max_waves):
            if not self.queue and not self.active:
                break
            self.step_wave()
        return self.stats
