"""Deterministic synthetic LM data pipeline.

Design goals (1000+-node deployments):

* **Stateless indexing** — batch ``i`` is a pure function of ``(seed, i)``,
  so checkpoint-restart needs to store only the step counter, and any host
  can regenerate any shard (no data-state gossip on restart).
* **Host sharding** — each host materializes only its slice of the global
  batch (``host_id/n_hosts``); the global batch is never assembled.
* **Learnable structure** — tokens follow an order-2 mixture pattern
  (token ~ f(prev, position band)) so a real model shows a monotonically
  decreasing loss, which the integration tests assert.

A file-backed reader (`TokenFileDataset`) with the same stateless-index
interface covers the "real corpus" path: a flat uint16/uint32 token file is
strided deterministically.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterator, Optional

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 — the per-element counter-based RNG."""
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    structure: float = 0.75  # fraction of tokens that follow the pattern


class SyntheticLM:
    """Infinite, deterministic, host-sharded token stream."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0, \
            (cfg.global_batch, cfg.n_hosts)
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts
        # a fixed random "grammar": successor table for the structured part
        rng = np.random.RandomState(cfg.seed ^ 0x5EED)
        self._succ = rng.randint(0, cfg.vocab_size,
                                 size=(cfg.vocab_size,), dtype=np.int64)

    # -- stateless batch indexing -------------------------------------------
    def batch_at(self, step: int) -> dict:
        """The (host-local) batch for global step ``step``."""
        c = self.cfg
        rows = (np.int64(step) * c.global_batch
                + c.host_id * self.local_batch
                + np.arange(self.local_batch, dtype=np.int64))
        # per-(row, col) counters -> uniform u64 lattice
        ctr = (rows[:, None].astype(np.uint64) << np.uint64(20)) \
            + np.arange(c.seq_len + 1, dtype=np.uint64)[None, :]
        u = _splitmix64(ctr ^ np.uint64(c.seed * 0x9E3779B1 + 1))
        rand_tok = (u % np.uint64(c.vocab_size)).astype(np.int64)
        keep_rand = (u >> np.uint64(32)) % np.uint64(1_000_000) \
            >= np.uint64(int(c.structure * 1_000_000))
        # order-1 structured successor chain, applied left-to-right
        toks = rand_tok.copy()
        for t in range(1, c.seq_len + 1):
            struct = self._succ[toks[:, t - 1]]
            toks[:, t] = np.where(keep_rand[:, t], rand_tok[:, t], struct)
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class TokenFileDataset:
    """Flat binary token file with the same stateless-index interface.

    Layout: little-endian uint16 (vocab < 65536) or uint32 tokens. Batch
    ``i`` reads ``local_batch`` rows strided pseudo-randomly through the
    file (deterministic in ``(seed, i)``), wrapping at EOF.
    """

    def __init__(self, path: str, cfg: DataConfig, dtype=np.uint16):
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts
        self._tok = np.memmap(path, dtype=dtype, mode="r")
        self._n = len(self._tok) - (cfg.seq_len + 1)
        assert self._n > 0, "token file shorter than one sample"

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        rows = (np.int64(step) * c.global_batch
                + c.host_id * self.local_batch
                + np.arange(self.local_batch, dtype=np.int64))
        starts = (_splitmix64(rows.astype(np.uint64)
                              ^ np.uint64(c.seed + 77))
                  % np.uint64(self._n)).astype(np.int64)
        idx = starts[:, None] + np.arange(c.seq_len + 1)[None, :]
        toks = np.asarray(self._tok[idx], dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def write_token_file(path: str, tokens: np.ndarray, dtype=np.uint16) -> None:
    tokens.astype(dtype).tofile(path)


def make_dataset(cfg: DataConfig, path: Optional[str] = None):
    if path and os.path.exists(path):
        return TokenFileDataset(path, cfg)
    return SyntheticLM(cfg)
