"""Per-block int8 quantize/dequantize kernels — gradient compression on the
pod (DCN) axis, the congestion-exposed link the paper's Ethernet findings
target. Symmetric per-block scaling; used with error feedback in
optim/compression.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _q_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (rows, block)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1) / 127.0, 1e-12)
    q_ref[...] = jnp.clip(jnp.round(x / scale[:, None]), -127, 127
                          ).astype(jnp.int8)
    s_ref[...] = scale


def _dq_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[...][:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "rows_per_step",
                                             "interpret"))
def quantize_int8(x, *, block: int = 256, rows_per_step: int = 64,
                  interpret: bool = True):
    """x: (R, C) with C % block == 0 -> (q int8 (R, C), scales (R, C/block))."""
    R, C = x.shape
    nb = C // block
    xb = x.reshape(R * nb, block)
    rows = min(rows_per_step, R * nb)
    grid = (pl.cdiv(R * nb, rows),)
    q, s = pl.pallas_call(
        _q_kernel,
        out_shape=(jax.ShapeDtypeStruct((R * nb, block), jnp.int8),
                   jax.ShapeDtypeStruct((R * nb,), jnp.float32)),
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((rows, block), lambda i: (i, 0)),
                   pl.BlockSpec((rows,), lambda i: (i,))),
        interpret=interpret,
    )(xb)
    return q.reshape(R, C), s.reshape(R, nb)


@functools.partial(jax.jit, static_argnames=("block", "rows_per_step",
                                             "interpret", "out_dtype"))
def dequantize_int8(q, s, *, block: int = 256, rows_per_step: int = 64,
                    interpret: bool = True, out_dtype=jnp.float32):
    R, C = q.shape
    nb = C // block
    rows = min(rows_per_step, R * nb)
    grid = (pl.cdiv(R * nb, rows),)
    out = pl.pallas_call(
        _dq_kernel,
        out_shape=jax.ShapeDtypeStruct((R * nb, block), out_dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0)),
                  pl.BlockSpec((rows,), lambda i: (i,))],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        interpret=interpret,
    )(q.reshape(R * nb, block), s.reshape(R * nb))
    return out.reshape(R, C)
