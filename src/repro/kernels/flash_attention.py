"""Blockwise (flash) causal attention Pallas kernel — TPU target.

Grid: (batch, kv_head, q_block). Each program holds one q tile in VMEM and
loops over kv tiles with running (max, sum, acc) fp32 accumulators — the
same online-softmax recurrence as the XLA-native path in
``models/layers.flash_attention_xla`` (which the dry-run lowers, since
Mosaic does not target the CPU backend; see DESIGN.md §9).

Block sizes default to (q=128, kv=128): tiles of 128x128 keep the MXU fully
occupied and the VMEM working set per program is
q(128xD) + k/v(128xD each) + acc(128xD f32) ~= 0.4 MiB at D=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_kv: int,
            seq_kv: int, causal: bool, scale: float):
    g = q_ref.shape[1]
    d = q_ref.shape[-1]
    q = q_ref[...].astype(jnp.float32) * scale  # (bq, G, D)
    qi = pl.program_id(2)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    def body(ci, carry):
        acc, m, l = carry
        kc = pl.load(k_ref, (pl.dslice(ci * block_kv, block_kv),
                             slice(None))).astype(jnp.float32)  # (bkv, D)
        vc = pl.load(v_ref, (pl.dslice(ci * block_kv, block_kv),
                             slice(None))).astype(jnp.float32)
        s = jax.lax.dot_general(q.reshape(-1, d), kc,
                                (((1,), (1,)), ((), ())))  # (bq*G, bkv)
        s = s.reshape(block_q, g, block_kv)
        kv_pos = ci * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_kv), 1)
        mask = kv_pos < seq_kv
        if causal:
            mask = mask & (q_pos >= kv_pos)
        s = jnp.where(mask[:, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[:, None, :], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + p.sum(axis=-1)
        pv = jax.lax.dot_general(p.reshape(-1, block_kv), vc,
                                 (((1,), (0,)), ((), ())))
        acc_new = acc * corr.reshape(block_q, g, 1) + pv.reshape(
            block_q, g, d)
        return acc_new, m_new, l_new

    n_kv = pl.cdiv(seq_kv, block_kv)
    if causal:
        # skip fully-masked kv blocks beyond the diagonal
        n_kv_eff = jnp.minimum(
            n_kv, (qi + 1) * block_q // block_kv + 1).astype(jnp.int32)
    else:
        n_kv_eff = n_kv
    acc0 = jnp.zeros((block_q, g, d), jnp.float32)
    m0 = jnp.full((block_q, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((block_q, g), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_kv_eff, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Skv, KH, D); GQA via H = KH * G."""
    B, Sq, H, D = q.shape
    _, Skv, KH, _ = k.shape
    G = H // KH
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    scale = 1.0 / np.sqrt(D)
    # pad KV to a block multiple so in-kernel dslice loads stay in bounds;
    # padded keys are masked out via seq_kv inside the kernel
    if Skv % bkv:
        pad = bkv - Skv % bkv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Skv_pad = k.shape[1]
    qr = q.reshape(B, Sq, KH, G, D).transpose(0, 2, 1, 3, 4)  # (B,KH,Sq,G,D)
    kr = k.transpose(0, 2, 1, 3)  # (B, KH, Skv_pad, D)
    vr = v.transpose(0, 2, 1, 3)
    grid = (B, KH, pl.cdiv(Sq, bq))
    out = pl.pallas_call(
        functools.partial(_kernel, block_q=bq, block_kv=bkv, seq_kv=Skv,
                          causal=causal, scale=scale),
        out_shape=jax.ShapeDtypeStruct((B, KH, Sq, G, D), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, bq, G, D), lambda b, h, i: (b, h, i, 0, 0)),
            pl.BlockSpec((None, None, Skv_pad, D), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((None, None, Skv_pad, D), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, G, D),
                               lambda b, h, i: (b, h, i, 0, 0)),
        interpret=interpret,
    )(qr, kr, vr)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, Sq, H, D)
