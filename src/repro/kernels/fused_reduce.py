"""Fused ring-allreduce accumulate step (paper Fig. 1).

The paper's breakdown shows custom ring AllReduce dominated by "reduction
costs and memory handling (initial buffer setup and memcpy operations)" —
on TPU the fix is to fuse the receive-buffer read, dtype upcast, scale, and
accumulate into one VMEM pass so the summand never round-trips through HBM
between the copy and the add. One (block_rows, block_cols) tile of both
operands is resident in VMEM per grid step; accumulation is fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(acc_ref, x_ref, o_ref, *, scale: float):
    acc = acc_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = (acc + scale * x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_rows",
                                             "block_cols", "interpret"))
def fused_accumulate(acc, x, *, scale: float = 1.0, block_rows: int = 256,
                     block_cols: int = 512, interpret: bool = True):
    """acc, x: (R, C) -> acc + scale * x (fp32 accumulation).

    Block shapes default to (256, 512): 256x512x4B x 3 buffers = 1.5 MiB of
    VMEM, MXU/VPU-aligned (last dim a multiple of 128).
    """
    R, C = acc.shape
    br, bc = min(block_rows, R), min(block_cols, C)
    grid = (pl.cdiv(R, br), pl.cdiv(C, bc))
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        out_shape=jax.ShapeDtypeStruct(acc.shape, acc.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                  pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        interpret=interpret,
    )(acc, x)
