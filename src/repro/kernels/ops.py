"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the container is CPU-only; Mosaic
targets TPU). On a real TPU backend pass ``interpret=False`` (or rely on the
default, which checks the backend).
"""
from __future__ import annotations

import jax

from repro.kernels import fabric_step as _fs
from repro.kernels import flash_attention as _fa
from repro.kernels import fused_reduce as _fr
from repro.kernels import quant as _q
from repro.kernels import ssm_scan as _ss


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_accumulate(acc, x, scale: float = 1.0, interpret=None):
    return _fr.fused_accumulate(
        acc, x, scale=scale,
        interpret=_default_interpret() if interpret is None else interpret)


def flash_attention(q, k, v, causal: bool = True, interpret=None, **kw):
    return _fa.flash_attention(
        q, k, v, causal=causal,
        interpret=_default_interpret() if interpret is None else interpret,
        **kw)


def ssm_scan(dA, dBx, h0, interpret=None, **kw):
    return _ss.ssm_scan(
        dA, dBx, h0,
        interpret=_default_interpret() if interpret is None else interpret,
        **kw)


def fused_selective_scan(dt, A, B_coef, C_coef, x, h0, interpret=None, **kw):
    return _ss.fused_selective_scan(
        dt, A, B_coef, C_coef, x, h0,
        interpret=_default_interpret() if interpret is None else interpret,
        **kw)


def fabric_step_core(*args, interpret=None, **kw):
    """Fused fabric-simulator step core (see kernels/fabric_step.py);
    same signature/return dict as ref.fabric_step_core."""
    return _fs.fabric_step_core(
        *args,
        interpret=_default_interpret() if interpret is None else interpret,
        **kw)


def quantize_int8(x, interpret=None, **kw):
    return _q.quantize_int8(
        x, interpret=_default_interpret() if interpret is None else interpret,
        **kw)


def dequantize_int8(q, s, interpret=None, **kw):
    return _q.dequantize_int8(
        q, s,
        interpret=_default_interpret() if interpret is None else interpret,
        **kw)
