"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fused_accumulate(acc, x, scale: float = 1.0):
    """Ring-allreduce receive-accumulate: acc + scale * x, accumulated in
    fp32 regardless of input dtype (paper Fig. 1 hotspot)."""
    return (acc.astype(jnp.float32)
            + scale * x.astype(jnp.float32)).astype(acc.dtype)


def flash_attention(q, k, v, *, causal: bool = True):
    """Naive full-matrix attention. q: (B, Sq, H, D); k/v: (B, Skv, KH, D)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qr = q.reshape(B, Sq, KH, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qr, k.astype(jnp.float32))
    s = s / np.sqrt(D)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(k.shape[1])[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgc,bckd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def ssm_scan(dA, dBx, h0):
    """Sequential selective-scan over time. dA/dBx: (B, T, Di, N); h0:
    (B, Di, N). Returns (hs (B, T, Di, N), h_final)."""
    def step(h, inp):
        a, b = inp
        h = a * h + b
        return h, h

    hT, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                          (dA.transpose(1, 0, 2, 3).astype(jnp.float32),
                           dBx.transpose(1, 0, 2, 3).astype(jnp.float32)))
    return hs.transpose(1, 0, 2, 3), hT


def fused_selective_scan(dt, A, B_coef, C_coef, x, h0):
    """Oracle for the fused kernel: dA/dBx derived from (dt, A, B, x), y_t
    contracted against C_t. dt/x: (B, T, Di); A: (Di, N); B/C: (B, T, N)."""
    dt32 = dt.astype(jnp.float32)
    dA = jnp.exp(dt32[..., None] * A.astype(jnp.float32))  # (B,T,Di,N)
    dBx = (dt32 * x.astype(jnp.float32))[..., None] \
        * B_coef.astype(jnp.float32)[:, :, None, :]
    hs, hT = ssm_scan(dA, dBx, h0)
    y = jnp.einsum("btdn,btn->btd", hs, C_coef.astype(jnp.float32))
    return y, hT


def quantize_int8(x, block: int = 256):
    """Per-block symmetric int8 quantization along the last axis.
    Returns (q int8, scales f32 with last dim = n_blocks)."""
    shape = x.shape
    n = shape[-1]
    assert n % block == 0
    xb = x.reshape(shape[:-1] + (n // block, block)).astype(jnp.float32)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(shape), scale


def dequantize_int8(q, scale, block: int = 256):
    shape = q.shape
    n = shape[-1]
    qb = q.reshape(shape[:-1] + (n // block, block)).astype(jnp.float32)
    return (qb * scale[..., None]).reshape(shape)
