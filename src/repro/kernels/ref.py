"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fused_accumulate(acc, x, scale: float = 1.0):
    """Ring-allreduce receive-accumulate: acc + scale * x, accumulated in
    fp32 regardless of input dtype (paper Fig. 1 hotspot)."""
    return (acc.astype(jnp.float32)
            + scale * x.astype(jnp.float32)).astype(acc.dtype)


def flash_attention(q, k, v, *, causal: bool = True):
    """Naive full-matrix attention. q: (B, Sq, H, D); k/v: (B, Skv, KH, D)."""
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qr = q.reshape(B, Sq, KH, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qr, k.astype(jnp.float32))
    s = s / np.sqrt(D)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(k.shape[1])[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgc,bckd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def ssm_scan(dA, dBx, h0):
    """Sequential selective-scan over time. dA/dBx: (B, T, Di, N); h0:
    (B, Di, N). Returns (hs (B, T, Di, N), h_final)."""
    def step(h, inp):
        a, b = inp
        h = a * h + b
        return h, h

    hT, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                          (dA.transpose(1, 0, 2, 3).astype(jnp.float32),
                           dBx.transpose(1, 0, 2, 3).astype(jnp.float32)))
    return hs.transpose(1, 0, 2, 3), hT


def fused_selective_scan(dt, A, B_coef, C_coef, x, h0):
    """Oracle for the fused kernel: dA/dBx derived from (dt, A, B, x), y_t
    contracted against C_t. dt/x: (B, T, Di); A: (Di, N); B/C: (B, T, N)."""
    dt32 = dt.astype(jnp.float32)
    dA = jnp.exp(dt32[..., None] * A.astype(jnp.float32))  # (B,T,Di,N)
    dBx = (dt32 * x.astype(jnp.float32))[..., None] \
        * B_coef.astype(jnp.float32)[:, :, None, :]
    hs, hT = ssm_scan(dA, dBx, h0)
    y = jnp.einsum("btdn,btn->btd", hs, C_coef.astype(jnp.float32))
    return y, hT


def fabric_step_core(plinks, inject, src_id, host_caps, q, occ, caps_finite,
                     src_sw, dst_sw, dt, qmax_bytes, hol_factor, hol_start,
                     burst_jitter, *, n_src: int, n_sw: int,
                     with_aux: bool = False):
    """Oracle for the fused fabric-step kernel: the memory-bound core of
    one simulator step (repro.core.fabric.simulator._step_impl), extracted
    VERBATIM from the pre-kernel ``lax`` code so this stays the bit-exact
    default path on CPU and in interpret mode.

    Covers, in order (DESIGN.md §13):

    * NIC injection limiting — ``src_load`` segment-sum over ``src_id``,
    * backpressure/PFC head-of-line stall — ``hot_q``/``tot_q`` segment
      sums and the ``sw_sat`` segment-max over ``src_sw``, gathered back
      through ``dst_sw`` into per-link effective capacities,
    * the H-hop staged-propagation loop — per-hop link-load scatter, FIFO
      fluid over-subscription division, arrival accumulation (plus the
      per-stage served-rate observer when ``with_aux``),
    * the queue update (offered load vs effective capacity, clipped to
      ``[0, qmax]``, sink pinned to 0).

    Everything upstream (phase gating, routing choice) and downstream
    (ECN signals, CC update, phase bookkeeping) stays in the simulator —
    those are cheap elementwise/gather ops; the scatters fused here are
    the dominant per-step cost.

    Args are per-cell (unbatched); the caller vmaps. ``plinks`` is the
    chosen path's link ids (F, H) with pad == sink == ``q.shape[0] - 1``;
    ``occ`` must equal ``q / qmax_bytes`` (computed once by the caller —
    the routing score shares it). ``caps_finite`` is whatever per-link
    capacity the caller hands in: since the link-fault engine
    (DESIGN.md §16) it may arrive already fault-scaled — the scale is
    folded in OUTSIDE this core, so the body needs (and has) no notion
    of faults. Returns a dict with ``inject`` (NIC-scaled),
    ``achieved``, ``arrival``, ``q_new``, ``caps_eff``, and
    ``served_stage_max`` (None unless ``with_aux``).
    """
    sink = q.shape[0] - 1
    valid = plinks < sink
    # ---- NIC limit: a source's flows share its injection link ----
    src_load = jnp.zeros((n_src,), jnp.float32).at[src_id].add(inject)
    scale = jnp.minimum(1.0, host_caps
                        / jnp.maximum(src_load[src_id], 1.0))
    inject = inject * scale
    # ---- lossless backpressure (credit/PFC head-of-line stall) ----
    sat_l = jnp.clip((occ - hol_start) / (1.0 - hol_start), 0.0, 1.0)
    hot_q = jnp.zeros((n_sw,), jnp.float32).at[src_sw].add(q * sat_l)
    tot_q = jnp.zeros((n_sw,), jnp.float32).at[src_sw].add(q)
    share = hot_q / jnp.maximum(tot_q, 1.0)
    sw_sat = jnp.zeros((n_sw,), jnp.float32).at[src_sw].max(sat_l)
    stall = 1.0 - hol_factor * sw_sat * share
    stall = stall.at[0].set(1.0)  # 0 == host endpoint
    caps_eff = caps_finite * stall[dst_sw]
    # ---- staged propagation + queues ----
    r = inject
    arrival = jnp.zeros((sink + 1,), jnp.float32)
    served_stage_max = jnp.zeros((sink + 1,), jnp.float32)
    for h in range(plinks.shape[1]):
        lk = plinks[:, h]
        contrib = r * valid[:, h]
        load = jnp.zeros((sink + 1,), jnp.float32).at[lk].add(contrib)
        arrival = arrival + load
        over = jnp.maximum(load / caps_eff, 1.0)
        r = jnp.where(valid[:, h], r / over[lk], r)
        if with_aux:
            served = jnp.zeros((sink + 1,), jnp.float32).at[lk].add(
                r * valid[:, h])
            served_stage_max = jnp.maximum(served_stage_max, served)
    q_new = jnp.clip(q + (arrival * (1.0 + burst_jitter)
                          - caps_eff) * dt,
                     0.0, qmax_bytes)
    q_new = q_new.at[sink].set(0.0)
    return {"inject": inject, "achieved": r, "arrival": arrival,
            "q_new": q_new, "caps_eff": caps_eff,
            "served_stage_max": served_stage_max if with_aux else None}


def quantize_int8(x, block: int = 256):
    """Per-block symmetric int8 quantization along the last axis.
    Returns (q int8, scales f32 with last dim = n_blocks)."""
    shape = x.shape
    n = shape[-1]
    assert n % block == 0
    xb = x.reshape(shape[:-1] + (n // block, block)).astype(jnp.float32)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(shape), scale


def dequantize_int8(q, scale, block: int = 256):
    shape = q.shape
    n = shape[-1]
    qb = q.reshape(shape[:-1] + (n // block, block)).astype(jnp.float32)
    return (qb * scale[..., None]).reshape(shape)
