"""Fused Pallas kernel for the fabric simulator's per-step hot core.

After PR 5 the whole characterization grid runs as one ``jit(vmap(vmap))``
over the simulator scan, so the per-step scatter/segment-sum core of
``fabric/simulator._step_impl`` dominates wall-clock: ~10 separate
O(F*H) scatter/gather passes over the packed path table (``plinks``) —
NIC segment-sum, three backpressure segment-reductions, and per hop a
link-load scatter, an over-subscription gather, and (under ``step_debug``)
a served-rate scatter. XLA lowers each as an independent HBM-round-trip
scatter with full-size zero-init.

This kernel fuses the whole core into ONE launch that keeps flow rows and
per-link state resident in VMEM across hops (DESIGN.md §13):

* Scatters/gathers become flow-blocked one-hot contractions: a
  (block_flows, n_out) equality mask against a ``broadcasted_iota`` link
  row, contracted on the MXU (``jnp.dot`` with fp32 accumulation). This
  is the TPU-native segment-sum lowering — Mosaic has no vector scatter,
  and the mask never touches HBM.
* Segment-max (``sw_sat``) uses the same mask with a masked ``jnp.max``
  (order-independent, so it is exact vs the reference scatter-max).
* The H-hop loop is unrolled in-kernel (H is static geometry meta); the
  per-flow rate vector ``r`` never leaves registers/VMEM between hops.

Exactness contract: identical arithmetic to ``kernels.ref.fabric_step_core``
except that one-hot contractions may sum a link's contributions in a
different order than XLA's scatter-add — fp32-allclose always, and
bit-exact whenever every (link, hop) has at most one contributing flow
(tests/test_kernels.py pins both). The reference stays the default on CPU
and in interpret mode; ``REPRO_FABRIC_KERNEL=pallas`` (or
``simulator.set_step_backend``) routes the engine through this kernel.

VMEM budget (defaults, fp32): the dominant residents are one
(block_flows, L+1) one-hot tile (128 x 4096 -> 2 MiB), the per-link rows
(q/occ/caps/arrival/load: 6 x (L+1) -> ~100 KiB at L=4096), and the
per-flow rows (~4 x F). Flow/link axes are padded to block multiples with
provably inert rows (pad flows inject 0 onto the sink; pad links have
cap 1, queue 0, and are referenced by no path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _onehot(idx, n_out):
    """(B,) int32 -> (B, n_out) fp32 equality mask (the scatter/gather
    surrogate: dot(vals, onehot) == segment-sum, dot(onehot, col) ==
    gather). iota is 2D (broadcasted_iota) per the Mosaic constraint."""
    ids = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], n_out), 1)
    return (idx[:, None] == ids).astype(jnp.float32)


def _kernel(plinks_ref, inject_ref, src_id_ref, host_caps_ref, q_ref,
            occ_ref, caps_finite_ref, src_sw_ref, dst_sw_ref, s_ref,
            *out_refs, sink: int, n_src: int, n_sw: int, bf: int, bl: int,
            with_aux: bool):
    inject_out_ref, a_ref, arrival_ref, qnew_ref, caps_eff_ref = out_refs[:5]
    dt = s_ref[0, 0]
    qmax_bytes = s_ref[0, 1]
    hol_factor = s_ref[0, 2]
    hol_start = s_ref[0, 3]
    burst_jitter = s_ref[0, 4]

    F, H = plinks_ref.shape          # flow axis padded to a bf multiple
    Lp = q_ref.shape[1]              # link axis padded to a bl multiple
    n_fb, n_lb = F // bf, Lp // bl

    # ---- NIC limit: src_load segment-sum, then per-flow gather+scale ----
    src_load = jnp.zeros((1, n_src), jnp.float32)
    for fb in range(n_fb):
        sl = slice(fb * bf, (fb + 1) * bf)
        sel = _onehot(src_id_ref[0, sl], n_src)
        src_load = src_load + jnp.dot(
            inject_ref[0, sl][None, :], sel,
            preferred_element_type=jnp.float32)
    inj_blocks = []
    for fb in range(n_fb):
        sl = slice(fb * bf, (fb + 1) * bf)
        sel = _onehot(src_id_ref[0, sl], n_src)
        mine = jnp.dot(sel, src_load.T,
                       preferred_element_type=jnp.float32)[:, 0]
        scale = jnp.minimum(1.0, host_caps_ref[0, sl]
                            / jnp.maximum(mine, 1.0))
        inj_blocks.append((inject_ref[0, sl] * scale)[None, :])
    inject = jnp.concatenate(inj_blocks, axis=1)  # (1, F), NIC-scaled
    inject_out_ref[...] = inject

    # ---- backpressure: hot_q/tot_q segment-sums + sw_sat segment-max ----
    q_row = q_ref[...]
    occ_row = occ_ref[...]
    hot_q = jnp.zeros((1, n_sw), jnp.float32)
    tot_q = jnp.zeros((1, n_sw), jnp.float32)
    sw_sat = jnp.zeros((1, n_sw), jnp.float32)
    for lb in range(n_lb):
        sl = slice(lb * bl, (lb + 1) * bl)
        sat_b = jnp.clip((occ_row[0, sl] - hol_start)
                         / (1.0 - hol_start), 0.0, 1.0)
        q_b = q_row[0, sl]
        sel = _onehot(src_sw_ref[0, sl], n_sw)
        hot_q = hot_q + jnp.dot((q_b * sat_b)[None, :], sel,
                                preferred_element_type=jnp.float32)
        tot_q = tot_q + jnp.dot(q_b[None, :], sel,
                                preferred_element_type=jnp.float32)
        # masked max: exact (order-free) surrogate of .at[].max on zeros
        sw_sat = jnp.maximum(
            sw_sat, jnp.max(sel * sat_b[:, None], axis=0)[None, :])
    share = hot_q / jnp.maximum(tot_q, 1.0)
    stall = 1.0 - hol_factor * sw_sat * share
    sw_ids = jax.lax.broadcasted_iota(jnp.int32, (1, n_sw), 1)
    stall = jnp.where(sw_ids == 0, 1.0, stall)  # 0 == host endpoint
    ce_blocks = []
    for lb in range(n_lb):
        sl = slice(lb * bl, (lb + 1) * bl)
        sel = _onehot(dst_sw_ref[0, sl], n_sw)
        st = jnp.dot(sel, stall.T, preferred_element_type=jnp.float32)[:, 0]
        ce_blocks.append((caps_finite_ref[0, sl] * st)[None, :])
    caps_eff = jnp.concatenate(ce_blocks, axis=1)  # (1, Lp)
    caps_eff_ref[...] = caps_eff

    # ---- H-hop staged propagation: flow rows resident across hops ----
    r = inject
    arrival = jnp.zeros((1, Lp), jnp.float32)
    served_max = jnp.zeros((1, Lp), jnp.float32)
    for h in range(H):
        load = jnp.zeros((1, Lp), jnp.float32)
        for fb in range(n_fb):
            sl = slice(fb * bf, (fb + 1) * bf)
            lk = plinks_ref[sl, h]
            contrib = r[0, sl] * (lk < sink).astype(jnp.float32)
            load = load + jnp.dot(contrib[None, :], _onehot(lk, Lp),
                                  preferred_element_type=jnp.float32)
        arrival = arrival + load
        over = jnp.maximum(load / caps_eff, 1.0)
        r_blocks = []
        served = jnp.zeros((1, Lp), jnp.float32)
        for fb in range(n_fb):
            sl = slice(fb * bf, (fb + 1) * bf)
            lk = plinks_ref[sl, h]
            validh = lk < sink
            sel = _onehot(lk, Lp)
            og = jnp.dot(sel, over.T,
                         preferred_element_type=jnp.float32)[:, 0]
            r_b = jnp.where(validh, r[0, sl] / og, r[0, sl])
            r_blocks.append(r_b[None, :])
            if with_aux:
                served = served + jnp.dot(
                    (r_b * validh.astype(jnp.float32))[None, :], sel,
                    preferred_element_type=jnp.float32)
        r = jnp.concatenate(r_blocks, axis=1)
        if with_aux:
            served_max = jnp.maximum(served_max, served)
    a_ref[...] = r
    arrival_ref[...] = arrival

    # ---- queue update ----
    link_ids = jax.lax.broadcasted_iota(jnp.int32, (1, Lp), 1)
    q_new = jnp.clip(q_row + (arrival * (1.0 + burst_jitter)
                              - caps_eff) * dt,
                     0.0, qmax_bytes)
    qnew_ref[...] = jnp.where(link_ids == sink, 0.0, q_new)
    if with_aux:
        out_refs[5][...] = served_max


@functools.partial(jax.jit, static_argnames=(
    "n_src", "n_sw", "with_aux", "interpret", "block_flows", "block_links"))
def fabric_step_core(plinks, inject, src_id, host_caps, q, occ, caps_finite,
                     src_sw, dst_sw, dt, qmax_bytes, hol_factor, hol_start,
                     burst_jitter, *, n_src: int, n_sw: int,
                     with_aux: bool = False, interpret: bool = True,
                     block_flows: int = 128, block_links: int = 256):
    """Fused fabric-step core (one kernel launch). Same signature and
    return dict as :func:`repro.kernels.ref.fabric_step_core` (the
    oracle); ``interpret=True`` runs the kernel through the Pallas
    interpreter (the only mode available off-TPU). Vmappable — the
    batched engine entries (``run_cells``/``run_cells_hetero``) vmap this
    along with the rest of the step.

    ``caps_finite`` may arrive already scaled by the link-fault engine
    (envelopes.fault_scale_at, DESIGN.md §16): the simulator folds the
    time-varying per-link fault scale into this operand OUTSIDE the
    launch, so fault scenarios ride through the kernel as plain data and
    the body stays byte-identical to the fault-free build."""
    F, H = plinks.shape
    Lp1 = q.shape[0]
    sink = Lp1 - 1
    bf = min(block_flows, _round_up(max(F, 1), 8))
    bl = min(block_links, _round_up(Lp1, 8))
    Fp, Lp = _round_up(max(F, 1), bf), _round_up(Lp1, bl)

    def pad_f(x, value, dtype):
        return jnp.pad(x.astype(dtype), (0, Fp - F), constant_values=value)

    def pad_l(x, value, dtype):
        return jnp.pad(x.astype(dtype), (0, Lp - Lp1), constant_values=value)

    # inert padding: pad flows inject 0 onto the sink from source 0; pad
    # links carry cap 1 / queue 0 and hang off switch 0 (the host bucket)
    plinks_p = jnp.pad(plinks.astype(jnp.int32),
                       ((0, Fp - F), (0, 0)), constant_values=sink)
    args = (
        plinks_p,
        pad_f(inject, 0.0, jnp.float32)[None, :],
        pad_f(src_id, 0, jnp.int32)[None, :],
        pad_f(host_caps, 1.0, jnp.float32)[None, :],
        pad_l(q, 0.0, jnp.float32)[None, :],
        pad_l(occ, 0.0, jnp.float32)[None, :],
        pad_l(caps_finite, 1.0, jnp.float32)[None, :],
        pad_l(src_sw, 0, jnp.int32)[None, :],
        pad_l(dst_sw, 0, jnp.int32)[None, :],
        jnp.stack([dt, qmax_bytes, hol_factor, hol_start,
                   burst_jitter]).astype(jnp.float32)[None, :],
    )
    fvec = jax.ShapeDtypeStruct((1, Fp), jnp.float32)
    lvec = jax.ShapeDtypeStruct((1, Lp), jnp.float32)
    out_shape = [fvec, fvec, lvec, lvec, lvec] + ([lvec] if with_aux else [])
    outs = pl.pallas_call(
        functools.partial(_kernel, sink=sink, n_src=n_src, n_sw=n_sw,
                          bf=bf, bl=bl, with_aux=with_aux),
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    inject_s, a, arrival, q_new, caps_eff = [o[0] for o in outs[:5]]
    return {"inject": inject_s[:F], "achieved": a[:F],
            "arrival": arrival[:Lp1], "q_new": q_new[:Lp1],
            "caps_eff": caps_eff[:Lp1],
            "served_stage_max": outs[5][0][:Lp1] if with_aux else None}
