"""Selective-scan (Mamba) chunk kernel — TPU target.

Grid: (batch, d_inner blocks). Each program keeps a (block_d, N) fp32 state
tile in VMEM and steps sequentially over the chunk's T timesteps — the
recurrent dimension stays on-chip, only the per-timestep coefficients
stream from HBM. This is the TPU-idiomatic shape of Mamba's CUDA scan
kernel: recompute-friendly chunking instead of warp shuffles (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(dA_ref, dBx_ref, h0_ref, hs_ref, hT_ref, *, T: int):
    h = h0_ref[...].astype(jnp.float32)  # (bd, N)

    def body(t, h):
        a = dA_ref[t].astype(jnp.float32)
        b = dBx_ref[t].astype(jnp.float32)
        h = a * h + b
        hs_ref[t] = h.astype(hs_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, T, body, h)
    hT_ref[...] = h.astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def ssm_scan(dA, dBx, h0, *, block_d: int = 512, interpret: bool = True):
    """dA, dBx: (B, T, Di, N); h0: (B, Di, N).
    Returns (hs (B, T, Di, N) fp32, h_final (B, Di, N) fp32)."""
    B, T, Di, N = dA.shape
    bd = min(block_d, Di)
    grid = (B, pl.cdiv(Di, bd))
    hs, hT = pl.pallas_call(
        functools.partial(_kernel, T=T),
        out_shape=(jax.ShapeDtypeStruct((B, T, Di, N), jnp.float32),
                   jax.ShapeDtypeStruct((B, Di, N), jnp.float32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, T, bd, N), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((None, T, bd, N), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((None, bd, N), lambda b, i: (b, i, 0)),
        ],
        out_specs=(pl.BlockSpec((None, T, bd, N), lambda b, i: (b, 0, i, 0)),
                   pl.BlockSpec((None, bd, N), lambda b, i: (b, i, 0))),
        interpret=interpret,
    )(dA, dBx, h0)
    return hs, hT


# --------------------------------------------------------------------------
# Fused selective scan — the deploy-path answer to §Perf F1
# --------------------------------------------------------------------------


def _fused_kernel(dt_ref, a_ref, b_ref, c_ref, x_ref, h0_ref, y_ref, hT_ref,
                  *, T: int):
    """Per program: one (bd, N) state tile. The coefficients dA = exp(dt*A)
    and dBx = (dt*x)*B are computed ON THE FLY from the (bd,)-wide dt/x
    rows and the resident A tile, and the output y_t = h_t . C_t is
    contracted IN-KERNEL — the (B, T, Di, N) hidden-state tensor never
    touches HBM. HBM traffic per tile: dt + x + y rows (3*bd*T) plus
    B + C rows (2*N*T), vs the XLA path's O(T*bd*N) state traffic."""
    a = a_ref[...].astype(jnp.float32)           # (bd, N)
    h = h0_ref[...].astype(jnp.float32)          # (bd, N)

    def body(t, h):
        dt = dt_ref[t].astype(jnp.float32)       # (bd,)
        x = x_ref[t].astype(jnp.float32)         # (bd,)
        bvec = b_ref[t].astype(jnp.float32)      # (N,)
        cvec = c_ref[t].astype(jnp.float32)      # (N,)
        dA = jnp.exp(dt[:, None] * a)            # (bd, N)
        h = dA * h + (dt * x)[:, None] * bvec[None, :]
        y_ref[t] = (h * cvec[None, :]).sum(axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, T, body, h)
    hT_ref[...] = h.astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_selective_scan(dt, A, B_coef, C_coef, x, h0, *, block_d: int = 512,
                         interpret: bool = True):
    """dt, x: (B, T, Di); A: (Di, N); B_coef, C_coef: (B, T, N);
    h0: (B, Di, N). Returns (y (B, T, Di) fp32, h_final (B, Di, N) fp32)."""
    Bb, T, Di = dt.shape
    N = A.shape[1]
    bd = min(block_d, Di)
    grid = (Bb, pl.cdiv(Di, bd))
    y, hT = pl.pallas_call(
        functools.partial(_fused_kernel, T=T),
        out_shape=(jax.ShapeDtypeStruct((Bb, T, Di), jnp.float32),
                   jax.ShapeDtypeStruct((Bb, Di, N), jnp.float32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, T, bd), lambda b, i: (b, 0, i)),     # dt
            pl.BlockSpec((bd, N), lambda b, i: (i, 0)),              # A
            pl.BlockSpec((None, T, N), lambda b, i: (b, 0, 0)),      # B
            pl.BlockSpec((None, T, N), lambda b, i: (b, 0, 0)),      # C
            pl.BlockSpec((None, T, bd), lambda b, i: (b, 0, i)),     # x
            pl.BlockSpec((None, bd, N), lambda b, i: (b, i, 0)),     # h0
        ],
        out_specs=(pl.BlockSpec((None, T, bd), lambda b, i: (b, 0, i)),
                   pl.BlockSpec((None, bd, N), lambda b, i: (b, i, 0))),
        interpret=interpret,
    )(dt, A, B_coef, C_coef, x, h0)
    return y, hT
