"""Public model API: build a Model from an ArchConfig, and produce the
abstract input/state specs used by the dry-run and the launchers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.encdec import build_encdec
from repro.models.layers import AxisRules
from repro.models.transformer import Model, build_decoder_lm


def build_model(cfg: ArchConfig, rules: AxisRules, mesh) -> Model:
    if cfg.is_encdec:
        return build_encdec(cfg, rules, mesh)
    return build_decoder_lm(cfg, rules, mesh)


# --------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStructs — no allocation; dry-run contract)
# --------------------------------------------------------------------------


def batch_shapes(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Train/prefill batch as ShapeDtypeStructs."""
    B, S = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    tok = lambda n: jax.ShapeDtypeStruct((B, n), jnp.int32)
    if cfg.family == "vlm":
        Pf = cfg.n_frontend_tokens
        return {"patches": jax.ShapeDtypeStruct((B, Pf, cfg.d_model), cdt),
                "tokens": tok(S - Pf), "labels": tok(S - Pf)}
    if cfg.family == "audio":
        F = cfg.n_frontend_tokens
        return {"frames": jax.ShapeDtypeStruct((B, F, cfg.d_model), cdt),
                "tokens": tok(S), "labels": tok(S)}
    return {"tokens": tok(S), "labels": tok(S)}


def batch_specs(cfg: ArchConfig, rules: AxisRules, batch_size: int) -> dict:
    bspec = rules.dp_if(batch_size)
    sp = rules.tp if cfg.seq_shard else None
    out = {"tokens": P(bspec, sp), "labels": P(bspec, sp)}
    if cfg.family == "vlm":
        out["patches"] = P(bspec, None, None)
    if cfg.family == "audio":
        out["frames"] = P(bspec, None, None)
    return out


def decode_inputs(cfg: ArchConfig, shape: ShapeConfig, model: Model):
    """(cache, tokens, pos) ShapeDtypeStructs + specs for a decode cell."""
    B, S = shape.global_batch, shape.seq_len
    cache = model.cache_shapes(B, S)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    cache_specs = model.cache_specs(B)
    specs = (cache_specs, P(model.rules.dp_if(B), None), P())
    return (cache, tokens, pos), specs


def to_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
