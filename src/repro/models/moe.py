"""Mixture-of-Experts FFN with explicit collective scheduling.

Two sharding modes (DESIGN.md §4/§6):

* ``"ep"`` — experts sharded over the data-parallel axes; token dispatch is an
  explicit ``jax.lax.all_to_all`` over those axes inside a fully-manual
  ``shard_map``. This is the paper's AlltoAll congestion pattern running as a
  first-class training collective (kimi-k2: 384 experts / 16- or 32-way EP).
* ``"2d"`` — experts replicated across data-parallel shards; expert weights
  stored FSDP-sharded on d_model and TP-sharded on d_ff, all-gathered per
  layer (grok-1: 8 experts do not divide the EP axis).

Memory discipline: dispatch buffers carry only the local ``model``-axis slice
of d_model (d/16), so the in-flight all-to-all payload is (E, C, d/16) — never
(E, C, d). The d-contraction is completed with one psum (up) and one
psum_scatter (down) over the TP axis.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.layers import AxisRules, ParamDecl


def moe_decls(cfg, rules: AxisRules) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    tp = rules.tp_if(f)
    out_std = 0.02 / np.sqrt(2 * max(cfg.n_layers, 1))
    if cfg.moe_sharding == "ep":
        ep = rules.ep
        assert E % rules.ep_size == 0, (E, rules.ep_size)
        w_in_spec = P(ep, None, tp)
        w_out_spec = P(ep, tp, None)
    elif cfg.moe_sharding == "ep_sp":
        # full EP compute with tokens sequence-sharded over model. Expert
        # weights are STORED f-sharded over model (replicating a 1T-param
        # expert bank over 16 model ranks costs 129 GB/device — measured,
        # §Perf K1a) and all-gathered per layer inside the body; the
        # gather is ~10x cheaper than the TP reduce-scatter it replaces.
        ep = rules.ep
        assert E % rules.ep_size == 0, (E, rules.ep_size)
        w_in_spec = P(ep, None, tp)
        w_out_spec = P(ep, tp, None)
    else:  # 2d / 2d_full
        fs = rules.fsdp_if(d)
        w_in_spec = P(None, fs, tp)
        w_out_spec = P(None, tp, fs)
    return {
        "router": ParamDecl((d, E), P(None, None)),
        "w1": ParamDecl((E, d, f), w_in_spec),
        "w3": ParamDecl((E, d, f), w_in_spec),
        "w2": ParamDecl((E, f, d), w_out_spec, std=out_std),
    }


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _dispatch_indices(gates, top_k: int, capacity: int):
    """Token->(expert, slot) assignment with per-shard capacity.

    Returns (flat_expert (N,), slot (N,), combine_w (N,)) with slot == capacity
    for dropped assignments (N = T * top_k).
    """
    T, E = gates.shape
    topv, topi = jax.lax.top_k(gates, top_k)  # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    flat_e = topi.reshape(-1)
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_e]
    pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    slot = jnp.where(pos < capacity, pos, capacity)
    return flat_e, slot, topv.reshape(-1)


def _aux_loss(gates, flat_e, top_k: int):
    """Switch-style load-balancing loss (mean over shards taken by caller)."""
    T, E = gates.shape
    frac = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * top_k)
    mean_prob = gates.mean(axis=0)
    return E * jnp.sum(frac * mean_prob)


def _activate(h, act):
    if act == "swiglu":
        h1, h3 = jnp.split(h, 2, axis=-1)
        return jax.nn.silu(h1) * h3
    if act == "relu2":
        return jnp.square(jax.nn.relu(h))
    return jax.nn.gelu(h)


def _psum_scatter_bf16(o, axis_name: str, n: int):
    """reduce-scatter of ``o`` (E, C, d) over its last dim with the wire in
    o's own dtype. An XLA reduce-scatter of a just-downcast bf16 tensor is
    re-promoted to an f32 wire by the excess-precision simplification
    (measured: §Perf G2) — an all_to_all moves raw bf16 payload instead,
    and the receive side sums the n=16 partials locally. The sum stays in
    o.dtype so the simplifier has no f32 round-trip to cancel; a 16-way
    bf16 tree-sum adds <=4 ulps, comparable to bf16 gradient all-reduce."""
    E, C, d = o.shape
    parts = o.reshape(E, C, n, d // n)
    # split over ranks: rank r receives every rank's r-th d-slice stacked
    parts = jax.lax.all_to_all(parts, axis_name, split_axis=2, concat_axis=0,
                               tiled=True)  # (n*E, C, 1, d/n) rank-major
    parts = parts.reshape(n, E, C, d // n)
    return jnp.sum(parts, axis=0)  # (E, C, d/n) in o.dtype


def moe_ffn(x, p, cfg, rules: AxisRules, mesh):
    """Apply the MoE FFN to x: (B, S, d) batch-sharded over ``rules.dp``
    (and sequence-sharded over ``rules.tp`` in "ep_sp" mode).

    Modes (DESIGN.md §4/§6, EXPERIMENTS.md §Perf G1/K1):
      * "ep"      — experts over data axis, d-sliced dispatch, TP up/down.
      * "2d"      — experts replicated, d-sliced dispatch, TP up/down
                    (paper-faithful baseline for E < tp_size).
      * "2d_full" — experts replicated, FULL-d dispatch buffer: the up
                    projection completes locally per f-slice (no psum); only
                    the down projection reduce-scatters, in compute_dtype.
      * "ep_sp"   — full EP with sequence-sharded tokens: experts replicated
                    over model, a2a over data only, no TP collectives.

    Returns (out (B, S, d), aux_loss scalar).
    """
    E, k, d, f = cfg.n_experts, cfg.top_k, cfg.d_model, cfg.d_ff
    mode = cfg.moe_sharding
    tp_ax = rules.tp
    tp_sz = rules.tp_size
    ep_ax = rules.ep
    ep_sz = rules.ep_size if mode in ("ep", "ep_sp") else 1
    d_loc = d // tp_sz
    f_loc = f // tp_sz if rules.tp_if(f) else f
    act = cfg.act
    cf = cfg.capacity_factor
    compute_dtype = jnp.dtype(cfg.compute_dtype)

    decls = moe_decls(cfg, rules)
    # sequence-sharded dispatch only when S divides the model axis. Decode
    # steps (S == 1) fall back to the "ep" compute path: the ep_sp weight
    # layout (E over ep, f over tp) is identical to "ep", and moving the
    # single token through TP psums costs ~nothing while the ep_sp per-
    # layer weight gather costs 274 GB/token on kimi (measured, §Perf K6).
    sp_ok = (mode == "ep_sp" and tp_ax
             and x.shape[1] % max(rules.sizes.get(tp_ax, 1), 1) == 0)
    if mode == "ep_sp" and not sp_ok:
        mode = "ep"
    x_spec = (P(rules.dp, tp_ax, None) if sp_ok
              else P(rules.dp, None, None))
    in_specs = (
        x_spec,
        decls["router"].spec,
        decls["w1"].spec,
        decls["w3"].spec,
        decls["w2"].spec,
    )
    out_specs = (x_spec, P())
    aux_axes = (rules.dp + (tp_ax,)) if sp_ok else rules.dp

    def dispatch(xf, gates, C, flat_e, slot, dd):
        """Scatter token rows (dd-wide) into the (E, C, dd) expert buffer."""
        T = xf.shape[0]
        tok = jnp.arange(T * k, dtype=jnp.int32) // k
        vals = xf[tok].astype(compute_dtype)
        buf = jnp.zeros((E, C + 1, dd), compute_dtype).at[flat_e, slot].set(vals)
        return buf[:, :C]

    def combine(o, flat_e, slot, comb_w, T, dd):
        o_pad = jnp.concatenate(
            [o, jnp.zeros((E, 1, dd), o.dtype)], axis=1)  # slot C == dropped
        picked = o_pad[flat_e, slot] * comb_w[:, None].astype(o.dtype)
        return picked.reshape(T, k, dd).sum(axis=1)

    def body(xl, wr, w1, w3, w2):
        B_loc, S_loc, _ = xl.shape
        T = B_loc * S_loc
        xf = xl.reshape(T, d)
        # bf16 operands with f32 accumulation: an f32 upcast here makes the
        # whole dispatch cotangent f32, doubling its psum wire (§Perf G2)
        gates = jax.nn.softmax(jnp.einsum(
            "td,de->te", xf, wr.astype(xf.dtype),
            preferred_element_type=jnp.float32))
        C = max(8, _round_up(int(np.ceil(T * k / E * cf)), 8))
        flat_e, slot, comb_w = _dispatch_indices(gates, k, C)
        aux = _aux_loss(gates, flat_e, k)
        aux = jax.lax.pmean(aux, aux_axes)

        if mode == "ep_sp":
            # full-d dispatch, a2a over the data axis only; experts compute
            # with per-layer tp-gathered (d, f) weights — the only model-
            # axis traffic is the weight gather (§Perf K1)
            w1l = jax.lax.all_gather(w1, tp_ax, axis=2, tiled=True) \
                if tp_sz > 1 else w1          # (E_loc, d, f)
            w3l = jax.lax.all_gather(w3, tp_ax, axis=2, tiled=True) \
                if tp_sz > 1 else w3
            w2l = jax.lax.all_gather(w2, tp_ax, axis=1, tiled=True) \
                if tp_sz > 1 else w2          # (E_loc, f, d)
            buf = dispatch(xf, gates, C, flat_e, slot, d)  # (E, C, d)
            if ep_sz > 1:
                buf = jax.lax.all_to_all(buf, ep_ax, 0, 1, tiled=True)
            h1 = jnp.einsum("ecd,edf->ecf", buf, w1l.astype(compute_dtype),
                            preferred_element_type=jnp.float32)
            if act == "swiglu":
                h3 = jnp.einsum("ecd,edf->ecf", buf,
                                w3l.astype(compute_dtype),
                                preferred_element_type=jnp.float32)
                h = jnp.concatenate([h1, h3], axis=-1)
            else:
                h = h1
            hh = _activate(h, act).astype(compute_dtype)
            o = jnp.einsum("ecf,efd->ecd", hh, w2l.astype(compute_dtype),
                           preferred_element_type=jnp.float32)
            o = o.astype(compute_dtype)
            if ep_sz > 1:
                o = jax.lax.all_to_all(o, ep_ax, 1, 0, tiled=True)
            out = combine(o, flat_e, slot, comb_w, T, d)
            return out.reshape(B_loc, S_loc, d).astype(xl.dtype), aux

        if mode == "2d_full":
            # full-d dispatch buffer: each TP rank computes its f-slice
            # COMPLETELY (w1 gathered (E, d, f_loc)) — the up-projection
            # psum disappears; only the down projection reduces, and it
            # does so in compute_dtype, not fp32 (§Perf G1)
            fs_axes = rules.fsdp_if(d)
            w1l = jax.lax.all_gather(w1, fs_axes, axis=1, tiled=True) \
                if fs_axes else w1
            w3l = jax.lax.all_gather(w3, fs_axes, axis=1, tiled=True) \
                if fs_axes else w3
            w2l = jax.lax.all_gather(w2, fs_axes, axis=2, tiled=True) \
                if fs_axes else w2
            buf = dispatch(xf, gates, C, flat_e, slot, d)  # (E, C, d)
            h1 = jnp.einsum("ecd,edf->ecf", buf, w1l.astype(compute_dtype),
                            preferred_element_type=jnp.float32)
            if act == "swiglu":
                h3 = jnp.einsum("ecd,edf->ecf", buf,
                                w3l.astype(compute_dtype),
                                preferred_element_type=jnp.float32)
                h = jnp.concatenate([h1, h3], axis=-1)
            else:
                h = h1
            hh = _activate(h, act).astype(compute_dtype)
            o = jnp.einsum("ecf,efd->ecd", hh, w2l.astype(compute_dtype),
                           preferred_element_type=jnp.float32)
            o = o.astype(compute_dtype)
            if tp_sz > 1:
                # a2a + local sum == reduce-scatter with a bf16 wire
                o = _psum_scatter_bf16(o, tp_ax, tp_sz)
            out_slice = combine(o, flat_e, slot, comb_w, T,
                                d_loc if tp_sz > 1 else d)
            if tp_sz > 1:
                out = jax.lax.all_gather(out_slice, tp_ax, axis=1, tiled=True)
            else:
                out = out_slice
            return out.reshape(B_loc, S_loc, d).astype(xl.dtype), aux

        # ---- "ep" / "2d": d-sliced dispatch + TP up/down (baseline) ----
        r = jax.lax.axis_index(tp_ax) if tp_sz > 1 else 0
        x_slice = jax.lax.dynamic_slice_in_dim(xf, r * d_loc, d_loc, axis=1)
        buf = dispatch(x_slice, gates, C, flat_e, slot, d_loc)  # (E, C, d_loc)

        if mode == "ep" and ep_sz > 1:
            buf = jax.lax.all_to_all(buf, ep_ax, split_axis=0, concat_axis=1,
                                     tiled=True)  # (E_loc, ep*C, d_loc)

        # --- expert weights: local d-slice of (E?, d, f_loc) ---
        if mode == "ep":
            w1l, w3l, w2l = w1, w3, w2  # (E_loc, d, f_loc), (E_loc, f_loc, d)
        else:
            fs_axes = rules.fsdp_if(d)
            if fs_axes:
                w1l = jax.lax.all_gather(w1, fs_axes, axis=1, tiled=True)
                w3l = jax.lax.all_gather(w3, fs_axes, axis=1, tiled=True)
                w2l = jax.lax.all_gather(w2, fs_axes, axis=2, tiled=True)
            else:
                w1l, w3l, w2l = w1, w3, w2
        w1s = jax.lax.dynamic_slice_in_dim(w1l, r * d_loc, d_loc, axis=1)
        w3s = jax.lax.dynamic_slice_in_dim(w3l, r * d_loc, d_loc, axis=1)

        # up-projection: contract the local d-slice, then complete with
        # psum. The per-rank partials are f32 accumulations; the cross-rank
        # reduction moves compute_dtype (bf16 wire — §Perf G4).
        h1 = jnp.einsum("ecd,edf->ecf", buf, w1s.astype(compute_dtype),
                        preferred_element_type=jnp.float32)
        if act == "swiglu":
            h3 = jnp.einsum("ecd,edf->ecf", buf, w3s.astype(compute_dtype),
                            preferred_element_type=jnp.float32)
            h = jnp.concatenate([h1, h3], axis=-1)
        else:
            h = h1
        if tp_sz > 1:
            h = jax.lax.psum(h.astype(compute_dtype), tp_ax)
        hh = _activate(h, act).astype(compute_dtype)

        # down-projection: partial over f_loc, reduce-scatter d over TP
        # (compute_dtype on the wire)
        o = jnp.einsum("ecf,efd->ecd", hh, w2l.astype(compute_dtype),
                       preferred_element_type=jnp.float32)
        o = o.astype(compute_dtype)
        if tp_sz > 1:
            o = jax.lax.psum_scatter(o, tp_ax, scatter_dimension=2, tiled=True)

        if mode == "ep" and ep_sz > 1:
            o = jax.lax.all_to_all(o, ep_ax, split_axis=1, concat_axis=0,
                                   tiled=True)  # (E, C, d_loc)

        out_slice = combine(o, flat_e, slot, comb_w, T, d_loc)
        if tp_sz > 1:
            out = jax.lax.all_gather(out_slice, tp_ax, axis=1, tiled=True)
        else:
            out = out_slice
        return out.reshape(B_loc, S_loc, d).astype(xl.dtype), aux

    fn = jax.shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_vma=False)
    return fn(x, p["router"], p["w1"], p["w3"], p["w2"])
