"""Mamba-1 selective-SSM block (falcon-mamba-7b; also used by hymba hybrid).

Training/prefill uses a chunked associative scan: ``lax.scan`` over sequence
chunks carrying the SSM state, with a parallel ``associative_scan`` inside the
chunk — the hidden state (B, chunk, d_inner, N) is materialized only per
chunk, never for the full sequence. Decode is a single O(1) state update,
which is what makes the ``long_500k`` cell sub-quadratic (DESIGN.md §6).

Sharding: d_inner is TP-sharded over ``model``; everything inside the scan is
elementwise in d_inner, so the only collectives are the in/out projections'
FSDP weight gathers and the out-projection psum (handled by GSPMD).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.layers import AxisRules, ParamDecl


def ssm_decls(cfg, rules: AxisRules) -> dict:
    d = cfg.d_model
    di, n, r, W = (cfg.resolved_d_inner, cfg.ssm_state,
                   cfg.resolved_dt_rank, cfg.conv_width)
    fs, tp = rules.fsdp_if(d), rules.tp_if(di)
    out_std = 0.02 / np.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "in_proj": ParamDecl((d, 2 * di), P(fs, tp)),
        "conv_w": ParamDecl((di, W), P(tp, None), std=0.1),
        "conv_b": ParamDecl((di,), P(tp), init="zeros"),
        "x_proj": ParamDecl((di, r + 2 * n), P(tp, None)),
        "dt_proj": ParamDecl((r, di), P(None, tp), std=0.1),
        "dt_bias": ParamDecl((di,), P(tp), init="zeros"),
        "a_log": ParamDecl((di, n), P(tp, None), init="ones"),
        "d_skip": ParamDecl((di,), P(tp), init="ones"),
        "out_proj": ParamDecl((di, d), P(tp, fs), std=out_std),
    }


def _ssm_coeffs(x1, p, cfg):
    """From conv'd activations x1 (..., di) compute (dA, dBx, C) fp32."""
    n, r = cfg.ssm_state, cfg.resolved_dt_rank
    proj = x1 @ p["x_proj"]  # (..., r + 2n)
    dt_r, B, C = jnp.split(proj.astype(jnp.float32), [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (..., di)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, n)
    dA = jnp.exp(dt[..., None] * A)  # (..., di, n)
    dBx = (dt * x1.astype(jnp.float32))[..., None] * B[..., None, :]
    return dA, dBx, C


def _causal_conv(x, p, W: int):
    """Depthwise causal conv via W shifted adds. x: (B, S, di)."""
    out = x * p["conv_w"][:, W - 1]
    for w in range(W - 1):
        shift = W - 1 - w
        out += jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]] \
            * p["conv_w"][:, w]
    return out + p["conv_b"]


def ssm_apply_seq(p, x, cfg, *, chunk: int = 256, h0=None, conv_state=None):
    """Full-sequence SSM. x: (B, S, d_model). Returns (y, final_cache)."""
    B, S, _ = x.shape
    di, n, W = cfg.resolved_d_inner, cfg.ssm_state, cfg.conv_width
    xz = x @ p["in_proj"]
    x1, z = jnp.split(xz, 2, axis=-1)  # (B, S, di)
    if conv_state is not None:  # continuation: prepend cached tail
        x1_ext = jnp.concatenate([conv_state, x1], axis=1)
        xc = _causal_conv(x1_ext, p, W)[:, W - 1:]
    else:
        xc = _causal_conv(x1, p, W)
    xc = jax.nn.silu(xc)

    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    xcs = xc.reshape(B, n_chunks, chunk, di).transpose(1, 0, 2, 3)

    # the chunk body is rematerialized: without it the scan transpose
    # stacks the associative-scan tree ((B, chunk, d_inner, N) at every
    # level) as backward residuals — measured 193s -> 118s memory term on
    # falcon-mamba train_4k (§Perf F1). A per-timestep sequential scan was
    # also tried and refuted (810s — XLA residual stacking per step); the
    # TPU deploy path is the fused Pallas kernel (kernels/ssm_scan.py).
    @partial(jax.checkpoint, prevent_cse=False)
    def body(h, xc_c):
        dA, dBx, C = _ssm_coeffs(xc_c, p, cfg)  # (B, c, di, n) fp32

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        Acum, Bcum = jax.lax.associative_scan(comb, (dA, dBx), axis=1)
        hs = Acum * h[:, None] + Bcum  # (B, c, di, n)
        y = jnp.einsum("bcdn,bcn->bcd", hs, C)
        return hs[:, -1], y

    h = jnp.zeros((B, di, n), jnp.float32) if h0 is None else h0
    h, ys = jax.lax.scan(body, h, xcs)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    y = (y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    cache = {"conv": x1[:, S - (W - 1):, :], "ssm": h}
    return out, cache


def ssm_apply_decode(p, x, cache, cfg):
    """Single-token SSM step. x: (B, d_model); cache: {conv (B,W-1,di), ssm}."""
    W = cfg.conv_width
    xz = x @ p["in_proj"]
    x1, z = jnp.split(xz, 2, axis=-1)  # (B, di)
    win = jnp.concatenate([cache["conv"], x1[:, None]], axis=1)  # (B, W, di)
    xc = jnp.einsum("bwd,dw->bd", win, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    dA, dBx, C = _ssm_coeffs(xc, p, cfg)  # (B, di, n), (B, n)
    h = dA * cache["ssm"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, C)
    y = (y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, {"conv": win[:, 1:], "ssm": h}


def ssm_cache_shape(cfg, batch: int, dtype):
    di, n, W = cfg.resolved_d_inner, cfg.ssm_state, cfg.conv_width
    return {
        "conv": jax.ShapeDtypeStruct((batch, W - 1, di), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, di, n), jnp.float32),
    }


def ssm_cache_specs(cfg, rules: AxisRules, bspec=None):
    di = cfg.resolved_d_inner
    tp = rules.tp_if(di)
    return {"conv": P(bspec, None, tp), "ssm": P(bspec, tp, None)}
