"""Generic decoder-only LM covering the dense / moe / vlm / hybrid / ssm
families. One scan-over-layers stack with pluggable attention, SSM, and FFN
sub-blocks; three entry points (train loss, prefill, decode) per DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    AxisRules, ParamDecl, attention_uses_head_tp, attn_decls, build_params,
    decl_specs, decl_shapes, decode_attention, embed_decls, embed_tokens,
    flash_attention_xla, make_wsc, mlp_apply, mlp_decls, rms_norm, rope,
    stack_decls, token_xent, unembed,
)

AUX_LOSS_WEIGHT = 0.01


@dataclasses.dataclass
class Model:
    cfg: Any
    rules: AxisRules
    mesh: Any
    decls: dict
    init: Callable
    param_specs: Any
    param_shapes: Any
    loss: Callable  # (params, batch) -> (scalar, metrics)
    prefill: Callable  # (params, batch) -> (logits, cache)
    decode: Callable  # (params, cache, tokens, pos) -> (logits, cache)
    cache_shapes: Callable  # (batch, seq) -> pytree of ShapeDtypeStruct
    cache_specs: Callable  # () -> pytree of PartitionSpec
    make_cache: Callable  # (batch, seq) -> zero-filled cache


def _scan_layers(body, carry, xs, remat: str):
    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.lax.scan(body, carry, xs)


def build_decoder_lm(cfg, rules: AxisRules, mesh) -> Model:
    wsc = make_wsc(mesh)
    head_tp = attention_uses_head_tp(cfg, rules)
    has_attn = cfg.family != "ssm"
    has_ssm = cfg.ssm_state > 0
    has_mlp = cfg.d_ff > 0
    is_moe = cfg.n_experts > 0
    is_vlm = cfg.family == "vlm"
    window = cfg.sliding_window
    eps = cfg.norm_eps
    cdt = jnp.dtype(cfg.compute_dtype)
    D = cfg.resolved_head_dim
    H, KH = cfg.n_heads, cfg.n_kv_heads
    # sequence parallelism: the residual stream keeps S sharded over the
    # model axis between layers (activation memory and HBM traffic drop
    # tp-fold; pairs with the "ep_sp" MoE mode) — §Perf K1
    assert not (cfg.seq_shard and has_ssm), \
        "seq_shard is incompatible with sequential SSM state"
    sp = rules.tp if cfg.seq_shard else None

    # ---------------- declarations ----------------
    block: dict = {"ln1": ParamDecl((cfg.d_model,), P(None), init="ones")}
    if has_attn:
        block["attn"] = attn_decls(cfg, rules)
    if has_ssm:
        block["ssm"] = ssm_lib.ssm_decls(cfg, rules)
    if has_mlp:
        block["ln2"] = ParamDecl((cfg.d_model,), P(None), init="ones")
        block["ffn"] = (moe_lib.moe_decls(cfg, rules) if is_moe
                        else mlp_decls(cfg, rules))
    decls = {"embed": embed_decls(cfg, rules),
             "layers": stack_decls(block, cfg.n_layers)}

    pdt = jnp.dtype(cfg.param_dtype)
    specs = decl_specs(decls)
    shapes = decl_shapes(decls, pdt)

    def init(rng):
        return build_params(decls, rng, pdt)

    # ---------------- attention ----------------
    def attn_seq(pl, x, bspec, emit_cache: bool):
        """Full-sequence attention (train/prefill). x: (B, S, d)."""
        B, S, _ = x.shape
        if head_tp:
            x = wsc(x, bspec, None, None)
        else:
            x = wsc(x, bspec, rules.tp, None)  # sequence-TP
        q = jnp.einsum("bsd,dhk->bshk", x, pl["wq"].astype(cdt))
        k = jnp.einsum("bsd,dhk->bshk", x, pl["wk"].astype(cdt))
        v = jnp.einsum("bsd,dhk->bshk", x, pl["wv"].astype(cdt))
        if not head_tp:
            # pin the projections to the S-shard BEFORE the KV gather:
            # without this GSPMD gathers x to full S and every model rank
            # runs the full-S projection (+ its full-S f32 backward) —
            # measured at ~7 TB/step of HBM traffic (§Perf K4/G5)
            q = wsc(q, bspec, rules.tp, None, None)
            k = wsc(k, bspec, rules.tp, None, None)
            v = wsc(v, bspec, rules.tp, None, None)
        pos = jnp.arange(S)[None]
        q, k = rope(q, pos, cfg.rope_theta), rope(k, pos, cfg.rope_theta)
        if head_tp:
            q = wsc(q, bspec, None, rules.tp_if(H), None)
            k = wsc(k, bspec, None, rules.tp_if(KH), None)
            v = wsc(v, bspec, None, rules.tp_if(KH), None)
        else:
            q = wsc(q, bspec, rules.tp, None, None)
            k = wsc(k, bspec, None, None, None)  # gather KV over model
            v = wsc(v, bspec, None, None, None)
        o = flash_attention_xla(q, k, v, causal=True, window=window,
                                chunk=cfg.attn_chunk,
                                score_dtype=cfg.score_dtype)
        out = jnp.einsum("bshk,hkd->bsd", o, pl["wo"].astype(cdt))
        out = wsc(out, bspec, sp, None)
        cache = None
        if emit_cache:
            if window:
                w_eff = min(window, S)
                positions = jnp.arange(S - w_eff, S)
                slots = positions % window
                ring = lambda t: jnp.zeros(
                    (B, window) + t.shape[2:], t.dtype).at[:, slots].set(
                        t[:, -w_eff:])
                slot_pos = jnp.full((window,), -(2 ** 30), jnp.int32
                                    ).at[slots].set(positions)
                cache = {"k": wsc(ring(k), bspec, rules.kv_seq, None, None),
                         "v": wsc(ring(v), bspec, rules.kv_seq, None, None),
                         "slot_pos": slot_pos}
            else:
                cache = {"k": wsc(k, bspec, rules.kv_seq, None, None),
                         "v": wsc(v, bspec, rules.kv_seq, None, None)}
        return out, cache

    def attn_dec(pl, x, cache, pos, bspec):
        """Single-token attention. x: (B, d)."""
        B = x.shape[0]
        q = jnp.einsum("bd,dhk->bhk", x, pl["wq"].astype(cdt))
        k = jnp.einsum("bd,dhk->bhk", x, pl["wk"].astype(cdt))
        v = jnp.einsum("bd,dhk->bhk", x, pl["wv"].astype(cdt))
        posb = jnp.full((1, 1), pos)
        q = rope(q[:, None], posb, cfg.rope_theta)[:, 0]
        k = rope(k[:, None], posb, cfg.rope_theta)[:, 0]
        if window:
            slot = pos % window
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k[:, None], slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v[:, None], slot, axis=1)
            sp = jax.lax.dynamic_update_slice_in_dim(
                cache["slot_pos"], pos[None].astype(jnp.int32), slot, axis=0)
            new_cache = {"k": kc, "v": vc, "slot_pos": sp}
            o = decode_attention(q, kc, vc, pos, window=window,
                                 slot_pos=sp[None])
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k[:, None], pos, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v[:, None], pos, axis=1)
            kc = wsc(kc, bspec, rules.kv_seq, None, None)
            vc = wsc(vc, bspec, rules.kv_seq, None, None)
            new_cache = {"k": kc, "v": vc}
            o = decode_attention(q, kc, vc, pos)
        out = jnp.einsum("bhk,hkd->bd", o, pl["wo"].astype(cdt))
        return out, new_cache

    # ---------------- block bodies ----------------
    def ffn_apply(pl, x, bspec):
        if is_moe:
            return moe_lib.moe_ffn(x, pl["ffn"], cfg, rules, mesh)
        x = wsc(x, bspec, sp, None)
        return mlp_apply(x, pl["ffn"], cfg.act), 0.0

    def seq_block(pl, x, bspec, emit_cache):
        h = rms_norm(x, pl["ln1"], eps)
        cache = {}
        if has_attn and has_ssm:  # hybrid: parallel heads
            ao, kv = attn_seq(pl["attn"], h, bspec, emit_cache)
            so, sc = ssm_lib.ssm_apply_seq(pl["ssm"], h, cfg)
            x = x + (ao + so) * 0.5
            if emit_cache:
                cache = dict(kv, **sc)
        elif has_attn:
            ao, kv = attn_seq(pl["attn"], h, bspec, emit_cache)
            x = x + ao
            if emit_cache:
                cache = kv
        else:  # pure ssm
            so, sc = ssm_lib.ssm_apply_seq(pl["ssm"], h, cfg)
            x = x + so
            if emit_cache:
                cache = sc
        aux = jnp.zeros((), jnp.float32)
        if has_mlp:
            h2 = rms_norm(x, pl["ln2"], eps)
            f, aux = ffn_apply(pl, h2, bspec)
            x = x + f
        return x, cache, aux

    def dec_block(pl, x, cache, pos, bspec):
        h = rms_norm(x, pl["ln1"], eps)
        new_cache = {}
        if has_attn and has_ssm:
            ao, kvc = attn_dec(pl["attn"], h, cache, pos, bspec)
            so, sc = ssm_lib.ssm_apply_decode(pl["ssm"], h, cache, cfg)
            x = x + (ao + so) * 0.5
            new_cache = dict(kvc, **sc)
        elif has_attn:
            ao, new_cache = attn_dec(pl["attn"], h, cache, pos, bspec)
            x = x + ao
        else:
            so, new_cache = ssm_lib.ssm_apply_decode(pl["ssm"], h, cache, cfg)
            x = x + so
        if has_mlp:
            h2 = rms_norm(x, pl["ln2"], eps)
            if is_moe:
                f, _ = moe_lib.moe_ffn(h2[:, None], pl["ffn"], cfg, rules, mesh)
                f = f[:, 0]
            else:
                f = mlp_apply(h2, pl["ffn"], cfg.act)
            x = x + f
        return x, new_cache

    # ---------------- stacks ----------------
    def run_seq(params, x, bspec, emit_cache: bool):
        def body(carry, pl):
            x, aux = carry
            x, cache, a = seq_block(pl, x, bspec, emit_cache)
            return (x, aux + a), (cache if emit_cache else 0)

        (x, aux), caches = _scan_layers(
            body, (x, jnp.zeros((), jnp.float32)), params["layers"], cfg.remat)
        return x, aux, (caches if emit_cache else None)

    def run_dec(params, x, caches, pos, bspec):
        def body(x, inputs):
            pl, cache = inputs
            x, new_cache = dec_block(pl, x, cache, pos, bspec)
            return x, new_cache

        return jax.lax.scan(body, x, (params["layers"], caches))

    # ---------------- embedding helpers ----------------
    def _embed_in(params, batch):
        tokens = batch["tokens"]
        bspec = rules.dp_if(tokens.shape[0])
        x = embed_tokens(params["embed"], tokens, cdt)
        n_front = 0
        if is_vlm:
            front = batch["patches"].astype(cdt)
            x = jnp.concatenate([front, x], axis=1)
            n_front = front.shape[1]
        x = wsc(x, bspec, sp, None)
        return x, bspec, n_front

    # ---------------- public entry points ----------------
    def loss(params, batch):
        x, bspec, n_front = _embed_in(params, batch)
        x, aux, _ = run_seq(params, x, bspec, emit_cache=False)
        if n_front:
            x = x[:, n_front:]
        logits = unembed(params["embed"], x, eps)
        logits = wsc(logits, bspec, sp,
                     None if sp else rules.tp_if(cfg.vocab_padded))
        labels = batch["labels"]
        ce = token_xent(logits, labels, mask=labels >= 0)
        total = ce + AUX_LOSS_WEIGHT * aux
        return total, {"loss": ce, "aux_loss": aux}

    def prefill(params, batch):
        x, bspec, n_front = _embed_in(params, batch)
        x, _, caches = run_seq(params, x, bspec, emit_cache=True)
        logits = unembed(params["embed"], x[:, -1], eps)
        return logits, caches

    def decode(params, caches, tokens, pos):
        bspec = rules.dp_if(tokens.shape[0])
        x = embed_tokens(params["embed"], tokens[:, 0], cdt)
        x = wsc(x, bspec, None)
        x, new_caches = run_dec(params, x, caches, pos, bspec)
        logits = unembed(params["embed"], x, eps)
        return logits, new_caches

    # ---------------- cache plumbing ----------------
    def cache_shapes(batch: int, seq: int):
        L = cfg.n_layers
        out = {}
        if has_attn:
            s = min(seq, window) if window else seq
            out["k"] = jax.ShapeDtypeStruct((L, batch, s, KH, D), cdt)
            out["v"] = jax.ShapeDtypeStruct((L, batch, s, KH, D), cdt)
            if window:
                out["slot_pos"] = jax.ShapeDtypeStruct((L, window), jnp.int32)
        if has_ssm:
            sc = ssm_lib.ssm_cache_shape(cfg, batch, cdt)
            out.update({k: jax.ShapeDtypeStruct((L,) + v.shape, v.dtype)
                        for k, v in sc.items()})
        return out

    def cache_specs(batch: int):
        out = {}
        bspec = rules.dp_if(batch)
        if has_attn:
            out["k"] = P(None, bspec, rules.kv_seq, None, None)
            out["v"] = P(None, bspec, rules.kv_seq, None, None)
            if window:
                out["slot_pos"] = P(None, None)
        if has_ssm:
            sc = ssm_lib.ssm_cache_specs(cfg, rules, bspec)
            out.update({k: P(*((None,) + tuple(v))) for k, v in sc.items()})
        return out

    def make_cache(batch: int, seq: int):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            cache_shapes(batch, seq))

    return Model(cfg=cfg, rules=rules, mesh=mesh, decls=decls, init=init,
                 param_specs=specs, param_shapes=shapes, loss=loss,
                 prefill=prefill, decode=decode, cache_shapes=cache_shapes,
                 cache_specs=cache_specs, make_cache=make_cache)
