"""Encoder-decoder backbone (whisper-tiny). The audio conv frontend is a stub
per the brief: inputs are precomputed frame embeddings (B, n_frames, d).

Positional encoding is sinusoidal for both stacks (Whisper's decoder uses a
learned table of its real 448-token maximum; the assigned stress shapes go to
32k, so a fixed-size learned table cannot apply — noted in DESIGN.md §6).
6 heads do not divide the 16-way TP axis: attention is head-replicated, only
the MLP is TP-sharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.layers import (
    AxisRules, ParamDecl, attn_decls, build_params, decl_shapes, decl_specs,
    decode_attention, embed_decls, embed_tokens, flash_attention_xla,
    make_wsc, mlp_apply, mlp_decls, rms_norm, stack_decls, token_xent,
    unembed,
)
from repro.models.transformer import Model, _scan_layers


def sinusoids(length: int, channels: int, offset=0):
    """Standard sin/cos positional embedding (length, channels), fp32."""
    assert channels % 2 == 0
    log_timescale = np.log(10_000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    pos = (offset + jnp.arange(length))[:, None].astype(jnp.float32)
    ang = pos * inv[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def build_encdec(cfg, rules: AxisRules, mesh) -> Model:
    wsc = make_wsc(mesh)
    eps = cfg.norm_eps
    cdt = jnp.dtype(cfg.compute_dtype)
    d, H, KH, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    F = cfg.n_frontend_tokens

    ln = lambda: ParamDecl((d,), P(None), init="ones")
    enc_block = {"ln1": ln(), "attn": attn_decls(cfg, rules),
                 "ln2": ln(), "ffn": mlp_decls(cfg, rules)}
    dec_block = {"ln1": ln(), "attn": attn_decls(cfg, rules),
                 "lnx": ln(), "xattn": attn_decls(cfg, rules),
                 "ln2": ln(), "ffn": mlp_decls(cfg, rules)}
    decls = {
        "embed": embed_decls(cfg, rules),
        "enc_layers": stack_decls(enc_block, cfg.enc_layers),
        "enc_ln_post": ln(),
        "dec_layers": stack_decls(dec_block, cfg.n_layers),
    }
    pdt = jnp.dtype(cfg.param_dtype)

    def init(rng):
        return build_params(decls, rng, pdt)

    def _qkv(pl, x):
        q = jnp.einsum("bsd,dhk->bshk", x, pl["wq"].astype(cdt))
        k = jnp.einsum("bsd,dhk->bshk", x, pl["wk"].astype(cdt))
        v = jnp.einsum("bsd,dhk->bshk", x, pl["wv"].astype(cdt))
        return q, k, v

    def _proj_out(pl, o):
        return jnp.einsum("bshk,hkd->bsd", o, pl["wo"].astype(cdt))

    def attn(pl, xq, xkv, *, causal, chunk, bspec=None):
        """Sequence-TP attention: 6 heads don't divide the 16-way model
        axis, so Q's sequence dim shards over ``model`` instead and KV is
        gathered — per-device score traffic drops tp_size-fold (§Perf W4)."""
        q, _, _ = _qkv(pl, xq)
        _, k, v = _qkv(pl, xkv)
        q = wsc(q, bspec, rules.tp, None, None)
        # pin to the S-shard before gathering (see transformer.attn_seq)
        k = wsc(k, bspec, rules.tp, None, None)
        v = wsc(v, bspec, rules.tp, None, None)
        k = wsc(k, bspec, None, None, None)
        v = wsc(v, bspec, None, None, None)
        o = flash_attention_xla(q, k, v, causal=causal, chunk=chunk,
                                score_dtype=cfg.score_dtype)
        return _proj_out(pl, o), (k, v)

    def encode(params, frames, bspec):
        x = frames.astype(cdt) + sinusoids(F, d).astype(cdt)[None]
        x = wsc(x, bspec, None, None)

        def body(x, pl):
            h = rms_norm(x, pl["ln1"], eps)
            ao, _ = attn(pl["attn"], h, h, causal=False, chunk=cfg.attn_chunk,
                         bspec=bspec)
            x = x + ao
            x = x + mlp_apply(rms_norm(x, pl["ln2"], eps), pl["ffn"], cfg.act)
            return x, 0

        x, _ = _scan_layers(body, x, params["enc_layers"], cfg.remat)
        return rms_norm(x, params["enc_ln_post"], eps)

    def run_decoder_seq(params, enc_out, tokens, bspec, emit_cache):
        S = tokens.shape[1]
        x = embed_tokens(params["embed"], tokens, cdt)
        x = x + sinusoids(S, d).astype(cdt)[None]
        x = wsc(x, bspec, None, None)

        def body(x, pl):
            h = rms_norm(x, pl["ln1"], eps)
            ao, (k, v) = attn(pl["attn"], h, h, causal=True,
                              chunk=cfg.attn_chunk, bspec=bspec)
            x = x + ao
            hx = rms_norm(x, pl["lnx"], eps)
            xo, (xk, xv) = attn(pl["xattn"], hx, enc_out, causal=False,
                                chunk=min(cfg.attn_chunk, F), bspec=bspec)
            x = x + xo
            x = x + mlp_apply(rms_norm(x, pl["ln2"], eps), pl["ffn"], cfg.act)
            cache = 0
            if emit_cache:
                cache = {"k": wsc(k, bspec, rules.kv_seq, None, None),
                         "v": wsc(v, bspec, rules.kv_seq, None, None),
                         "xk": xk, "xv": xv}
            return x, cache

        x, caches = _scan_layers(body, x, params["dec_layers"], cfg.remat)
        return x, (caches if emit_cache else None)

    # ---------------- public API ----------------
    def loss(params, batch):
        tokens = batch["tokens"]
        bspec = rules.dp_if(tokens.shape[0])
        enc_out = encode(params, batch["frames"], bspec)
        x, _ = run_decoder_seq(params, enc_out, tokens, bspec, False)
        logits = unembed(params["embed"], x, eps)
        logits = wsc(logits, bspec, None, rules.tp_if(cfg.vocab_padded))
        labels = batch["labels"]
        ce = token_xent(logits, labels, mask=labels >= 0)
        return ce, {"loss": ce, "aux_loss": jnp.zeros((), jnp.float32)}

    def prefill(params, batch):
        tokens = batch["tokens"]
        bspec = rules.dp_if(tokens.shape[0])
        enc_out = encode(params, batch["frames"], bspec)
        x, caches = run_decoder_seq(params, enc_out, tokens, bspec, True)
        logits = unembed(params["embed"], x[:, -1], eps)
        return logits, caches

    def decode(params, caches, tokens, pos):
        B = tokens.shape[0]
        bspec = rules.dp_if(B)
        x = embed_tokens(params["embed"], tokens[:, 0], cdt)
        x = x + sinusoids(1, d, offset=pos).astype(cdt)[0]
        x = wsc(x, bspec, None)

        def body(x, inputs):
            pl, cache = inputs
            h = rms_norm(x, pl["ln1"], eps)
            q = jnp.einsum("bd,dhk->bhk", h, pl["attn"]["wq"].astype(cdt))
            k = jnp.einsum("bd,dhk->bhk", h, pl["attn"]["wk"].astype(cdt))
            v = jnp.einsum("bd,dhk->bhk", h, pl["attn"]["wv"].astype(cdt))
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k[:, None], pos, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v[:, None], pos, axis=1)
            kc = wsc(kc, bspec, rules.kv_seq, None, None)
            vc = wsc(vc, bspec, rules.kv_seq, None, None)
            o = decode_attention(q, kc, vc, pos)
            x = x + jnp.einsum("bhk,hkd->bd", o, pl["attn"]["wo"].astype(cdt))
            hx = rms_norm(x, pl["lnx"], eps)
            qx = jnp.einsum("bd,dhk->bhk", hx, pl["xattn"]["wq"].astype(cdt))
            ox = decode_attention(qx, cache["xk"], cache["xv"], F - 1)
            x = x + jnp.einsum("bhk,hkd->bd", ox,
                               pl["xattn"]["wo"].astype(cdt))
            x = x + mlp_apply(rms_norm(x, pl["ln2"], eps), pl["ffn"], cfg.act)
            return x, {"k": kc, "v": vc, "xk": cache["xk"], "xv": cache["xv"]}

        x, new_caches = jax.lax.scan(body, x, (params["dec_layers"], caches))
        logits = unembed(params["embed"], x, eps)
        return logits, new_caches

    # ---------------- cache plumbing ----------------
    def cache_shapes(batch: int, seq: int):
        L = cfg.n_layers
        return {
            "k": jax.ShapeDtypeStruct((L, batch, seq, KH, D), cdt),
            "v": jax.ShapeDtypeStruct((L, batch, seq, KH, D), cdt),
            "xk": jax.ShapeDtypeStruct((L, batch, F, KH, D), cdt),
            "xv": jax.ShapeDtypeStruct((L, batch, F, KH, D), cdt),
        }

    def cache_specs(batch: int):
        bspec = rules.dp_if(batch)
        return {
            "k": P(None, bspec, rules.kv_seq, None, None),
            "v": P(None, bspec, rules.kv_seq, None, None),
            "xk": P(None, bspec, None, None, None),
            "xv": P(None, bspec, None, None, None),
        }

    def make_cache(batch: int, seq: int):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            cache_shapes(batch, seq))

    return Model(cfg=cfg, rules=rules, mesh=mesh, decls=decls, init=init,
                 param_specs=decl_specs(decls),
                 param_shapes=decl_shapes(decls, pdt), loss=loss,
                 prefill=prefill, decode=decode, cache_shapes=cache_shapes,
                 cache_specs=cache_specs, make_cache=make_cache)
