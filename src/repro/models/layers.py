"""Shared model-layer machinery: params-as-declarations, norms, RoPE,
blockwise (flash-style) attention, decode attention over sharded KV caches,
and MLP variants.

Sharding policy (DESIGN.md §4):
  * activations are batch-sharded over the data-parallel axes (``rules.dp``);
  * weights are FSDP-sharded on their input dim (``rules.fsdp``) and
    TP-sharded on heads / d_ff (``rules.tp``) when divisible;
  * GQA archs whose KV-head count does not divide the TP axis use
    sequence-TP attention (Q sequence sharded over ``model``, KV gathered);
  * decode KV caches shard their sequence dim over ``model`` (``rules.kv_seq``).
"""
from __future__ import annotations

import dataclasses
import zlib
from functools import partial
from typing import Callable, Mapping, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# --------------------------------------------------------------------------
# Axis rules
# --------------------------------------------------------------------------


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= x
    return out


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Maps logical parallelism roles onto mesh axis names."""

    dp: tuple  # batch axes, e.g. ("pod", "data") or ("data",)
    fsdp: tuple  # weight input-dim sharding axes (ZeRO-3 style)
    tp: Optional[str]  # tensor-parallel axis ("model")
    ep: tuple  # expert-parallel axes (MoE EP all-to-all group)
    kv_seq: Optional[str]  # axis for decode KV-cache sequence sharding
    sizes: Mapping[str, int]  # mesh axis name -> size

    # -- helpers -----------------------------------------------------------
    def axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return _prod(self.sizes[a] for a in axes)

    @property
    def dp_size(self) -> int:
        return self.axis_size(self.dp)

    @property
    def ep_size(self) -> int:
        return self.axis_size(self.ep)

    @property
    def tp_size(self) -> int:
        return self.axis_size(self.tp) if self.tp else 1

    def tp_if(self, n: int):
        """TP axis if ``n`` divides evenly, else None (replicate)."""
        return self.tp if (self.tp and n % self.sizes[self.tp] == 0) else None

    def fsdp_if(self, n: int):
        """FSDP axes if ``n`` divides evenly, else None."""
        if self.fsdp and n % self.axis_size(self.fsdp) == 0:
            return self.fsdp
        return None

    def dp_if(self, n: int):
        if self.dp and n % self.dp_size == 0:
            return self.dp
        return None


def single_device_rules() -> AxisRules:
    """Degenerate rules for 1-device smoke meshes."""
    return AxisRules(
        dp=("data",), fsdp=("data",), tp="model", ep=("data",),
        kv_seq="model", sizes={"data": 1, "model": 1},
    )


# --------------------------------------------------------------------------
# Declarative parameters
# --------------------------------------------------------------------------


class ParamDecl(NamedTuple):
    shape: tuple
    spec: P
    init: str = "normal"  # "normal" | "ones" | "zeros"
    std: float = 0.02


def _name_seed(rng, name: str):
    return jax.random.fold_in(rng, zlib.crc32(name.encode()) & 0x7FFFFFFF)


def build_params(decls: Mapping[str, "ParamDecl | Mapping"], rng, dtype):
    """Materialize a (possibly nested) declaration tree into arrays."""
    out = {}
    for name, d in decls.items():
        if isinstance(d, Mapping):
            out[name] = build_params(d, _name_seed(rng, name), dtype)
        elif d.init == "ones":
            out[name] = jnp.ones(d.shape, dtype)
        elif d.init == "zeros":
            out[name] = jnp.zeros(d.shape, dtype)
        else:
            k = _name_seed(rng, name)
            out[name] = (jax.random.normal(k, d.shape, jnp.float32) * d.std).astype(dtype)
    return out


def decl_specs(decls):
    out = {}
    for name, d in decls.items():
        out[name] = decl_specs(d) if isinstance(d, Mapping) else d.spec
    return out


def decl_shapes(decls, dtype):
    out = {}
    for name, d in decls.items():
        if isinstance(d, Mapping):
            out[name] = decl_shapes(d, dtype)
        else:
            out[name] = jax.ShapeDtypeStruct(d.shape, dtype)
    return out


def stack_decls(decls, n_layers: int):
    """Prefix every leaf with a layer dim (for lax.scan over the stack)."""
    out = {}
    for name, d in decls.items():
        if isinstance(d, Mapping):
            out[name] = stack_decls(d, n_layers)
        else:
            out[name] = ParamDecl((n_layers,) + tuple(d.shape),
                                  P(*((None,) + tuple(d.spec))), d.init, d.std)
    return out


# --------------------------------------------------------------------------
# Primitive layers
# --------------------------------------------------------------------------


def rms_norm(x, scale, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mlp_apply(x, p, act: str):
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w1"]))
    else:  # gelu
        h = jax.nn.gelu(x @ p["w1"])
    return h @ p["w2"]


def mlp_decls(cfg, rules: AxisRules) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    fs, tp = rules.fsdp_if(d), rules.tp_if(f)
    out_std = 0.02 / np.sqrt(2 * max(cfg.n_layers, 1))
    decls = {
        "w1": ParamDecl((d, f), P(fs, tp)),
        "w2": ParamDecl((f, d), P(tp, fs), std=out_std),
    }
    if cfg.act == "swiglu":
        decls["w3"] = ParamDecl((d, f), P(fs, tp))
    return decls


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------


def attn_decls(cfg, rules: AxisRules, name_std: Optional[float] = None) -> dict:
    d, H, KH, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    fs = rules.fsdp_if(d)
    # head-TP only when the *KV* head count divides the TP axis; otherwise the
    # sequence-TP path is used and heads stay replicated.
    head_tp = rules.tp_if(KH) if rules.tp_if(H) else None
    q_tp = rules.tp_if(H) if head_tp else None
    out_std = name_std or 0.02 / np.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "wq": ParamDecl((d, H, D), P(fs, q_tp, None)),
        "wk": ParamDecl((d, KH, D), P(fs, head_tp, None)),
        "wv": ParamDecl((d, KH, D), P(fs, head_tp, None)),
        "wo": ParamDecl((H, D, d), P(q_tp, None, fs), std=out_std),
    }


def attention_uses_head_tp(cfg, rules: AxisRules) -> bool:
    return bool(rules.tp_if(cfg.n_kv_heads) and rules.tp_if(cfg.n_heads))


def flash_attention_xla(q, k, v, *, causal: bool, q_offset=0, window: int = 0,
                        chunk: int = 1024, score_dtype=jnp.float32):
    """Blockwise attention (XLA-native flash): scan over KV chunks carrying
    running (max, sum, acc). Memory is O(S_q * chunk), never O(S_q * S_kv).

    q: (B, Sq, H, D); k/v: (B, Skv, KH, D); GQA via H = KH * G.
    ``q_offset``: absolute position of q[0] (prefill continuation / seq-TP).
    ``window`` > 0 restricts attention to the last ``window`` positions (SWA).

    The chunk body is rematerialized (jax.checkpoint): without it the scan
    transpose stacks every chunk's (Sq, chunk) score/probability tensors as
    backward residuals — measured at ~2 TB of HBM traffic per train step on
    the 4k cells (EXPERIMENTS.md §Perf iteration W1). Recomputing the chunk
    from (q, kc, vc) costs ~1 extra attention forward, pure MXU slack on
    every memory-bound cell.
    """
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = 1.0 / np.sqrt(D)
    qr = q.reshape(B, Sq, KH, G, D)
    Skv = k.shape[1]
    chunk = min(chunk, Skv)
    if Skv % chunk:  # pad KV to a chunk multiple; padding is masked out
        pad = chunk - Skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    q_pos = q_offset + jnp.arange(Sq)

    sdt = jnp.dtype(score_dtype)

    @partial(jax.checkpoint, prevent_cse=False)
    def body(carry, inputs):
        acc, m, l = carry
        kc, vc, c_start = inputs
        # scores in ``score_dtype`` — bf16 halves the dominant HBM stream
        # on memory-bound cells (§Perf W2); running max/sum stay fp32.
        s = jnp.einsum("bqkgd,bckd->bqkgc", qr, kc,
                       preferred_element_type=sdt) * jnp.asarray(scale, sdt)
        kv_pos = c_start + jnp.arange(chunk)
        mask = jnp.broadcast_to(kv_pos[None, :] < Skv, (Sq, chunk))
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        # masked lanes hold s == -inf, so exp() already gives exactly 0 —
        # no second where() materialization needed
        p = jnp.exp(s - m_safe[..., None].astype(sdt))
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(v.dtype), vc,
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new), None

    kc = k.reshape(B, n_chunks, chunk, KH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KH, D).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(n_chunks) * chunk
    acc0 = jnp.zeros((B, Sq, KH, G, D), jnp.float32)
    m0 = jnp.full((B, Sq, KH, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, KH, G), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kc, vc, starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     slot_pos=None):
    """Single-token attention against a (possibly seq-sharded) KV cache.

    q: (B, H, D); caches: (B, S, KH, D); ``pos``: scalar absolute position of
    the current token. With ``window``/``slot_pos`` the cache is a ring buffer
    and ``slot_pos[b, s]`` holds each slot's absolute position.
    """
    B, H, D = q.shape
    KH = k_cache.shape[2]
    G = H // KH
    scale = 1.0 / np.sqrt(D)
    qr = q.reshape(B, KH, G, D)
    s = jnp.einsum("bkgd,bckd->bkgc", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    S = k_cache.shape[1]
    if slot_pos is None:
        valid = (jnp.arange(S) <= pos)[None, :]
    else:
        valid = (slot_pos <= pos)
        if window:
            valid &= slot_pos > pos - window
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    out = jnp.einsum("bkgc,bckd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out / p.sum(axis=-1, keepdims=True)
    return out.reshape(B, H, D).astype(q.dtype)


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------


def embed_decls(cfg, rules: AxisRules) -> dict:
    V, d = cfg.vocab_padded, cfg.d_model
    return {
        "tok": ParamDecl((V, d), P(rules.tp_if(V), rules.fsdp_if(d))),
        "out": ParamDecl((d, V), P(rules.fsdp_if(d), rules.tp_if(V)),
                         std=0.02 / np.sqrt(max(cfg.n_layers, 1))),
        "ln_f": ParamDecl((d,), P(None), init="ones"),
    }


def embed_tokens(emb, tokens, compute_dtype):
    return jnp.take(emb["tok"], tokens, axis=0).astype(compute_dtype)


def unembed(emb, x, eps: float):
    h = rms_norm(x, emb["ln_f"], eps)
    return (h @ emb["out"]).astype(jnp.float32)


def token_xent(logits, labels, mask=None):
    """Stable masked cross-entropy. logits fp32 (B, S, V); labels int (B, S).

    The label pick uses an iota-select instead of take_along_axis: a gather
    over the vocab dim forces GSPMD to all-gather V-sharded logits (the
    full (B, S, V) fp32 tensor — measured as the dominant HBM+wire term on
    big-vocab cells, §Perf W5), while the select contracts locally and
    reduces a scalar per token."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    picked = jnp.where(vocab_iota == labels[..., None].astype(jnp.int32),
                       logits, 0.0)
    ll = picked.sum(axis=-1)
    nll = lse - ll
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# --------------------------------------------------------------------------
# Sharding-constraint helper
# --------------------------------------------------------------------------


def make_wsc(mesh):
    """Returns wsc(x, *spec) applying a NamedSharding constraint, or identity
    when ``mesh`` is None (pure-eager smoke paths)."""
    if mesh is None:
        return lambda x, *spec: x
    from jax.sharding import NamedSharding

    def wsc(x, *spec):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))

    return wsc
