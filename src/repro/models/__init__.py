from repro.models.api import build_model  # noqa: F401
from repro.models.layers import AxisRules, single_device_rules  # noqa: F401
