from repro import jax_compat  # noqa: F401  (installs jax 0.4.x polyfills)
