"""Polyfills bridging jax 0.4.x and the 0.5+/0.6 APIs the codebase uses.

Imported from ``repro/__init__.py`` so any entry point (tests, benchmarks,
subprocess scripts) gets the shims as soon as a ``repro`` module loads.
Newer jax versions are left untouched.

* ``jax.shard_map``  — moved out of ``jax.experimental.shard_map`` in 0.5;
  the keyword ``check_rep`` was renamed ``check_vma``.
* ``jax.set_mesh``   — 0.6 context manager; on 0.4.x a ``Mesh`` is itself
  the context manager that installs the physical mesh.

``force_host_device_count`` lives here too: the one sanctioned way to
request N host platform devices. It must run before the jax backend
initializes (importing jax is fine; the flag is read at first device
query), and it APPENDS to ``XLA_FLAGS`` — user-set flags survive, and an
existing device-count flag is replaced rather than duplicated.
"""
from __future__ import annotations

import os

import jax

_DEVICE_COUNT_FLAG = "--xla_force_host_platform_device_count"


def force_host_device_count(n: int) -> None:
    """Ask the CPU platform for ``n`` devices by amending ``XLA_FLAGS``
    in place (replace our flag if present, keep everything else)."""
    flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if not f.startswith(_DEVICE_COUNT_FLAG)]
    flags.append(f"{_DEVICE_COUNT_FLAG}={int(n)}")
    os.environ["XLA_FLAGS"] = " ".join(flags)


def install() -> None:
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh=None, in_specs=None, out_specs=None,
                      check_vma=None, **kw):
            if check_vma is not None:
                kw["check_rep"] = check_vma
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

        jax.shard_map = shard_map

    if not hasattr(jax, "set_mesh"):
        def set_mesh(mesh):
            return mesh  # Mesh.__enter__ installs it (0.4.x semantics)

        jax.set_mesh = set_mesh


install()
