"""Polyfills bridging jax 0.4.x and the 0.5+/0.6 APIs the codebase uses.

Imported from ``repro/__init__.py`` so any entry point (tests, benchmarks,
subprocess scripts) gets the shims as soon as a ``repro`` module loads.
Newer jax versions are left untouched.

* ``jax.shard_map``  — moved out of ``jax.experimental.shard_map`` in 0.5;
  the keyword ``check_rep`` was renamed ``check_vma``.
* ``jax.set_mesh``   — 0.6 context manager; on 0.4.x a ``Mesh`` is itself
  the context manager that installs the physical mesh.
"""
from __future__ import annotations

import jax


def install() -> None:
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh=None, in_specs=None, out_specs=None,
                      check_vma=None, **kw):
            if check_vma is not None:
                kw["check_rep"] = check_vma
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

        jax.shard_map = shard_map

    if not hasattr(jax, "set_mesh"):
        def set_mesh(mesh):
            return mesh  # Mesh.__enter__ installs it (0.4.x semantics)

        jax.set_mesh = set_mesh


install()
