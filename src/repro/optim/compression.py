"""Int8 error-feedback gradient compression for the pod (DCN) axis.

The paper's Ethernet findings (incast sensitivity, ECN tuning, congestion
spreading) bite hardest on the slowest, most shared axis — for a multi-pod
TPU deployment that is the pod-to-pod DCN all-reduce. Compressing the pod
axis shrinks its wire bytes ~3.9x (int8 + per-256-block fp32 scales), which
the roofline analysis (EXPERIMENTS.md §Perf) converts directly into a lower
collective term.

Error feedback keeps the compression *unbiased over time*: the residual of
every quantization is added back before the next one, so the series of
decompressed gradients telescopes to the true gradient sum (Karimireddy et
al. 2019 — "EF-SGD"). Property-tested in tests/test_compression.py.

``compressed_psum`` is the collective: quantize the local shard, all_gather
the int8 payload + scales over the axis, dequantize and sum locally. Wire
bytes per rank: (n-1)/n * V * (1 + 4/block) vs 2 * (n-1)/n * V * 4 for a
ring all-reduce of fp32 — ~7.9x fewer; vs bf16 ~3.9x.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref

BLOCK = 256


def _pad_to_block(v: jnp.ndarray, block: int) -> Tuple[jnp.ndarray, int]:
    n = v.shape[0]
    pad = (-n) % block
    if pad:
        v = jnp.pad(v, (0, pad))
    return v, n


def compress_leaf(g: jnp.ndarray, ef: jnp.ndarray, block: int = BLOCK):
    """(g + ef) -> (q int8, scales, new_ef). Shapes: g flat (N,)."""
    v = g.astype(jnp.float32) + ef
    vp, n = _pad_to_block(v, block)
    q, s = kref.quantize_int8(vp.reshape(1, -1), block=block)
    back = kref.dequantize_int8(q, s, block=block).reshape(-1)[:n]
    return q.reshape(-1), s.reshape(-1), v - back


def decompress_leaf(q: jnp.ndarray, s: jnp.ndarray, n: int,
                    block: int = BLOCK) -> jnp.ndarray:
    out = kref.dequantize_int8(q.reshape(1, -1), s.reshape(1, -1),
                               block=block)
    return out.reshape(-1)[:n]


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(
        lambda p: jnp.zeros((int(jnp.size(p)),), jnp.float32), params)


def ef_compress(grads: Any, ef: Any, block: int = BLOCK):
    """Tree-wise error-feedback compression.

    Returns (payload tree of (q, s, n), new_ef tree)."""
    flat_g = jax.tree.map(lambda g: g.reshape(-1), grads)
    both = jax.tree.map(lambda g, e: compress_leaf(g, e, block), flat_g, ef)
    payload = jax.tree.map(lambda t: (t[0], t[1], None), both,
                           is_leaf=lambda x: isinstance(x, tuple))
    payload = jax.tree.map(
        lambda g, t: (t[0], t[1], int(jnp.size(g))), grads, both,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    new_ef = jax.tree.map(lambda t: t[2], both,
                          is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    return payload, new_ef


def ef_decompress(payload: Any, like: Any, block: int = BLOCK) -> Any:
    return jax.tree.map(
        lambda p, l: decompress_leaf(p[0], p[1], int(jnp.size(l)),
                                     block).reshape(l.shape),
        payload, like,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)


# --------------------------------------------------------------------------
# compressed cross-pod mean (the DCN collective)
# --------------------------------------------------------------------------


def compressed_psum_mean(x: jnp.ndarray, axis_name: str, n: int,
                         block: int = BLOCK) -> jnp.ndarray:
    """Mean of ``x`` over ``axis_name`` moving int8 on the wire.

    Runs inside shard_map. Each rank quantizes its local value, all-gathers
    (q, scale) over the axis, and reduces in fp32 locally. Exactness is NOT
    expected — callers pair this with error feedback across steps.
    """
    orig_shape = x.shape
    v = x.reshape(-1).astype(jnp.float32)
    vp, n_elem = _pad_to_block(v, block)
    q, s = kref.quantize_int8(vp.reshape(1, -1), block=block)
    q_all = jax.lax.all_gather(q.reshape(-1), axis_name)      # (n, Np) int8
    s_all = jax.lax.all_gather(s.reshape(-1), axis_name)      # (n, Np/blk)
    back = kref.dequantize_int8(
        q_all.reshape(n, -1), s_all.reshape(n, -1), block=block)
    return (back.sum(axis=0)[:n_elem] / n).reshape(orig_shape).astype(x.dtype)


def wire_bytes(n_elems: int, dtype_bytes: int = 4, n: int = 2,
               block: int = BLOCK) -> dict:
    """Analytic wire-byte comparison for EXPERIMENTS.md §Perf."""
    frac = (n - 1) / n
    raw_ar = 2 * frac * n_elems * dtype_bytes      # ring all-reduce
    comp_ag = frac * n_elems * (1 + 4.0 / block)   # int8 all-gather
    return {"uncompressed": raw_ar, "compressed": comp_ag,
            "ratio": raw_ar / comp_ag}
