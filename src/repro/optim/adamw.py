"""Sharding-aware optimizers: AdamW and a memory-lean variant for the
trillion-parameter configs ("adafactor_m": bf16 first moment + factored
second moment), per DESIGN.md §4. Self-contained (no optax in the image).

``state_specs`` mirrors the parameter PartitionSpecs so optimizer state is
sharded exactly like the parameters it tracks (ZeRO-style).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # adamw moments dtype


class Optimizer(NamedTuple):
    name: str
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params, step) -> (params, state)
    state_specs: Callable[[Any], Any]
    state_shapes: Callable[[Any], Any]


def _schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _clip(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), grads), g


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------


def adamw(cfg: OptConfig = OptConfig()) -> Optimizer:
    mdt = jnp.dtype(cfg.moment_dtype)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, mdt)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        grads, gnorm = _clip(grads, cfg.grad_clip)
        lr = _schedule(cfg, step)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1 - cfg.b1 ** t
        bc2 = 1 - cfg.b2 ** t

        def upd(p, g, m, v):
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
            v_new = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g)
            u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * u).astype(p.dtype),
                    m_new.astype(mdt), v_new.astype(mdt))

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}, gnorm

    def state_specs(param_specs):
        return {"m": param_specs, "v": param_specs}

    def state_shapes(param_shapes):
        s = lambda p: jax.ShapeDtypeStruct(p.shape, mdt)
        return {"m": jax.tree.map(s, param_shapes),
                "v": jax.tree.map(s, param_shapes)}

    return Optimizer("adamw", init, update, state_specs, state_shapes)


# --------------------------------------------------------------------------
# adafactor_m: bf16 momentum + factored second moment (giant configs)
# --------------------------------------------------------------------------


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_m(cfg: OptConfig = OptConfig()) -> Optimizer:
    def init(params):
        def vrow(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p.shape)
                    else jnp.zeros(p.shape, jnp.float32))

        def vcol(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p.shape) else jnp.zeros((1,), jnp.float32))

        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                                  params),
                "vr": jax.tree.map(vrow, params),
                "vc": jax.tree.map(vcol, params)}

    def update(grads, state, params, step):
        grads, gnorm = _clip(grads, cfg.grad_clip)
        lr = _schedule(cfg, step)
        t = (step + 1).astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** t

        def upd(p, g, m, vr, vc):
            g2 = jnp.square(g) + 1e-30
            if _factored(p.shape):
                vr_new = cfg.b2 * vr + (1 - cfg.b2) * g2.mean(axis=-1)
                vc_new = cfg.b2 * vc + (1 - cfg.b2) * g2.mean(axis=-2)
                r = vr_new / jnp.maximum(
                    vr_new.mean(axis=-1, keepdims=True), 1e-30)
                v_hat = r[..., None] * vc_new[..., None, :]
            else:
                vr_new = cfg.b2 * vr + (1 - cfg.b2) * g2
                vc_new = vc
                v_hat = vr_new
            u = g / (jnp.sqrt(v_hat / bc2) + cfg.eps)
            m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * u
            upd_ = m_new + cfg.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * upd_).astype(p.dtype),
                    m_new.astype(jnp.bfloat16), vr_new, vc_new)

        out = jax.tree.map(upd, params, grads, state["m"], state["vr"],
                           state["vc"])
        pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "vr": pick(2), "vc": pick(3)}, gnorm

    def state_specs(param_specs):
        def vr_spec(s):
            t = tuple(s)
            return P(*t[:-1]) if len(t) >= 2 else P(*t)

        def vc_spec(s):
            t = tuple(s)
            return P(*(t[:-2] + t[-1:])) if len(t) >= 2 else P(None)

        return {"m": param_specs,
                "vr": jax.tree.map(vr_spec, param_specs,
                                   is_leaf=lambda x: isinstance(x, P)),
                "vc": jax.tree.map(vc_spec, param_specs,
                                   is_leaf=lambda x: isinstance(x, P))}

    def state_shapes(param_shapes):
        def vr(p):
            return jax.ShapeDtypeStruct(
                p.shape[:-1] if _factored(p.shape) else p.shape, jnp.float32)

        def vc(p):
            return jax.ShapeDtypeStruct(
                p.shape[:-2] + p.shape[-1:] if _factored(p.shape) else (1,),
                jnp.float32)

        return {"m": jax.tree.map(
                    lambda p: jax.ShapeDtypeStruct(p.shape, jnp.bfloat16),
                    param_shapes),
                "vr": jax.tree.map(vr, param_shapes),
                "vc": jax.tree.map(vc, param_shapes)}

    return Optimizer("adafactor_m", init, update, state_specs, state_shapes)


def get_optimizer(name: str, cfg: OptConfig = OptConfig()) -> Optimizer:
    if name == "adamw":
        return adamw(cfg)
    if name == "adafactor_m":
        return adafactor_m(cfg)
    raise KeyError(name)
