"""Congestion-aware collective schedule selection (beyond-paper layer).

The paper characterizes how fabrics respond to congestion; this module
*acts* on that characterization: given a collective (kind, participant
count, payload) and a fabric model + background-traffic profile, predict
each candidate schedule's finish time and pick the winner.

Two prediction tiers:

* ``predict_analytic`` — alpha-beta model from the schedule's serialized
  step count and per-rank wire bytes (collectives.wire_bytes_model), with a
  fabric-dependent effective bandwidth. Free; used per-call.
* ``predict_simulated`` — a thin lru-cached client of the mitigation
  lab's simulator-backed scoring path (mitigation.search.simulated_times);
  captures interaction effects (HOL stall, CC transients) the alpha-beta
  model cannot. Cached; used to build offline schedule tables.

The same machinery tunes the *pod-axis* options of the training step:
gradient compression on/off trades wire bytes against quantization compute,
decided from the roofline terms of the dry-run artifact
(``choose_pod_strategy``).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

from repro.core import congestion as cong
from repro.core.collectives import wire_bytes_model
from repro.core.fabric.systems import SystemPreset

CANDIDATES: Dict[str, Tuple[str, ...]] = {
    "all_gather": ("ring_all_gather", "bidir_ring_all_gather"),
    "all_reduce": ("ring_all_reduce",),
    "all_to_all": ("linear_all_to_all", "pairwise_all_to_all"),
}

# benchmarkable collective name for the simulator tier
_SIM_NAME = {
    "ring_all_gather": "ring_allgather",
    "bidir_ring_all_gather": "ring_allgather",
    "ring_all_reduce": "ring_allreduce",
    "linear_all_to_all": "alltoall",
    "pairwise_all_to_all": "alltoall",
}


@dataclasses.dataclass(frozen=True)
class Prediction:
    algo: str
    time_s: float
    wire_bytes: float
    steps: int
    tier: str  # "analytic" | "simulated"


def predict_analytic(kind: str, algo: str, n: int, vector_bytes: float,
                     *, link_bw: float = 50e9, step_latency_s: float = 2e-6,
                     congestion_factor: float = 1.0) -> Prediction:
    """alpha-beta: t = steps * alpha + bytes / (bw / congestion_factor)."""
    m = wire_bytes_model(algo, n, vector_bytes)
    t = m["steps"] * step_latency_s \
        + m["bytes"] * congestion_factor / link_bw
    return Prediction(algo, t, m["bytes"], m["steps"], "analytic")


@lru_cache(maxsize=256)
def _simulated_point(system_name: str, n: int, coll: str, vector_bytes: float,
                     profile_kind: str, burst_s: float, pause_s: float,
                     aggressor: str) -> float:
    # Thin client of search.simulated_times, whose own lru table is
    # agent-aware (keyed on the Candidate too) — this cache only saves
    # the Profile reconstruction for the default-candidate tier.
    from repro.core.mitigation import search

    prof = {"off": cong.no_congestion(), "steady": cong.steady(),
            "bursty": cong.bursty(burst_s, pause_s)}[profile_kind]
    t_u, t_c = search.simulated_times(
        system_name, n * 2 if aggressor else n, coll, aggressor,
        vector_bytes, prof, n_iters=20, warmup=4)
    return t_c if aggressor else t_u


def predict_simulated(kind: str, algo: str, n: int, vector_bytes: float,
                      system: SystemPreset,
                      profile: Optional[cong.Profile] = None,
                      aggressor: str = "") -> Prediction:
    profile = profile or cong.no_congestion()
    t = _simulated_point(system.name, n, _SIM_NAME[algo], float(vector_bytes),
                         profile.kind, profile.burst_s, profile.pause_s,
                         aggressor)
    # schedule-level correction: the fluid sim models the traffic pattern;
    # serialized-step latency differs per algorithm.
    m = wire_bytes_model(algo, n, vector_bytes)
    base_steps = wire_bytes_model(
        {"all_gather": "ring_all_gather", "all_reduce": "ring_all_reduce",
         "all_to_all": "linear_all_to_all"}[kind], n, vector_bytes)["steps"]
    t = t + (m["steps"] - base_steps) * 2e-6
    return Prediction(algo, t, m["bytes"], m["steps"], "simulated")


def choose_schedule(kind: str, n: int, vector_bytes: float,
                    system: Optional[SystemPreset] = None,
                    profile: Optional[cong.Profile] = None,
                    aggressor: str = "",
                    use_simulator: bool = False) -> Prediction:
    """Pick the fastest candidate schedule for a collective."""
    preds: List[Prediction] = []
    for algo in CANDIDATES[kind]:
        if use_simulator and system is not None:
            preds.append(predict_simulated(kind, algo, n, vector_bytes,
                                           system, profile, aggressor))
        else:
            preds.append(predict_analytic(kind, algo, n, vector_bytes))
    return min(preds, key=lambda p: p.time_s)


# --------------------------------------------------------------------------
# pod-axis training-step strategy (compression / sharding) from roofline
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PodStrategy:
    compress_grads: bool
    predicted_collective_s: float
    predicted_baseline_s: float

    @property
    def speedup_on_collective_term(self) -> float:
        if self.predicted_collective_s == 0:
            return 1.0
        return self.predicted_baseline_s / self.predicted_collective_s


def choose_pod_strategy(grad_bytes_per_device: float, n_pods: int,
                        *, dcn_bw: float = 25e9, peak_flops: float = 197e12,
                        quant_flops_per_byte: float = 4.0,
                        compress_ratio: float = 3.9) -> PodStrategy:
    """Compression pays when wire time saved exceeds quantization compute.

    grad_bytes_per_device: pod-axis all-reduce payload (bf16 grads).
    """
    frac = (n_pods - 1) / max(n_pods, 1)
    t_base = 2 * frac * grad_bytes_per_device / dcn_bw
    t_wire = t_base / compress_ratio
    t_quant = quant_flops_per_byte * grad_bytes_per_device / peak_flops
    t_comp = t_wire + t_quant
    return PodStrategy(compress_grads=t_comp < t_base,
                       predicted_collective_s=min(t_comp, t_base),
                       predicted_baseline_s=t_base)
