"""Custom collective schedules over point-to-point primitives.

The paper (§III-B) implements ring AllGather and linear AlltoAll over
MPI send/recv so the algorithm is identical across systems. Here the same
schedules are expressed as explicit ``jax.lax.ppermute`` step sequences
inside ``shard_map`` — the JAX-native analogue of send/recv — plus the
XLA-native one-shot collectives as the baseline alternative. A ring
AllReduce (= ReduceScatter + AllGather) mirrors the paper's Fig. 1 custom
implementation; its accumulate step is the hot-spot the fused Pallas kernel
targets (kernels/fused_reduce.py).

All step functions run *inside* shard_map and take the static axis size
(python int) so schedules unroll at trace time.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _fwd(n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def _bwd(n: int):
    return [(i, (i - 1) % n) for i in range(n)]


# --------------------------------------------------------------------------
# Ring AllGather (paper's custom AllGather)
# --------------------------------------------------------------------------


def ring_all_gather(x, axis_name: str, n: int, *, bidirectional: bool = False):
    """x: local shard (d, ...). Returns (n, d, ...) in global rank order."""
    rank = jax.lax.axis_index(axis_name)
    if n == 1:
        return x[None]
    chunks = [x]
    if not bidirectional:
        cur = x
        for _ in range(n - 1):
            cur = jax.lax.ppermute(cur, axis_name, _fwd(n))
            chunks.append(cur)
        # chunks[j] holds the shard of rank (rank - j) mod n
        stacked = jnp.stack(chunks)
        src = (rank - jnp.arange(n)) % n
        order = jnp.zeros((n,), jnp.int32).at[src].set(jnp.arange(n))
        return stacked[order]
    fw = bw = x
    fchunks, bchunks = [x], []
    steps_f = (n - 1 + 1) // 2
    steps_b = (n - 1) // 2
    for _ in range(steps_f):
        fw = jax.lax.ppermute(fw, axis_name, _fwd(n))
        fchunks.append(fw)
    for _ in range(steps_b):
        bw = jax.lax.ppermute(bw, axis_name, _bwd(n))
        bchunks.append(bw)
    stacked = jnp.stack(fchunks + bchunks)
    srcs = jnp.concatenate([
        (rank - jnp.arange(steps_f + 1)) % n,
        (rank + 1 + jnp.arange(steps_b)) % n])
    order = jnp.zeros((n,), jnp.int32).at[srcs].set(jnp.arange(n))
    return stacked[order]


# --------------------------------------------------------------------------
# Ring ReduceScatter / AllReduce (paper Fig. 1 custom ring AllReduce)
# --------------------------------------------------------------------------


def ring_reduce_scatter(x, axis_name: str, n: int,
                        add: Optional[Callable] = None):
    """x: (n, d, ...) full per-rank buffer. Returns rank's reduced chunk."""
    if n == 1:
        return x[0]
    add = add or (lambda a, b: a + b)
    rank = jax.lax.axis_index(axis_name)
    take = lambda c: jnp.take(x, c % n, axis=0)
    acc = take(rank - 1)
    for s in range(1, n):
        acc = jax.lax.ppermute(acc, axis_name, _fwd(n))
        acc = add(acc, take(rank - 1 - s))
    return acc


def ring_all_reduce(x, axis_name: str, n: int,
                    add: Optional[Callable] = None):
    """x: (n, d, ...). Returns (n, d, ...) fully reduced (RS + AG)."""
    chunk = ring_reduce_scatter(x, axis_name, n, add)
    return ring_all_gather(chunk, axis_name, n)


# --------------------------------------------------------------------------
# AlltoAll: linear (paper) and pairwise schedules
# --------------------------------------------------------------------------


def linear_all_to_all(x, axis_name: str, n: int):
    """Paper's 'linear' algorithm: direct exchange. x: (n, d, ...)."""
    if n == 1:
        return x
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                              tiled=True)


def pairwise_all_to_all(x, axis_name: str, n: int):
    """n-1 ppermute rounds; round s exchanges with rank +/- s."""
    rank = jax.lax.axis_index(axis_name)
    out = jnp.zeros_like(x)
    out = out.at[rank].set(jnp.take(x, rank, axis=0))
    for s in range(1, n):
        sent = jnp.take(x, (rank + s) % n, axis=0)
        perm = [(i, (i + s) % n) for i in range(n)]
        rec = jax.lax.ppermute(sent, axis_name, perm)
        out = out.at[(rank - s) % n].set(rec)
    return out


# --------------------------------------------------------------------------
# Incast (the paper's edge-congestion aggressor pattern)
# --------------------------------------------------------------------------


def incast_gather(x, axis_name: str, n: int, root: int = 0):
    """Linear fan-in of every rank's buffer to ``root``. Returns (n, d, ...)
    valid at root (zeros elsewhere)."""
    rank = jax.lax.axis_index(axis_name)
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = jnp.where((rank == root),
                    out.at[root].set(x), out)
    for s in range(1, n):
        src = (root + s) % n
        rec = jax.lax.ppermute(x, axis_name, [(src, root)])
        out = jnp.where(rank == root, out.at[src].set(rec), out)
    return out


# --------------------------------------------------------------------------
# Top-level runners + analytic wire-byte models (autotuner/roofline)
# --------------------------------------------------------------------------


def run_on_mesh(mesh, axis_name: str, fn, x, in_spec=None, out_spec=None):
    """Run a step-schedule collective over one mesh axis via shard_map."""
    in_spec = in_spec if in_spec is not None else P(axis_name)
    out_spec = out_spec if out_spec is not None else P(None)
    return jax.shard_map(fn, mesh=mesh, in_specs=in_spec, out_specs=out_spec,
                         check_vma=False)(x)


def wire_bytes_model(kind: str, n: int, vector_bytes: float) -> dict:
    """Per-rank wire bytes + serialized step count for each schedule."""
    v = float(vector_bytes)
    if n <= 1:
        return {"bytes": 0.0, "steps": 0}
    if kind == "ring_all_gather":
        return {"bytes": (n - 1) / n * v, "steps": n - 1}
    if kind == "bidir_ring_all_gather":
        return {"bytes": (n - 1) / n * v, "steps": (n - 1 + 1) // 2}
    if kind == "ring_all_reduce":
        return {"bytes": 2 * (n - 1) / n * v, "steps": 2 * (n - 1)}
    if kind in ("linear_all_to_all", "pairwise_all_to_all"):
        return {"bytes": (n - 1) / n * v,
                "steps": 1 if kind == "linear_all_to_all" else n - 1}
    if kind == "incast":
        return {"bytes": v, "steps": n - 1}
    raise KeyError(kind)
