"""Declarative scenario registry: named sweeps over the batched engine.

A :class:`Scenario` is a list of :class:`Grid` specs. Each Grid maps to ONE
``bench.run_grid`` call — one flow set, one compile, every (vector size x
profile x baseline/congested) cell batched under ``jax.vmap``. The paper's
Fig. 5/6/7-8 sweeps are registered here, plus new congestion families the
host-callback engine could not express (ramp onsets, random telegraph
aggressors, multi-tenant envelope mixes).

Adding a sweep: write a builder returning a Scenario, decorate it with
``@register``, and run it with ``run_scenario(get("name"))`` (or wire it to
a benchmarks/ driver; see EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Tuple

from repro.core import bench
from repro.core import congestion as cong
from repro.core.envelopes import Profile
from repro.core.fabric import systems
from repro.core.traffic import JobSpec

KiB = 2 ** 10
MiB = 2 ** 20


@dataclasses.dataclass(frozen=True)
class Grid:
    """One flow-program's worth of cells: sizes x profiles (plus the
    implied per-size baselines), vmapped by bench.run_grid.

    ``phased=True`` lowers the victim's step schedule into barrier-gated
    phases; ``jobs`` replaces the victim/aggressor split with an explicit
    multi-job program (job 0 is the measured primary; jobs without nodes
    get an interleaved share of the allocation).

    ``cells`` turns the grid *scale-batched*: a tuple of ``(system,
    n_nodes)`` pairs — heterogeneous node counts and topology families —
    that run through bench.run_scale_grid (geometries padded into
    buckets, one compile per bucket). ``system``/``n_nodes`` are ignored
    when ``cells`` is set (keep them as a label/0)."""

    system: str
    n_nodes: int
    aggressor: str
    sizes: Tuple[float, ...]
    profiles: Tuple[Profile, ...]
    victim: str = "ring_allgather"
    phased: bool = False
    jobs: Tuple[JobSpec, ...] = ()
    cells: Tuple[Tuple[str, int], ...] = ()


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    grids: Tuple[Grid, ...]
    n_iters: int = 25
    warmup: int = 5
    # microbenchmark scenarios (wall-clock collective timing) carry their
    # payload sizes here instead of fabric grids
    microbench_sizes: Tuple[int, ...] = ()
    # non-grid drivers (fig1/fig3/fig4) declare their sweep points here;
    # the matching benchmarks/ driver interprets each tuple
    points: Tuple[tuple, ...] = ()


SCENARIOS: Dict[str, Callable[[bool], Scenario]] = {}


def register(builder: Callable[[bool], Scenario]):
    probe = builder(False)
    SCENARIOS[probe.name] = builder
    return builder


def get(name: str, quick: bool = False) -> Scenario:
    return SCENARIOS[name](quick)


def run_grid_spec(scenario: Scenario, grid: Grid) -> List[bench.BenchResult]:
    system = list(grid.cells) if grid.cells \
        else systems.get_system(grid.system)
    return bench.run_grid(
        system, grid.n_nodes, grid.victim,
        grid.aggressor, grid.sizes, grid.profiles,
        n_iters=scenario.n_iters, warmup=scenario.warmup,
        phased=grid.phased, jobs=list(grid.jobs) or None)


def run_scenario(scenario: Scenario) -> Iterator[bench.BenchResult]:
    """Run every grid of a scenario (each grid = one batched call)."""
    for grid in scenario.grids:
        yield from run_grid_spec(scenario, grid)


def result_row(grid: Grid, r: bench.BenchResult) -> dict:
    """Flatten a BenchResult to the CSV row shape the drivers print."""
    row = {
        "system": r.system, "n_nodes": r.n_nodes, "victim": r.victim,
        "aggressor": r.aggressor, "vector_bytes": r.vector_bytes,
        "profile": r.profile,
        "ratio": round(r.ratio, 4),
        "t_uncongested_us": round(r.t_uncongested_s * 1e6, 1),
        "t_congested_us": round(r.t_congested_s * 1e6, 1),
    }
    prof = next((p for p in grid.profiles if p.label() == r.profile), None)
    if prof is not None and prof.kind in ("bursty", "random"):
        row["burst_ms"] = round(prof.burst_s * 1e3, 4)
        row["pause_ms"] = round(prof.pause_s * 1e3, 4)
    if r.job_times:
        row["job_times"] = ";".join(
            f"{name}:{t * 1e6:.1f}us:{n}" for name, t, n in r.job_times)
    return row


# --------------------------------------------------------------------------
# Paper sweeps (Figs. 5-8)
# --------------------------------------------------------------------------

FIG5_SYSTEMS = ("cresco8", "leonardo", "lumi")
FIG5_AGGRESSORS = ("alltoall", "incast")
FIG5_NODES = (16, 32, 64, 128, 256)
FIG5_SIZES = (512, 32 * KiB, 2 * MiB, 16 * MiB)

BURSTS_MS = (0.5, 2.0, 8.0)
PAUSES_MS = (0.2, 1.0, 8.0)
FIG6_SIZES = (512, 32 * KiB, 2 * MiB)


def _bursty_grid(bursts_ms, pauses_ms) -> Tuple[Profile, ...]:
    return tuple(cong.bursty(b * 1e-3, p * 1e-3)
                 for b in bursts_ms for p in pauses_ms)


@register
def fig5_steady(quick: bool = False) -> Scenario:
    nodes = (16, 64, 256) if quick else FIG5_NODES
    sizes = (32 * KiB, 2 * MiB) if quick else FIG5_SIZES
    grids = tuple(Grid(s, n, a, sizes, (cong.steady(),))
                  for s in FIG5_SYSTEMS for a in FIG5_AGGRESSORS
                  for n in nodes)
    return Scenario(
        "fig5_steady",
        "Paper Fig. 5 / Obs. 2: steady congestion at scale — ratio heatmaps "
        "(nodes x vector size) per system x aggressor, AllGather victim.",
        grids)


@register
def fig6_bursty(quick: bool = False) -> Scenario:
    sizes = (32 * KiB,) if quick else FIG6_SIZES
    bursts = (0.5, 8.0) if quick else BURSTS_MS
    pauses = (0.2, 8.0) if quick else PAUSES_MS
    grids = tuple(Grid(s, 64, a, sizes, _bursty_grid(bursts, pauses))
                  for s in FIG5_SYSTEMS for a in FIG5_AGGRESSORS)
    return Scenario(
        "fig6_bursty",
        "Paper Fig. 6 / Obs. 3: bursty congestion at 64 nodes — "
        "(burst x pause) duty-cycle heatmaps per system x aggressor x size.",
        grids)


@register
def fig7_fig8_scale(quick: bool = False) -> Scenario:
    """Scale-batched since the geometry-bucket engine: the whole
    (system x n_nodes) ladder rides one run_scale_grid call per
    aggressor — one compile per geometry bucket instead of one per
    scale (quick: 2 scales x 2 systems, the CI smoke)."""
    cells = (("cresco8", 64), ("cresco8", 128),
             ("lumi", 64), ("lumi", 128)) if quick else \
        (("cresco8", 64), ("cresco8", 128), ("lumi", 256))
    sizes = (2 * MiB,) if quick else (32 * KiB, 2 * MiB)
    bursts = (2.0,) if quick else BURSTS_MS
    pauses = (0.2, 8.0) if quick else PAUSES_MS
    # quick keeps the incast grid only — that is the Fig. 7 claim (64 vs
    # 128-node congestion-tree width) and the CI smoke budget
    aggrs = ("incast",) if quick else FIG5_AGGRESSORS
    grids = tuple(Grid("scale", 0, a, sizes, _bursty_grid(bursts, pauses),
                       cells=cells)
                  for a in aggrs)
    return Scenario(
        "fig7_fig8_scale",
        "Paper Figs. 7-8: bursty congestion at larger scale (CRESCO8 "
        "64/128 nodes, LUMI 256 nodes), scale-batched.",
        grids, n_iters=12 if quick else 20, warmup=3 if quick else 4)


@register
def collective_microbench(quick: bool = False) -> Scenario:
    return Scenario(
        "collective_microbench",
        "§III-B: wall-clock cost of the custom collective schedules on an "
        "8-device host mesh (benchmarks/collective_bench.py).",
        grids=(), microbench_sizes=(32 * KiB, 2 * MiB))


# --------------------------------------------------------------------------
# Beyond-paper scenario families (traceable-envelope shapes)
# --------------------------------------------------------------------------


@register
def ramp_onset(quick: bool = False) -> Scenario:
    """Congestion onset: aggressors ramp from idle to full blast. Probes
    how fast each fabric's CC walks victims down as pressure builds —
    square-wave profiles only show the endpoints."""
    ramps = (cong.ramp(1e-3), cong.ramp(8e-3), cong.ramp(32e-3),
             cong.steady())
    sysnames = ("leonardo", "lumi") if quick else FIG5_SYSTEMS
    sizes = (2 * MiB,) if quick else (32 * KiB, 2 * MiB)
    grids = tuple(Grid(s, 32, a, sizes, ramps)
                  for s in sysnames for a in ("incast",))
    return Scenario(
        "ramp_onset",
        "Aggressor intensity ramps 0 -> 1 over 1/8/32 ms (vs steady): "
        "congestion-onset response per fabric.",
        grids)


@register
def random_telegraph(quick: bool = False) -> Scenario:
    """Irregular bursts with the same mean duty cycle as Fig. 6's periodic
    ones: compares periodic vs random arrival of congestion (production
    background traffic is not a square wave)."""
    pairs = ((2.0, 0.2), (2.0, 8.0)) if quick else \
        ((0.5, 0.2), (2.0, 0.2), (2.0, 8.0), (8.0, 8.0))
    profiles = []
    for b, p in pairs:
        profiles.append(cong.bursty(b * 1e-3, p * 1e-3))
        profiles.append(cong.random_onoff(b * 1e-3, p * 1e-3, seed=1))
    sysnames = ("cresco8", "leonardo") if quick else FIG5_SYSTEMS
    grids = tuple(Grid(s, 32, "incast", (2 * MiB,), tuple(profiles))
                  for s in sysnames)
    return Scenario(
        "random_telegraph",
        "Periodic vs random on/off aggressors at matched duty cycles.",
        grids)


# --------------------------------------------------------------------------
# Non-grid paper figures (fig1/fig3/fig4) — declared here so EVERY
# benchmark driver routes through the registry; the matching driver
# interprets the ``points`` tuples.
# --------------------------------------------------------------------------


@register
def fig1_breakdown(quick: bool = False) -> Scenario:
    sizes = (MiB, 16 * MiB) if quick else (MiB, 16 * MiB, 128 * MiB)
    return Scenario(
        "fig1_breakdown",
        "Paper Fig. 1: ring AllReduce cost breakdown (reduce/memcpy vs "
        "simulated EDR wire time) on 8 nodes.",
        grids=(), points=tuple((s,) for s in sizes))


@register
def fig3_sawtooth(quick: bool = False) -> Scenario:
    sizes = (16 * MiB,) if quick else (16 * MiB, 128 * MiB)
    syss = ("haicgu_ce8850", "haicgu_ib", "nanjing_nslb")
    return Scenario(
        "fig3_sawtooth",
        "Paper Fig. 3 / Obs. 1: CE8850 self-congestion sawtooth on 4-node "
        "AllGather; EDR IB and CE9855 stay stable.",
        grids=(), points=tuple((s, v) for s in syss for v in sizes))


@register
def fleet_replay(quick: bool = False) -> Scenario:
    """Stochastic fleet replay (benchmarks/fleet_replay.py): each point is
    a (system, n_nodes, n_seeds) batched seed sweep through
    core/workload.py with streaming percentile metrics in the scan."""
    n_seeds = 8 if quick else 256
    cells = (("cresco8", 16), ("lumi", 16)) if quick \
        else (("cresco8", 32), ("lumi", 32))
    return Scenario(
        "fleet_replay",
        "Fleet-scale stochastic workload replay: Poisson short flows + "
        "training tenants with per-tenant CC mixes, p50/p99/p99.9 queue "
        "delay and FCT from streaming in-scan histograms.",
        grids=(), points=tuple((s, n, n_seeds) for s, n in cells))


@register
def fig4_nslb(quick: bool = False) -> Scenario:
    sizes = (4 * MiB, 16 * MiB) if quick else \
        (MiB, 4 * MiB, 16 * MiB, 64 * MiB)
    return Scenario(
        "fig4_nslb",
        "Paper Fig. 4: NSLB on/off under steady AlltoAll congestion "
        "(4+4 nodes, Nanjing CE9855 leaf-spine).",
        grids=(), points=tuple((m, s) for m in ("nslb", "ecmp")
                               for s in sizes))


# --------------------------------------------------------------------------
# Traffic-program scenario families (phased schedules, multi-job mixes)
# --------------------------------------------------------------------------


@register
def phased_collectives(quick: bool = False) -> Scenario:
    """Phased vs flattened lowering of the same victim under the same
    aggressor: the shape of Fig. 5/6 cells when the collective's temporal
    structure (barrier-gated ring shard steps; pairwise matchings vs the
    linear all-pairs blob) is modeled instead of one static flow set.
    The paired grids share (system, victim, aggressor, sizes), so the
    ratio delta isolates the schedule."""
    sysnames = ("leonardo", "cresco8") if quick else FIG5_SYSTEMS
    victims = ("alltoall",) if quick else ("ring_allreduce", "alltoall")
    sizes = (2 * MiB,) if quick else (32 * KiB, 2 * MiB)
    profiles = (cong.steady(),) if quick else \
        (cong.steady(), cong.bursty(2e-3, 2e-3))
    grids = []
    for s in sysnames:
        for a in FIG5_AGGRESSORS:
            for v in victims:
                for ph in (False, True):
                    grids.append(Grid(s, 32, a, sizes, profiles,
                                      victim=v, phased=ph))
    return Scenario(
        "phased_collectives",
        "Phased (barrier-gated step schedules) vs flattened victim "
        "lowerings under steady/bursty aggressors.",
        tuple(grids), n_iters=15, warmup=3)


def _mix_jobs(kind: str) -> Tuple[JobSpec, ...]:
    """Canned two-or-more-job programs. Job 0 is the measured primary;
    background jobs are envelope-gated so the per-size baseline cell
    (envelope off) isolates the primary job on the same allocation."""
    if kind == "training_vs_training":
        return (JobSpec("train_a", "ring_allreduce", phased=True),
                JobSpec("train_b", "ring_allreduce", vector_bytes=2 * MiB,
                        phased=True, envelope_gated=True,
                        sweep_bytes=False))
    if kind == "training_vs_incast":
        return (JobSpec("train", "ring_allreduce", phased=True),
                JobSpec("incast_job", "incast", endless=True,
                        envelope_gated=True, sweep_bytes=False))
    if kind == "four_tenant":
        return (JobSpec("tenant0", "ring_allreduce", phased=True),) + tuple(
            JobSpec(f"tenant{i}", "ring_allreduce", vector_bytes=2 * MiB,
                    phased=True, envelope_gated=True, sweep_bytes=False)
            for i in range(1, 4))
    raise KeyError(kind)


@register
def multi_job_mix(quick: bool = False) -> Scenario:
    """Concurrent-job interference (the multi-application congestion of
    arXiv:1907.05312): a phased training job measured against a second
    training tenant, an endless incast tenant, and a 4-tenant
    fair-share — all inside one jit(vmap) per grid, per-job iteration
    times reported in job_times."""
    sysnames = ("leonardo",) if quick else ("leonardo", "lumi", "cresco8")
    mixes = ("training_vs_training", "training_vs_incast") if quick else \
        ("training_vs_training", "training_vs_incast", "four_tenant")
    sizes = (2 * MiB,) if quick else (32 * KiB, 2 * MiB)
    profiles = (cong.steady(),) if quick else \
        (cong.steady(), cong.bursty(2e-3, 2e-3))
    grids = tuple(Grid(s, 32, mix, sizes, profiles,
                       victim="ring_allreduce", jobs=_mix_jobs(mix))
                  for s in sysnames for mix in mixes)
    return Scenario(
        "multi_job_mix",
        "Multi-job fabric sharing: training-vs-training, training-vs-"
        "incast, and N-tenant fair-share mixes (job 0 measured; "
        "background tenants envelope-gated).",
        grids, n_iters=12, warmup=3)


# --------------------------------------------------------------------------
# Scale-batched scenario families (heterogeneous topologies in one vmap)
# --------------------------------------------------------------------------


@register
def scale_sweep(quick: bool = False) -> Scenario:
    """The paper's central axis — how congestion impact changes with
    system size — as ONE batched sweep per aggressor: an EDR/HDR/NDR/
    Slingshot x {16..512}-node ladder of (system, n_nodes) cells padded
    into geometry buckets. Jha et al. show congestion trees are a scale
    phenomenon; this is the grid axis that used to recompile per cell."""
    if quick:
        cells = tuple((s, n) for s in ("cresco8", "lumi")
                      for n in (16, 64))
        sizes: Tuple[float, ...] = (2 * MiB,)
        profiles: Tuple[Profile, ...] = (cong.steady(),)
        aggrs = ("alltoall",)
    else:
        cells = tuple((s, n)
                      for s in ("haicgu_ib", "leonardo", "cresco8", "lumi")
                      for n in (16, 32, 64, 128, 256, 512))
        sizes = (32 * KiB, 2 * MiB)
        profiles = (cong.steady(), cong.bursty(2e-3, 2e-3))
        aggrs = FIG5_AGGRESSORS
    grids = tuple(Grid("scale", 0, a, sizes, profiles, cells=cells)
                  for a in aggrs)
    return Scenario(
        "scale_sweep",
        "Cross-scale congestion: EDR/HDR/NDR/Slingshot x 16..512 nodes "
        "per aggressor, scale-batched (one compile per geometry bucket).",
        grids, n_iters=15, warmup=3)


@register
def mixed_topology(quick: bool = False) -> Scenario:
    """Topology-family shootout at matched allocation size: single-switch,
    leaf-spine, blocking fat-tree, Dragonfly and Dragonfly+ cells stacked
    in one scale-batched call, so the ratio spread isolates what the
    *fabric structure* (path diversity, taper, global links) contributes
    under the identical victim/aggressor program."""
    n = 16 if quick else 32
    names = ("haicgu_ib", "cresco8", "lumi") if quick else \
        ("haicgu_ib", "nanjing_nslb", "cresco8", "lumi", "leonardo")
    cells = tuple((s, n) for s in names)
    sizes = (2 * MiB,) if quick else (32 * KiB, 2 * MiB)
    profiles = (cong.steady(),) if quick else \
        (cong.steady(), cong.bursty(2e-3, 2e-3))
    aggrs = ("incast",) if quick else FIG5_AGGRESSORS
    grids = tuple(Grid("mixed", 0, a, sizes, profiles, cells=cells)
                  for a in aggrs)
    return Scenario(
        "mixed_topology",
        "Heterogeneous topology families (single-switch / leaf-spine / "
        "fat-tree / dragonfly / dragonfly+) at one scale, batched into "
        "geometry buckets.",
        grids, n_iters=15, warmup=3)


# --------------------------------------------------------------------------
# Mitigation-lab scenario families (mitigation/search + score; the
# benchmarks/mitigation_lab.py driver scores candidates across these)
# --------------------------------------------------------------------------


@register
def mitigation_panel(quick: bool = False) -> Scenario:
    """The mitigation lab's scoring panel: every candidate (CC config x
    routing policy) is measured on each of these cells (score.py turns
    grids into PanelCells). Quick = the 2-scenario CI smoke: the Fig. 4
    leaf-spine cell (load-balancing axis) + the bursty Leonardo incast
    collapse (CC axis — the congestion tree is HOL-driven, so
    ``hol_factor`` isolation is what the search should find); full adds
    the steady incast collapse and a multi-job mix."""
    grids = [
        # Fig. 4 leaf-spine cell: steady AlltoAll-on-AlltoAll — the NSLB
        # vs ECMP flat-line claim lives here
        Grid("nanjing_nslb", 8, "alltoall", (4 * MiB,), (cong.steady(),),
             victim="alltoall"),
        # bursty duty-cycle incast at 64 nodes on Leonardo (HDR): the
        # paper's congestion-tree collapse — the CC-search axis
        Grid("leonardo", 64, "incast", (2 * MiB,),
             (cong.bursty(2e-3, 2e-3),)),
    ]
    if not quick:
        grids += [
            Grid("leonardo", 32, "incast", (2 * MiB,), (cong.steady(),)),
            Grid("leonardo", 32, "training_vs_incast", (2 * MiB,),
                 (cong.steady(),), victim="ring_allreduce",
                 jobs=_mix_jobs("training_vs_incast")),
            # flapping hot link UNDER live incast congestion: the search
            # must find a config robust to the compound failure (the
            # link_fault family carries the fault-only panel)
            Grid("leonardo", 32, "incast", (2 * MiB,),
                 (cong.with_faults(cong.steady(),
                                   cong.flap(0.2e-3, 20e-3, duty=0.3,
                                             seed=5)),)),
        ]
    return Scenario(
        "mitigation_panel",
        "Mitigation-lab scoring panel: steady Fig.4 leaf-spine, bursty "
        "and steady Leonardo incast collapse, multi-job mix.",
        tuple(grids), n_iters=12, warmup=3)


@register
def mitigation_routing(quick: bool = False) -> Scenario:
    """Routing-policy shootout on path-diverse fabrics: the same cells
    the traced-policy engine sweeps as data (fixed/ECMP/NSLB/adaptive/
    flowlet ride one compile); as a plain scenario it exercises the
    mixed-routing scale-batched path end-to-end."""
    cells = (("nanjing_ecmp", 8), ("cresco8", 16)) if quick else \
        (("nanjing_ecmp", 8), ("nanjing_nslb", 8), ("cresco8", 16),
         ("leonardo", 32))
    sizes = (4 * MiB,) if quick else (512 * KiB, 4 * MiB)
    profiles = (cong.steady(),) if quick else \
        (cong.steady(), cong.bursty(2e-3, 2e-3))
    grids = tuple(Grid("mitigation", 0, a, sizes, profiles,
                       victim="alltoall", cells=cells)
                  for a in (("alltoall",) if quick
                            else ("alltoall", "incast")))
    return Scenario(
        "mitigation_routing",
        "Mixed-routing shootout (leaf-spine ECMP/NSLB, fat-tree and "
        "Dragonfly+ AR) — one scale-batched compile across routing "
        "modes.",
        grids, n_iters=12, warmup=3)


# --------------------------------------------------------------------------
# Fault-scenario families (link faults + intra-node stage; DESIGN.md §16)
# --------------------------------------------------------------------------


@register
def link_fault(quick: bool = False) -> Scenario:
    """Link failure & degradation events as time-varying per-link
    capacity envelopes (ROADMAP item 4a): a flapping hot link, a dying
    optic (linear decay that persists), fabric-wide jitter — each alone
    on an otherwise clean fabric, plus a flap compounding with live
    incast congestion. Scale-batched so the whole family is one compile
    per geometry bucket; the mitigation lab draws its flapping-link
    panel from here (score.panel_from_scenario)."""
    hot_flap = cong.with_faults(
        cong.no_congestion(), cong.flap(0.2e-3, 20e-3, duty=0.3, seed=5))
    dying_optic = cong.with_faults(
        cong.no_congestion(), cong.degrade(0.2e-3, 1.5e-3, severity=0.7))
    fabric_jitter = cong.with_faults(
        cong.no_congestion(),
        cong.jitter(0.2e-3, 20e-3, severity=0.6,
                    link_group=cong.GROUP_FABRIC, seed=9))
    flap_under_incast = cong.with_faults(
        cong.steady(), cong.flap(0.2e-3, 20e-3, duty=0.3, seed=5))
    if quick:
        cells = (("leonardo", 16), ("lumi", 16))
        clean_profiles = (hot_flap, dying_optic)
        sizes: Tuple[float, ...] = (2 * MiB,)
    else:
        cells = (("leonardo", 16), ("leonardo", 64), ("lumi", 16),
                 ("lumi", 64), ("cresco8", 16))
        clean_profiles = (hot_flap, dying_optic, fabric_jitter,
                          cong.with_faults(
                              cong.no_congestion(),
                              cong.outage(0.5e-3, 2e-3, severity=1.0)),
                          # switch-level variant: the busiest switch's
                          # whole link set fails as one unit (line-card
                          # loss; GROUP_SWITCH matches link_sw_group)
                          cong.with_faults(
                              cong.no_congestion(),
                              cong.switch_outage(0.5e-3, 2e-3,
                                                 severity=0.9)))
        sizes = (256 * KiB, 2 * MiB)
    grids = (
        # no aggressor: every flow is the victim's, so GROUP_HOT is the
        # victim's own most-traversed link — the fault does the damage
        Grid("fault", 0, "", sizes, clean_profiles, cells=cells),
        # compound case: the hot link flaps while incast runs
        Grid("fault", 0, "incast", sizes, (flap_under_incast,),
             cells=cells[:2] if quick else cells),
    )
    return Scenario(
        "link_fault",
        "Flapping hot link, dying optic, fabric jitter and hard outage "
        "as per-link capacity envelopes, alone and compounding incast.",
        grids, n_iters=12, warmup=3)


@register
def intra_node(quick: bool = False) -> Scenario:
    """Intra-node stage contention (ROADMAP item 4b, per Tarraga-Moreno
    et al.): NVLink/PCIe modeled as a proportional-share stage ahead of
    the NIC, armed by the geometry flag and swept over the node-capacity
    fraction. AlltoAll victims put many concurrent flows on each node,
    so the stage — not the fabric — becomes the bottleneck as the
    fraction drops; ratio tracks the fraction once it binds."""
    fracs = (1.0, 0.5, 0.25) if quick else (2.0, 1.0, 0.5, 0.25)
    profiles = tuple(cong.with_node_cap(cong.no_congestion(), f)
                     for f in fracs)
    cells = (("leonardo", 16), ("lumi", 16)) if quick else \
        (("leonardo", 16), ("leonardo", 32), ("lumi", 16), ("lumi", 32),
         ("cresco8", 16))
    sizes = (1 * MiB,) if quick else (256 * KiB, 1 * MiB)
    grids = (Grid("intra", 0, "", sizes, profiles, victim="alltoall",
                  cells=cells),)
    return Scenario(
        "intra_node",
        "Intra-node (NVLink/PCIe) stage contention: AlltoAll victims vs "
        "a swept per-node capacity fraction ahead of the NIC.",
        grids, n_iters=12, warmup=3)


@register
def multi_tenant(quick: bool = False) -> Scenario:
    """Several aggressor tenants with different burst periods share the
    aggressor nodes; their envelopes blend into a fractional intensity.
    The blend's duty cycle matches a single mid-period tenant, isolating
    the effect of overlapping, desynchronized tenants."""
    tenants = cong.multi_tenant(
        (cong.bursty(0.5e-3, 0.5e-3), 1 / 3),
        (cong.bursty(2e-3, 2e-3), 1 / 3),
        (cong.random_onoff(4e-3, 4e-3, seed=3), 1 / 3))
    profiles = (tenants, cong.bursty(2e-3, 2e-3), cong.steady())
    sysnames = ("leonardo", "lumi") if quick else FIG5_SYSTEMS
    grids = tuple(Grid(s, 32, a, (2 * MiB,), profiles)
                  for s in sysnames for a in ("alltoall", "incast"))
    return Scenario(
        "multi_tenant",
        "Three desynchronized tenant envelopes blended at 1/3 weight each "
        "vs a single 50%-duty tenant vs steady.",
        grids)
