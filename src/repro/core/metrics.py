"""Streaming percentile metrics for fleet-scale replay (DESIGN.md §15).

A fleet replay runs thousands of seeds x 10^5 steps; materializing per-step
traces is O(T x B x F) — hundreds of GB — so distribution metrics are
folded into the ``lax.scan`` carry instead:

* **Fixed-bin log-spaced histograms** for queue delay and flow completion
  time (FCT). :data:`NBINS` bins spanning :data:`DECADES` decades from
  ``10**LOG10_MIN`` seconds at :data:`BINS_PER_DECADE` bins/decade.
  Memory is O(B x NBINS), independent of step count; any quantile read
  from the histogram is exact up to one bin width (a factor of
  ``10**(1/BINS_PER_DECADE)`` ~= 1.33x). Values below/above the span
  clamp into the first/last bin.
* **Welford accumulators** (count / mean / M2) per tenant (job) over
  per-completion slowdown samples, merged each step with Chan's parallel
  update — exact in exact arithmetic, fp32-stable in practice.

Exactness contract (pinned by tests/test_workload.py): binning the same
samples post-hoc with :func:`np_hist` reproduces the streaming histogram
*exactly* (same counts, bin by bin), and the streaming Welford mean /
variance match the post-hoc mean / variance to fp tolerance. The
streaming path loses only within-bin resolution, never samples.

Everything traced lives here as jnp-polymorphic helpers (pass ``xp``);
host-side extraction (:func:`percentiles`, :func:`hist_cdf`,
:func:`welford_finalize`) is plain NumPy.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

NBINS = 64
BINS_PER_DECADE = 8
DECADES = NBINS // BINS_PER_DECADE  # 8 decades
LOG10_MIN = -7.0  # first bin edge: 100 ns — below any queue delay of note
_FLOOR = 1e-30  # log argument floor; maps 0.0 into the first bin

# default quantiles reported by the replay driver
QUANTILES = (0.50, 0.90, 0.99, 0.999)


def bin_edges() -> np.ndarray:
    """(NBINS + 1,) bin edges in seconds, log-spaced."""
    return 10.0 ** (LOG10_MIN + np.arange(NBINS + 1) / BINS_PER_DECADE)


def bin_index(x, xp=np):
    """Bin id for sample(s) ``x`` (seconds) — identical formula for the
    traced (xp=jnp) and post-hoc (xp=np) paths, so streaming and
    materialized histograms agree bin-for-bin."""
    lg = xp.log10(xp.maximum(xp.asarray(x, xp.float32), _FLOOR))
    idx = xp.floor((lg - LOG10_MIN) * BINS_PER_DECADE)
    return xp.clip(idx, 0, NBINS - 1).astype(xp.int32)


def hist_add(h, x, w, xp):
    """Scatter weighted samples into histogram ``h`` (shape (NBINS,))."""
    return h.at[bin_index(x, xp)].add(xp.asarray(w, xp.float32))


def np_hist(x, w=None) -> np.ndarray:
    """Post-hoc reference histogram over materialized samples — the
    exactness oracle for the streaming path."""
    x = np.asarray(x, np.float32).ravel()
    w = np.ones_like(x) if w is None else np.asarray(w, np.float32).ravel()
    h = np.zeros((NBINS,), np.float64)
    np.add.at(h, np.asarray(bin_index(x, np)).ravel(), w)
    return h.astype(np.float32)


def welford_update(wn, wmean, wm2, sample, weight, seg_ids, n_groups, xp):
    """Merge one step's per-group sample batch into Welford accumulators
    (Chan's parallel update). ``sample``/``weight`` are per-element;
    ``seg_ids`` groups them (e.g. flow -> job). A group with zero batch
    weight is left exactly unchanged (frac == 0)."""
    w = xp.asarray(weight, xp.float32)
    zeros = xp.zeros((n_groups,), xp.float32)
    nb = zeros.at[seg_ids].add(w)
    sum_b = zeros.at[seg_ids].add(w * sample)
    mean_b = sum_b / xp.maximum(nb, 1.0)
    m2_b = zeros.at[seg_ids].add(w * (sample - mean_b[seg_ids]) ** 2)
    n_new = wn + nb
    delta = mean_b - wmean
    frac = nb / xp.maximum(n_new, 1.0)
    return (n_new,
            wmean + delta * frac,
            wm2 + m2_b + delta * delta * wn * frac)


def welford_finalize(wn, wmean, wm2):
    """(count, mean, std) from accumulators; NaN mean/std where count==0."""
    wn = np.asarray(wn, np.float64)
    empty = wn <= 0
    mean = np.where(empty, np.nan, np.asarray(wmean, np.float64))
    var = np.asarray(wm2, np.float64) / np.maximum(wn, 1.0)
    std = np.where(empty, np.nan, np.sqrt(np.maximum(var, 0.0)))
    return wn, mean, std


def percentiles(h: np.ndarray, qs: Sequence[float] = QUANTILES) -> dict:
    """Quantiles read from a histogram: the geometric midpoint of the
    first bin whose cumulative weight reaches ``q`` of the total. Exact
    up to one bin width. Empty histogram -> NaN. Batched histograms
    (.., NBINS) return arrays over the leading axes."""
    h = np.asarray(h, np.float64)
    edges = bin_edges()
    mids = np.sqrt(edges[:-1] * edges[1:])
    cdf = np.cumsum(h, axis=-1)
    total = cdf[..., -1:]
    out = {}
    for q in qs:
        # first bin with cdf >= q * total (argmax of the boolean mask)
        hit = cdf >= np.maximum(q * total, _FLOOR)
        idx = np.argmax(hit, axis=-1)
        val = mids[idx]
        out[q] = np.where(total[..., 0] > 0, val, np.nan)
    return out


def hist_cdf(h: np.ndarray):
    """(upper_edges, cdf in [0,1]) for plotting FCT / delay CDFs."""
    h = np.asarray(h, np.float64)
    cdf = np.cumsum(h, axis=-1)
    total = np.maximum(cdf[..., -1:], _FLOOR)
    return bin_edges()[1:], cdf / total
