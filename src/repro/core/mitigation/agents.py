"""Pluggable mitigation-search agents over the batched evaluator
(ROADMAP item 5; archgym-style simulator-backed design-space search).

A :class:`SearchAgent` proposes a *batch* of :class:`Candidate` points
per generation and observes their panel scores:

    propose(history) -> List[Candidate]     # one generation
    observe(observations)                   # scores come back

Agents search the normalized unit cube over a set of continuous CC
knobs (``cc.SEARCH_BOUNDS``); :class:`PanelEvaluator` lowers every
generation into ONE ``search.run_candidates`` call — the candidates
ride vmap lanes, so a generation costs one ``run_cells_hetero`` launch
(and, after the first generation fixes the lane shape, zero new
compiles: tests/test_agents.py pins the TRACE_COUNTS contract). The
evaluator memoizes scores by candidate label, so an agent re-proposing
an already-scored point hits the table instead of the simulator.

Four implementations (the archgym lineup, numpy-only):

* :class:`RandomWalkAgent` — uniform random search, the baseline every
  learned agent must beat.
* :class:`GAAgent` — (mu + lambda) evolutionary search: tournament
  selection, blend crossover, gaussian mutation.
* :class:`CMAESAgent` — separable (diagonal-covariance) CMA-ES with
  step-size adaptation via the standard evolution paths.
* :class:`BOAgent` — lightweight Bayesian optimization: a Matern-5/2 GP
  surrogate fit by Cholesky, expected-improvement acquisition maximized
  over a seeded random pool.

:func:`run_agent` drives one agent to an evaluation budget and logs a
:class:`Trajectory` (best-so-far score vs. evaluations, wall-clock,
compile counts); :func:`compare_agents` produces the archgym-style
time-to-convergence report against the bounded-grid winner
(:func:`grid_reference`) that benchmarks/whatif_bench.py records.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fabric import simulator as sim
from repro.core.fabric.cc import SEARCH_BOUNDS
from repro.core.mitigation import score as score_lib
from repro.core.mitigation import search
from repro.core.mitigation.score import CandidateScore
from repro.core.mitigation.search import Candidate, PanelCell

# default knob subset agents navigate: the injection-throttling axes of
# Olmedilla et al. (DCQCN/AI-ECN rate control + HOL isolation)
AGENT_KNOBS = ("hol_factor", "md", "rai_frac")

# baseline-tax penalty: pick_winner disqualifies candidates whose
# uncongested baseline exceeds the default by > 2%; the scalar objective
# soft-penalizes past the same slack so the search landscape stays
# continuous while agreeing with the winner guard at the optimum
BASELINE_SLACK = 0.02
TAX_WEIGHT = 10.0


def objective(s: CandidateScore) -> float:
    """Scalarized panel score (maximized): worst-cell victim ratio,
    soft-penalized by any uncongested-baseline tax beyond the
    ``pick_winner`` slack. Full-panel DNF is -inf."""
    if not np.isfinite(s.ratio_min):
        return float("-inf")
    tax = max(0.0, s.t_base_worst_rel - (1.0 + BASELINE_SLACK))
    return float(s.ratio_min - TAX_WEIGHT * tax)


@dataclasses.dataclass(frozen=True)
class Observation:
    """One scored candidate handed back to the agent."""

    candidate: Candidate
    objective: float
    score: CandidateScore


# --------------------------------------------------------------------------
# Batched panel evaluation with a memo table
# --------------------------------------------------------------------------


class PanelEvaluator:
    """Scores candidate batches on a fixed panel through ONE
    ``run_candidates`` call per batch, memoizing by candidate label.

    The fabric-default candidate rides the first fresh batch (aggregate
    needs its uncongested times as the baseline reference), so a whole
    multi-generation search compiles at most two lane shapes: the first
    generation's (batch + default) and the steady-state batch.
    ``evals`` counts candidate evaluations actually sent to the
    simulator (the default baseline is shared overhead, not charged);
    ``table_hits`` counts re-proposals served from the memo."""

    def __init__(self, panel: Sequence[PanelCell], *, n_iters: int = 12,
                 warmup: int = 3, max_steps: int = 200_000,
                 chunk: int = 2048, stride: int = 8, mesh=None,
                 launcher=None):
        self.panel = list(panel)
        self.kw = dict(n_iters=n_iters, warmup=warmup, max_steps=max_steps,
                       chunk=chunk, stride=stride, mesh=mesh,
                       launcher=launcher)
        self.table: Dict[str, CandidateScore] = {}
        self._default_runs: Optional[list] = None
        self.evals = 0
        self.table_hits = 0
        self.calls = 0

    def evaluate(self, cands: Sequence[Candidate]) -> List[CandidateScore]:
        labels = [c.label() for c in cands]
        fresh: List[Candidate] = []
        seen = set(self.table)
        for c, lab in zip(cands, labels):
            if lab in seen:
                self.table_hits += 1
            else:
                fresh.append(c)
                seen.add(lab)
        if fresh:
            batch = list(fresh)
            ride_default = self._default_runs is None
            if ride_default:
                batch.insert(0, search.default_candidate())
            runs = search.run_candidates(self.panel, batch, **self.kw)
            self.calls += 1
            if ride_default:
                self._default_runs = [r for r in runs
                                      if r.candidate == "default"]
            else:
                runs = runs + self._default_runs
            for s in score_lib.aggregate(runs):
                self.table[s.candidate] = s
            self.evals += len(fresh)
        return [self.table[lab] for lab in labels]


# --------------------------------------------------------------------------
# Agent interface + the four implementations
# --------------------------------------------------------------------------


class SearchAgent:
    """Base: candidates <-> normalized unit-cube vectors over a set of
    continuous ``SEARCH_BOUNDS`` knobs. Deterministic under a fixed seed
    (every draw comes from the agent's own ``default_rng``)."""

    kind = "agent"

    def __init__(self, knobs: Sequence[str] = AGENT_KNOBS, *,
                 batch: int = 8, seed: int = 0,
                 policy: Optional[int] = None):
        knobs = tuple(knobs)
        for k in knobs:
            if k not in search.GRAD_KNOBS:
                raise KeyError(f"{k!r} is not a continuous searchable "
                               f"knob; choose from {search.GRAD_KNOBS}")
        if batch < 1:
            raise ValueError("batch must be >= 1")
        self.knobs = knobs
        self.dim = len(knobs)
        self.bounds = np.asarray([SEARCH_BOUNDS[k] for k in knobs],
                                 np.float64)
        self.batch = int(batch)
        self.seed = int(seed)
        self.rng = np.random.default_rng(seed)
        self.policy = policy
        self.history: List[Observation] = []

    # ---- unit cube <-> Candidate --------------------------------------
    def to_candidate(self, x: np.ndarray) -> Candidate:
        x = np.clip(np.asarray(x, np.float64), 0.0, 1.0)
        lo, hi = self.bounds[:, 0], self.bounds[:, 1]
        vals = lo + (hi - lo) * x
        return Candidate(policy=self.policy,
                         cc=tuple(sorted(zip(self.knobs, map(float, vals)))))

    def to_vector(self, cand: Candidate) -> np.ndarray:
        cc = dict(cand.cc)
        lo, hi = self.bounds[:, 0], self.bounds[:, 1]
        vals = np.asarray([cc[k] for k in self.knobs], np.float64)
        return np.clip((vals - lo) / (hi - lo), 0.0, 1.0)

    # ---- the pluggable surface ----------------------------------------
    def propose(self, history: Sequence[Observation]) -> List[Candidate]:
        raise NotImplementedError

    def observe(self, observations: Sequence[Observation]) -> None:
        self.history.extend(observations)
        self._update(list(observations))

    def _update(self, obs: List[Observation]) -> None:
        pass

    # ---- helpers -------------------------------------------------------
    def _finite(self, f: float) -> float:
        # DNF lanes rank strictly below every finished candidate but
        # stay finite so means/weights remain well-defined
        return f if np.isfinite(f) else -1e6

    def best(self) -> Optional[Observation]:
        if not self.history:
            return None
        return max(self.history, key=lambda o: self._finite(o.objective))


class RandomWalkAgent(SearchAgent):
    """Uniform random search — the archgym random-walker baseline every
    learned agent is compared against at equal budget."""

    kind = "random"

    def propose(self, history) -> List[Candidate]:
        return [self.to_candidate(self.rng.uniform(size=self.dim))
                for _ in range(self.batch)]


class GAAgent(SearchAgent):
    """(mu + lambda) evolutionary search: tournament selection over the
    surviving population, per-dimension blend crossover, gaussian
    mutation."""

    kind = "ga"

    def __init__(self, knobs: Sequence[str] = AGENT_KNOBS, *,
                 batch: int = 8, seed: int = 0,
                 policy: Optional[int] = None, mu: int = 8,
                 sigma: float = 0.12, p_mut: float = 0.5):
        super().__init__(knobs, batch=batch, seed=seed, policy=policy)
        self.mu = int(mu)
        self.sigma = float(sigma)
        self.p_mut = float(p_mut)
        self.pop: List[Tuple[np.ndarray, float]] = []

    def _tournament(self) -> np.ndarray:
        k = min(3, len(self.pop))
        picks = [self.pop[i] for i in
                 self.rng.choice(len(self.pop), size=k, replace=False)]
        return max(picks, key=lambda p: p[1])[0]

    def propose(self, history) -> List[Candidate]:
        if not self.pop:  # seed generation
            return [self.to_candidate(self.rng.uniform(size=self.dim))
                    for _ in range(self.batch)]
        out = []
        for _ in range(self.batch):
            pa, pb = self._tournament(), self._tournament()
            alpha = self.rng.uniform(size=self.dim)
            child = alpha * pa + (1.0 - alpha) * pb
            mut = self.rng.random(self.dim) < self.p_mut
            child = child + mut * self.rng.normal(0.0, self.sigma, self.dim)
            out.append(self.to_candidate(child))
        return out

    def _update(self, obs: List[Observation]) -> None:
        self.pop.extend((self.to_vector(o.candidate),
                         self._finite(o.objective)) for o in obs)
        self.pop.sort(key=lambda p: -p[1])
        del self.pop[self.mu:]


class CMAESAgent(SearchAgent):
    """Separable CMA-ES (diagonal covariance): rank-weighted mean
    recombination, cumulative step-size adaptation, and per-dimension
    variance updates — the standard sep-CMA-ES constants, numpy-only."""

    kind = "cmaes"

    def __init__(self, knobs: Sequence[str] = AGENT_KNOBS, *,
                 batch: int = 8, seed: int = 0,
                 policy: Optional[int] = None, sigma0: float = 0.3):
        super().__init__(knobs, batch=batch, seed=seed, policy=policy)
        d, lam = self.dim, self.batch
        self.mean = np.full(d, 0.5)
        self.sigma = float(sigma0)
        mu = max(lam // 2, 1)
        w = np.log(mu + 0.5) - np.log(np.arange(1, mu + 1))
        self.w = w / w.sum()
        self.mueff = 1.0 / np.sum(self.w ** 2)
        self.cs = (self.mueff + 2.0) / (d + self.mueff + 5.0)
        self.damps = 1.0 + 2.0 * max(
            0.0, math.sqrt((self.mueff - 1.0) / (d + 1.0)) - 1.0) + self.cs
        self.cc = (4.0 + self.mueff / d) / (d + 4.0 + 2.0 * self.mueff / d)
        self.c1 = 2.0 / ((d + 1.3) ** 2 + self.mueff)
        self.cmu = min(1.0 - self.c1,
                       2.0 * (self.mueff - 2.0 + 1.0 / self.mueff)
                       / ((d + 2.0) ** 2 + self.mueff))
        # sep-CMA corrections scale cmu up for diagonal-only updates
        self.cmu = min(1.0 - self.c1, self.cmu * (d + 2.0) / 3.0)
        self.C = np.ones(d)
        self.ps = np.zeros(d)
        self.pc = np.zeros(d)
        self.chiN = math.sqrt(d) * (1.0 - 1.0 / (4.0 * d)
                                    + 1.0 / (21.0 * d * d))
        self.gen = 0
        self._last: List[np.ndarray] = []

    def propose(self, history) -> List[Candidate]:
        std = self.sigma * np.sqrt(self.C)
        self._last = [np.clip(self.mean + std
                              * self.rng.standard_normal(self.dim), 0.0, 1.0)
                      for _ in range(self.batch)]
        return [self.to_candidate(x) for x in self._last]

    def _update(self, obs: List[Observation]) -> None:
        # re-derive the sampled vectors from the observed candidates so
        # table-served duplicates cannot desynchronize sampling state
        xs = np.asarray([self.to_vector(o.candidate) for o in obs])
        fs = np.asarray([self._finite(o.objective) for o in obs])
        order = np.argsort(-fs)
        mu = len(self.w)
        if len(order) < mu:  # short generation (budget tail)
            w = self.w[:len(order)]
            w = w / w.sum()
        else:
            w = self.w
        sel = xs[order[:len(w)]]
        old = self.mean
        self.mean = w @ sel
        d = self.dim
        y = (self.mean - old) / max(self.sigma, 1e-12)
        self.ps = (1.0 - self.cs) * self.ps + math.sqrt(
            self.cs * (2.0 - self.cs) * self.mueff) \
            * y / np.sqrt(np.maximum(self.C, 1e-12))
        self.gen += 1
        hsig = (np.linalg.norm(self.ps)
                / math.sqrt(1.0 - (1.0 - self.cs) ** (2.0 * self.gen))
                / self.chiN) < 1.4 + 2.0 / (d + 1.0)
        self.pc = (1.0 - self.cc) * self.pc + hsig * math.sqrt(
            self.cc * (2.0 - self.cc) * self.mueff) * y
        artmp = (sel - old) / max(self.sigma, 1e-12)
        self.C = (1.0 - self.c1 - self.cmu) * self.C \
            + self.c1 * (self.pc ** 2
                         + (1.0 - hsig) * self.cc * (2.0 - self.cc) * self.C) \
            + self.cmu * (w @ (artmp ** 2))
        self.C = np.maximum(self.C, 1e-8)
        self.sigma *= math.exp((self.cs / self.damps)
                               * (np.linalg.norm(self.ps) / self.chiN - 1.0))
        self.sigma = float(np.clip(self.sigma, 1e-4, 1.0))


def _norm_cdf(z: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + np.vectorize(math.erf)(z / math.sqrt(2.0)))


def _norm_pdf(z: np.ndarray) -> np.ndarray:
    return np.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)


class BOAgent(SearchAgent):
    """Lightweight Bayesian optimization: Matern-5/2 GP surrogate (fixed
    lengthscale, Cholesky fit with jitter) + expected-improvement
    acquisition maximized over a seeded random pool mixed with local
    perturbations of the incumbent. Pure numpy — no scipy."""

    kind = "bo"

    def __init__(self, knobs: Sequence[str] = AGENT_KNOBS, *,
                 batch: int = 8, seed: int = 0,
                 policy: Optional[int] = None, lengthscale: float = 0.25,
                 noise: float = 1e-4, pool: int = 256, xi: float = 0.01):
        super().__init__(knobs, batch=batch, seed=seed, policy=policy)
        self.ell = float(lengthscale)
        self.noise = float(noise)
        self.pool = int(pool)
        self.xi = float(xi)

    def _kernel(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        d = np.sqrt(np.maximum(
            ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1), 1e-18))
        r = math.sqrt(5.0) * d / self.ell
        return (1.0 + r + r * r / 3.0) * np.exp(-r)

    def propose(self, history) -> List[Candidate]:
        obs = [o for o in history if np.isfinite(o.objective)]
        if len(obs) < max(2 * self.dim, 4):  # cold start: space-filling
            return [self.to_candidate(self.rng.uniform(size=self.dim))
                    for _ in range(self.batch)]
        X = np.asarray([self.to_vector(o.candidate) for o in obs])
        y = np.asarray([o.objective for o in obs], np.float64)
        ym, ys = y.mean(), max(y.std(), 1e-9)
        yn = (y - ym) / ys
        K = self._kernel(X, X) + (self.noise + 1e-8) * np.eye(len(X))
        L = np.linalg.cholesky(K)
        alpha = np.linalg.solve(L.T, np.linalg.solve(L, yn))
        # acquisition pool: global uniform + local moves around the best
        best_x = X[int(np.argmax(y))]
        cand = np.concatenate([
            self.rng.uniform(size=(self.pool, self.dim)),
            np.clip(best_x + 0.1
                    * self.rng.standard_normal((self.pool // 4, self.dim)),
                    0.0, 1.0)])
        Ks = self._kernel(cand, X)
        mu = Ks @ alpha
        v = np.linalg.solve(L, Ks.T)
        var = np.maximum(1.0 - (v ** 2).sum(0), 1e-12)
        sd = np.sqrt(var)
        f_best = yn.max()
        z = (mu - f_best - self.xi) / sd
        ei = (mu - f_best - self.xi) * _norm_cdf(z) + sd * _norm_pdf(z)
        order = np.argsort(-ei)
        picks: List[np.ndarray] = []
        for i in order:
            x = cand[i]
            if any(np.abs(x - p).max() < 1e-3 for p in picks):
                continue  # batch-diversity: skip near-duplicates
            picks.append(x)
            if len(picks) == self.batch:
                break
        while len(picks) < self.batch:  # pool exhausted: explore
            picks.append(self.rng.uniform(size=self.dim))
        return [self.to_candidate(x) for x in picks]


AGENTS = {a.kind: a for a in (RandomWalkAgent, GAAgent, CMAESAgent, BOAgent)}


def make_agent(kind: str, **kw) -> SearchAgent:
    if kind not in AGENTS:
        raise KeyError(f"unknown agent kind {kind!r}; "
                       f"known: {sorted(AGENTS)}")
    return AGENTS[kind](**kw)


# --------------------------------------------------------------------------
# Trajectory logging + the archgym-style comparison harness
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Trajectory:
    """Per-agent search log: best-so-far objective vs. cumulative
    simulator evaluations, wall-clock and engine compiles (TRACE_COUNTS
    delta) after each generation."""

    agent: str
    evals: List[int] = dataclasses.field(default_factory=list)
    best: List[float] = dataclasses.field(default_factory=list)
    wall_s: List[float] = dataclasses.field(default_factory=list)
    traces: List[int] = dataclasses.field(default_factory=list)
    best_label: str = ""
    best_score: Optional[CandidateScore] = None

    def evals_to(self, target: float, tol: float = 1e-6) -> Optional[int]:
        """Evaluations spent when best-so-far first reached ``target``
        (None = never within budget) — the time-to-convergence axis."""
        for e, b in zip(self.evals, self.best):
            if b >= target - tol:
                return e
        return None

    def as_dict(self) -> dict:
        return {"agent": self.agent, "evals": list(self.evals),
                "best": [float(b) for b in self.best],
                "wall_s": [round(float(w), 3) for w in self.wall_s],
                "traces": list(self.traces),
                "best_label": self.best_label,
                "best_objective": float(self.best[-1])
                if self.best else float("-inf")}


def run_agent(agent: SearchAgent, panel: Sequence[PanelCell], *,
              budget: int = 32,
              evaluator: Optional[PanelEvaluator] = None,
              **run_kw) -> Trajectory:
    """Drive one agent to ``budget`` simulator evaluations; one
    ``run_candidates`` call per generation. Budgets that are a multiple
    of the agent's batch land exactly; otherwise the final generation
    overruns by at most batch-1 (lane shapes stay fixed, which is what
    keeps the whole search at one steady-state compile)."""
    ev = evaluator if evaluator is not None else PanelEvaluator(
        panel, **run_kw)
    traj = Trajectory(agent=agent.kind)
    t0 = time.monotonic()
    tr0 = sim.trace_count("run_cells_hetero")
    best = float("-inf")
    best_s: Optional[CandidateScore] = None
    guard = 0
    while ev.evals < budget:
        props = list(agent.propose(agent.history))
        if not props:
            break
        before = ev.evals
        scores = ev.evaluate(props)
        obs = [Observation(c, objective(s), s)
               for c, s in zip(props, scores)]
        agent.observe(obs)
        for o in obs:
            if o.objective > best:
                best = o.objective
                best_s = o.score
        traj.evals.append(ev.evals)
        traj.best.append(best)
        traj.wall_s.append(time.monotonic() - t0)
        traj.traces.append(sim.trace_count("run_cells_hetero") - tr0)
        # a fully-converged agent proposing only table-known points makes
        # no progress against the budget; stop after a few such rounds
        guard = guard + 1 if ev.evals == before else 0
        if guard >= 3:
            break
    if best_s is not None:
        traj.best_label = best_s.candidate
        traj.best_score = best_s
    return traj


def grid_candidates(knobs: Sequence[str] = AGENT_KNOBS, *,
                    points_per_knob: int = 3,
                    policy: Optional[int] = None) -> List[Candidate]:
    """Cartesian ``points_per_knob``-level grid over continuous knobs
    (the search space's corners + midpoints) — the bounded-grid tier the
    agents race against, and the what-if layer's default candidate
    list."""
    axes = []
    for k in knobs:
        lo, hi = SEARCH_BOUNDS[k]
        axes.append((k, tuple(float(v)
                              for v in np.linspace(lo, hi,
                                                   points_per_knob))))
    return [Candidate(policy=policy, cc=tuple(sorted(zip(
        [k for k, _ in axes], vals))))
        for vals in itertools.product(*[v for _, v in axes])]


def grid_reference(panel: Sequence[PanelCell],
                   knobs: Sequence[str] = AGENT_KNOBS, *,
                   points_per_knob: int = 3,
                   policy: Optional[int] = None,
                   evaluator: Optional[PanelEvaluator] = None,
                   **run_kw) -> dict:
    """The bounded-grid tier's winner on the same objective, scored in
    one batched call. Returns the target the agents race toward:
    {label, objective, evals}."""
    cands = grid_candidates(knobs, points_per_knob=points_per_knob,
                            policy=policy)
    ev = evaluator if evaluator is not None else PanelEvaluator(
        panel, **run_kw)
    scores = ev.evaluate(cands)
    objs = [objective(s) for s in scores]
    i = int(np.argmax(objs))
    return {"label": scores[i].candidate, "objective": float(objs[i]),
            "evals": len(cands)}


def compare_agents(agent_kinds: Sequence[str],
                   panel: Sequence[PanelCell], *, budget: int = 32,
                   batch: int = 8, knobs: Sequence[str] = AGENT_KNOBS,
                   seed: int = 0, policy: Optional[int] = None,
                   target: Optional[dict] = None,
                   **run_kw) -> dict:
    """The archgym-style comparison: run each agent kind (fresh
    evaluator each — no cross-agent freeloading through the memo table)
    to the same budget, then report per-agent trajectories and
    evaluations-to-target against the bounded-grid winner."""
    if target is None:
        target = grid_reference(panel, knobs, policy=policy, **run_kw)
    report: dict = {"budget": budget, "batch": batch,
                    "knobs": list(knobs), "target": target, "agents": {}}
    for kind in agent_kinds:
        agent = make_agent(kind, knobs=knobs, batch=batch, seed=seed,
                           policy=policy)
        ev = PanelEvaluator(panel, **run_kw)
        traj = run_agent(agent, panel, budget=budget, evaluator=ev)
        d = traj.as_dict()
        d["evals_to_target"] = traj.evals_to(target["objective"])
        d["table_hits"] = ev.table_hits
        report["agents"][kind] = d
    return report
