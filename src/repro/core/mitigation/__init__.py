"""Mitigation lab: congestion mitigations as first-class searchable
objects (paper's closing charge — "guide researchers and HPC architects
in designing more effective congestion-control mechanisms and network
load-balancing strategies").

* :mod:`search` — bounded CC / routing knob spaces expanded into stacked
  ``SimParams`` and swept through the batched engine in one
  ``jit(vmap)``, plus a gradient tier that differentiates victim
  slowdown through the fluid scan.
* :mod:`score` — multi-scenario panels drawn from the scenario registry,
  per-candidate metrics (victim slowdown, aggressor goodput, Jain
  fairness), Pareto frontier and per-fabric winner selection.
"""
from repro.core.mitigation import score, search  # noqa: F401
