"""Multi-scenario Pareto scoring for mitigation candidates.

Each candidate is measured across a *panel* of scenarios drawn from the
scenario registry (steady incast, bursty duty cycles, multi-job mixes)
and summarized on three axes:

* ``ratio_min`` / ``ratio_mean`` — victim slowdown (the paper's
  t_uncongested/t_congested; 1.0 = congestion fully mitigated). The
  worst cell is the headline: a mitigation that flat-lines steady incast
  but collapses under bursts has NOT solved the problem.
* ``aggr_gbps`` — aggressor/background goodput. Throttling aggressors to
  zero trivially protects victims (Olmedilla et al.'s injection-
  throttling tradeoff); a real mitigation keeps background tenants fed.
* ``jain`` — Jain fairness over victim flows' delivered bytes (a policy
  that saves the mean by starving one victim flow shows up here).

:func:`pareto_frontier` reports the non-dominated candidates on those
axes; :func:`pick_winner` scalarizes (worst-cell ratio first, then
fairness, then aggressor goodput) under a baseline guard: a winner may
not degrade the uncongested iteration time vs the fabric default by
more than ``baseline_slack``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import scenarios as scen
from repro.core.fabric import systems
from repro.core.mitigation import search
from repro.core.mitigation.search import Candidate, CellRun, PanelCell

# scenario families the default panel draws from
PANEL_SCENARIO = "mitigation_panel"
# the link-fault robustness panel: benchmarks/fault_scenarios.py asks
# "which CC/routing config is robust to a flapping link" per fabric
FAULT_PANEL_SCENARIO = "link_fault"


def panel_from_scenario(name: str = PANEL_SCENARIO,
                        quick: bool = False) -> List[PanelCell]:
    """Expand a registered grid scenario into panel cells (one cell per
    (grid, size, profile) — the registry stays the single source of
    scenario truth; the panel is just a flattened view of it)."""
    scenario = scen.get(name, quick)
    cells: List[PanelCell] = []
    for grid in scenario.grids:
        # scale-batched grids carry (system, n_nodes) in ``cells`` and a
        # placeholder label in ``system``; plain grids are the one-cell
        # special case (mirrors benchmarks.common.expected_grid_keys)
        grid_cells = list(grid.cells) or [(grid.system, grid.n_nodes)]
        for sysname, n in grid_cells:
            sysp = systems.get_system(sysname)
            for v in grid.sizes:
                for prof in grid.profiles:
                    # n_nodes is part of the key: scale-batched grids
                    # repeat a system at several scales, and aggregate()
                    # matches baselines by cell name
                    cells.append(PanelCell(
                        name=f"{name}:{sysname}-{int(n)}/{grid.aggressor}"
                             f"/{prof.label()}/{int(v)}",
                        system=sysp, n_nodes=int(n), victim=grid.victim,
                        aggressor=grid.aggressor, vector_bytes=float(v),
                        profile=prof, jobs=tuple(grid.jobs)))
    return cells


@dataclasses.dataclass
class CandidateScore:
    """Panel-aggregated scorecard of one candidate."""

    candidate: str
    ratio_min: float  # worst-cell victim ratio (headline axis)
    ratio_mean: float
    aggr_gbps: float  # mean aggressor/background goodput, congested lanes
    jain: float  # mean victim fairness
    t_base_worst_rel: float  # worst baseline time relative to default (1.0 =
    # no uncongested-cost; >1 = the mitigation taxes the uncongested case)
    cells: Tuple[CellRun, ...] = ()
    # panel cells this candidate did not finish (zero completed
    # iterations): excluded from every axis above; a candidate that DNFs
    # its WHOLE panel has NaN axes and is dropped from the frontier
    n_dnf: int = 0


def aggregate(runs: Sequence[CellRun],
              default_label: str = "default") -> List[CandidateScore]:
    """Fold per-cell runs into per-candidate scorecards. Baseline cost is
    measured against the ``default_label`` candidate's uncongested time
    on the same cell (the fabric's shipped config). DNF cells (zero
    completed iterations — NaN times) are counted in ``n_dnf`` and
    excluded from the axes rather than silently averaged."""
    by_cand: Dict[str, List[CellRun]] = {}
    for r in runs:
        by_cand.setdefault(r.candidate, []).append(r)
    base_t = {r.cell: r.t_uncongested_s
              for r in by_cand.get(default_label, []) if not r.dnf}
    out = []
    for cand, rs in by_cand.items():
        ok = [r for r in rs if not r.dnf]
        rel = [r.t_uncongested_s / base_t[r.cell]
               for r in ok if base_t.get(r.cell, 0) > 0]
        out.append(CandidateScore(
            candidate=cand,
            ratio_min=min(r.ratio for r in ok) if ok else float("nan"),
            ratio_mean=float(np.mean([r.ratio for r in ok]))
            if ok else float("nan"),
            aggr_gbps=float(np.mean(
                [8e-9 * r.aggr_bytes / max(r.sim_time_s, 1e-9)
                 for r in ok])) if ok else float("nan"),
            jain=float(np.mean([r.jain for r in ok]))
            if ok else float("nan"),
            t_base_worst_rel=max(rel) if rel else 1.0,
            cells=tuple(rs),
            n_dnf=len(rs) - len(ok)))
    return out


# Pareto axes: all maximized
AXES = ("ratio_min", "aggr_gbps", "jain")


def _dominates(a: CandidateScore, b: CandidateScore, eps: float) -> bool:
    ge = all(getattr(a, ax) >= getattr(b, ax) - eps for ax in AXES)
    gt = any(getattr(a, ax) > getattr(b, ax) + eps for ax in AXES)
    return ge and gt


def _scored(scores: Sequence[CandidateScore]) -> List[CandidateScore]:
    """Candidates with at least one finished panel cell (full-panel DNF
    leaves every axis NaN — incomparable, excluded from the frontier)."""
    return [s for s in scores if np.isfinite(s.ratio_min)]


def pareto_frontier(scores: Sequence[CandidateScore],
                    eps: float = 1e-3) -> List[CandidateScore]:
    """Non-dominated candidates on (victim ratio, aggressor goodput,
    fairness), sorted by worst-cell ratio descending. Full-panel DNF
    candidates are excluded (their axes are NaN)."""
    scores = _scored(scores)
    front = [s for s in scores
             if not any(_dominates(o, s, eps) for o in scores if o is not s)]
    return sorted(front, key=lambda s: (-s.ratio_min, -s.jain,
                                        -s.aggr_gbps))


def pick_winner(scores: Sequence[CandidateScore],
                baseline_slack: float = 0.02) -> CandidateScore:
    """Scalarized per-fabric winner: best worst-cell ratio (then
    fairness, then aggressor goodput) among candidates whose uncongested
    baseline stays within ``baseline_slack`` of the fabric default.
    Full-panel DNF candidates never win (unless EVERY candidate DNF'd,
    in which case the first is returned as a flagged placeholder)."""
    finished = _scored(scores)
    if not finished:  # nothing completed: surface the failure, don't crash
        return scores[0]
    ok = [s for s in finished if s.t_base_worst_rel <= 1.0 + baseline_slack]
    if not ok:  # every candidate taxes the baseline; fall back to all
        ok = finished
    return max(ok, key=lambda s: (round(s.ratio_min, 3),
                                  round(s.jain, 3), s.aggr_gbps))


def winners_by_system(runs: Sequence[CellRun],
                      baseline_slack: float = 0.02,
                      default_label: str = "default",
                      ) -> Dict[str, CandidateScore]:
    """Per-fabric winners: split cell runs on the system token of the
    panel-cell name (``<scenario>:<system>-<n>/...``, the format
    :func:`panel_from_scenario` emits) and pick a winner per fabric.
    The fault panels care about this split — a config that rescues a
    flapping Slingshot link may tax a fat-tree's baseline."""
    by_sys: Dict[str, List[CellRun]] = {}
    for r in runs:
        sysname = r.cell.split(":", 1)[-1].split("-", 1)[0]
        by_sys.setdefault(sysname, []).append(r)
    return {s: pick_winner(aggregate(rs, default_label=default_label),
                           baseline_slack=baseline_slack)
            for s, rs in sorted(by_sys.items())}


def score_table(panel: Sequence[PanelCell],
                candidates: Sequence[Candidate], *, n_iters: int = 12,
                warmup: int = 3, **kw) -> List[CandidateScore]:
    """Run the full (panel x candidate) sweep and aggregate. The default
    candidate is prepended if absent so baseline guards always have a
    reference."""
    cands = list(candidates)
    if not any(c.label() == "default" for c in cands):
        cands.insert(0, search.default_candidate())
    runs = search.run_candidates(panel, cands, n_iters=n_iters,
                                 warmup=warmup, **kw)
    return aggregate(runs)
