"""Vmapped mitigation search: CC / load-balancing knob spaces swept
through the batched fabric engine.

A :class:`Candidate` is one point of the mitigation space: a traced
routing policy id (+ flowlet gap) and a set of CC scalar overrides —
every knob a ``SimParams`` field, bounded by ``cc.SEARCH_BOUNDS``.
:func:`run_candidates` expands (panel cell x candidate x
baseline/congested) into stacked ``SimParams`` and executes the whole
search in ONE ``run_cells_hetero`` call per GeometryDims bucket: the
candidates ride the same vmap lanes a parameter sweep does, so scoring
50 candidates costs one compile, not 50.

Two tiers:

* **grid tier** — cartesian expansion of :class:`CCSpace` x
  :class:`RoutingSpace` (:func:`expand`), scored by
  ``score.score_table``.
* **gradient tier** (:func:`gradient_refine`) — the engine is pure, so
  victim slowdown is differentiable through the fluid scan: continuous
  knobs are sigmoid-reparameterized into their bounds and descended
  with plain Adam against a fixed-length ``lax.scan`` objective
  (``lax.while_loop`` has no reverse-mode rule — the early-exit runner
  is for measurement, the fixed-length one for gradients; DESIGN.md
  §12 documents the caveat).

:func:`simulated_times` is the single simulator-backed scoring path —
``autotune.predict_simulated`` is a thin lru-cached client of it.
"""
from __future__ import annotations

import dataclasses
import itertools
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bench
from repro.core import congestion as cong
from repro.core.fabric import simulator as sim
from repro.core.fabric.cc import SEARCH_BOUNDS
from repro.core.fabric.routing import (POLICY_FLOWLET, POLICY_NAMES)
from repro.core.fabric.systems import SystemPreset, default_policy, get_system

# knobs that stay integers when lowered into SimParams
_INT_KNOBS = ("kind",)


def check_bounds(name: str, value: float) -> float:
    if name not in SEARCH_BOUNDS:
        raise KeyError(f"unknown mitigation knob {name!r}; "
                       f"known: {sorted(SEARCH_BOUNDS)}")
    lo, hi = SEARCH_BOUNDS[name]
    if not (lo <= value <= hi):
        raise ValueError(f"{name}={value} outside bounds [{lo}, {hi}]")
    return value


@dataclasses.dataclass(frozen=True)
class CCSpace:
    """Bounded CC knob grid: (SimParams field, candidate values) pairs,
    expanded as a cartesian product. Values are validated against
    ``cc.SEARCH_BOUNDS`` at construction."""

    knobs: Tuple[Tuple[str, Tuple[float, ...]], ...] = ()

    def __post_init__(self):
        for name, values in self.knobs:
            for v in values:
                check_bounds(name, v)

    @staticmethod
    def of(**knobs) -> "CCSpace":
        return CCSpace(tuple((k, tuple(v)) for k, v in knobs.items()))

    def grid(self) -> List[Dict[str, float]]:
        names = [k for k, _ in self.knobs]
        return [dict(zip(names, vs)) for vs in itertools.product(
            *(vals for _, vals in self.knobs))] or [{}]


@dataclasses.dataclass(frozen=True)
class RoutingSpace:
    """Load-balancing candidates: traced policy ids plus flowlet gap
    thresholds (the gap axis only multiplies the flowlet policy)."""

    policies: Tuple[int, ...] = ()
    flowlet_gaps_s: Tuple[float, ...] = (200e-6,)

    def __post_init__(self):
        for g in self.flowlet_gaps_s:
            check_bounds("flowlet_gap_s", g)

    def grid(self) -> List[Dict[str, float]]:
        out: List[Dict[str, float]] = []
        for pol in self.policies or (None,):
            gaps = self.flowlet_gaps_s if pol == POLICY_FLOWLET \
                else self.flowlet_gaps_s[:1]
            out.extend({"policy": pol, "flowlet_gap_s": g} for g in gaps)
        return out


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the mitigation space. ``policy=None`` keeps each
    panel cell's system-default policy (CC-only candidates score fairly
    across fabrics with different native routing)."""

    policy: Optional[int] = None
    flowlet_gap_s: float = 200e-6
    cc: Tuple[Tuple[str, float], ...] = ()
    name: str = ""

    def label(self) -> str:
        if self.name:
            return self.name
        pol = "native" if self.policy is None else POLICY_NAMES[self.policy]
        if self.policy == POLICY_FLOWLET:
            pol += f"[{self.flowlet_gap_s * 1e6:g}us]"
        cc = ",".join(f"{k}={v:g}" for k, v in self.cc)
        return pol + (f"|{cc}" if cc else "")

    def apply(self, p: sim.SimParams, default_pol: int) -> sim.SimParams:
        pol = self.policy if self.policy is not None else default_pol
        kw = {"policy": jnp.asarray(pol, jnp.int32),
              "flowlet_gap_s": jnp.asarray(self.flowlet_gap_s, jnp.float32)}
        # a cc override of flowlet_gap_s (it IS a bounded knob) wins over
        # the routing-axis default
        kw.update({k: jnp.asarray(v, jnp.int32 if k in _INT_KNOBS
                                  else jnp.float32) for k, v in self.cc})
        return dataclasses.replace(p, **kw)


def expand(cc_space: CCSpace = CCSpace(),
           routing_space: RoutingSpace = RoutingSpace()) -> List[Candidate]:
    """Cartesian grid tier: every (routing x CC) combination, validated
    against the knob bounds."""
    out = []
    for r in routing_space.grid():
        for c in cc_space.grid():
            for k, v in c.items():
                check_bounds(k, v)
            out.append(Candidate(policy=r["policy"],
                                 flowlet_gap_s=r["flowlet_gap_s"],
                                 cc=tuple(sorted(c.items()))))
    return out


def default_candidate(name: str = "default") -> Candidate:
    """The fabric's shipped configuration (native policy, stock CC)."""
    return Candidate(name=name)


# --------------------------------------------------------------------------
# Batched execution: (panel cell x candidate x baseline/congested) lanes
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PanelCell:
    """One scoring scenario: a (system, allocation, traffic program,
    congestion profile, vector size) cell every candidate is measured
    on. ``jobs`` swaps the victim/aggressor split for a multi-job mix
    (scenarios._mix_jobs)."""

    name: str
    system: SystemPreset
    n_nodes: int
    victim: str
    aggressor: str
    vector_bytes: float
    profile: cong.Profile
    jobs: tuple = ()


@dataclasses.dataclass
class CellRun:
    """Raw per-(cell, candidate) measurements (score.py derives the
    Pareto metrics from these)."""

    cell: str
    candidate: str
    t_uncongested_s: float
    t_congested_s: float
    ratio: float
    victim_bytes: float  # delivered by victim flows, congested lane
    aggr_bytes: float  # delivered by aggressor/background flows
    sim_time_s: float
    jain: float  # fairness over victim flows' delivered bytes
    # a lane completed zero iterations within the step budget: times and
    # ratio are NaN; score.aggregate excludes the cell (marked DNF)
    # instead of folding NaN into the Pareto axes
    dnf: bool = False
    warmup_ok: bool = True


def _jain(x: np.ndarray) -> float:
    x = np.asarray(x, np.float64)
    x = x[x > 0]
    if len(x) == 0:
        return 1.0
    return float((x.sum() ** 2) / (len(x) * np.sum(x * x)))


def run_candidate_rows(panel: Sequence[PanelCell],
                       cand_rows: Sequence[Sequence[Candidate]], *,
                       n_iters: int = 12, warmup: int = 3,
                       max_steps: int = 200_000, chunk: int = 2048,
                       stride: int = 8, mesh=None,
                       launcher=None) -> List[CellRun]:
    """Per-cell candidate rows in one batched call: ``cand_rows[i]`` is
    the candidate list measured on ``panel[i]``. Rows must share one
    length — the lane axis is rectangular — which is what lets the
    what-if server coalesce *different* queries' (cells x candidates)
    into a single ``run_cells_hetero`` launch (runtime/whatif.py pads
    short rows with repeats). Per-cell lane construction is identical to
    :func:`run_candidates`, so a coalesced run is bit-identical to the
    per-query serial runs it replaces (lanes are independent under vmap;
    bucket padding is inert — tests/test_whatif.py pins it)."""
    if len(cand_rows) != len(panel):
        raise ValueError(f"{len(cand_rows)} candidate rows for "
                         f"{len(panel)} panel cells")
    widths = {len(r) for r in cand_rows}
    if len(widths) != 1:
        raise ValueError(f"candidate rows must share one length, got "
                         f"{sorted(widths)}")
    bench.check_iter_budget(n_iters)
    launcher = bench._resolve_launcher(mesh, launcher, shard_axis="lane")
    # policy_tables: candidates cross-select ECMP/NSLB as traced data,
    # so every panel geometry must carry the full static tables.
    # Fault-scenario cells: one faulted cell anywhere in the panel puts
    # the inert fault table on EVERY lane (params stack across cells),
    # and a node-capped cell arms its case's intra-node stage (the
    # bucket maxes the flag; stage-off cells run it inert at inf).
    with_ft = cong.needs_fault_table([c.profile for c in panel])
    cases = [bench.build_case(c.system, c.n_nodes, c.victim, c.aggressor,
                              jobs=list(c.jobs) or None,
                              policy_tables=True,
                              intra_node=c.profile.node_cap_frac > 0)
             for c in panel]
    dims, stacked = bench.bucket_stack([c.geom for c in cases])
    dts, rows = [], []
    for cell, case, cands in zip(panel, cases, cand_rows):
        dt = bench.choose_dt(case.topo, case.n_victims, cell.vector_bytes,
                             case.lat(), n_phases=case.max_phases)
        dts.append(dt)
        lane = []
        for cand in cands:
            for prof in (cong.no_congestion(), cell.profile):
                p = case.cell_params(cell.vector_bytes, prof, dt,
                                     n_flows=dims.n_flows,
                                     with_fault_table=with_ft)
                lane.append(cand.apply(p, case.policy))
        rows.append(sim.stack_params(lane))
    params = sim.stack_params(rows)
    run = launcher if launcher is not None else sim.run_cells_hetero
    out = run(stacked, params,
              jnp.asarray(n_iters, jnp.int32), chunk=chunk,
              max_chunks=-(-max_steps // chunk),
              stride=stride)
    runs: List[CellRun] = []
    fbytes = np.asarray(out["fbytes"])
    t_all = np.asarray(out["t"])
    for ci, (cell, case, dt) in enumerate(zip(panel, cases, dts)):
        lat = case.lat()
        F = case.geom.n_flows
        vmask = np.asarray(case.is_victim, bool)
        for ki, cand in enumerate(cand_rows[ci]):
            base_i, cong_i = 2 * ki, 2 * ki + 1
            base = sim.summarize(out, n_iters=n_iters, warmup=warmup, dt=dt,
                                 chunk=chunk, stride=stride,
                                 cell=(ci, base_i))
            res = sim.summarize(out, n_iters=n_iters, warmup=warmup, dt=dt,
                                chunk=chunk, stride=stride,
                                cell=(ci, cong_i))
            t_u = bench.mean_iter_time(base, lat)
            t_c = bench.mean_iter_time(res, lat)
            dnf = base.n_done == 0 or res.n_done == 0
            fb = fbytes[ci, cong_i][:F]
            runs.append(CellRun(
                cell=cell.name, candidate=cand.label(),
                t_uncongested_s=t_u, t_congested_s=t_c,
                ratio=float("nan") if dnf
                else (t_u / t_c if t_c > 0 else 0.0),
                victim_bytes=float(fb[vmask].sum()),
                aggr_bytes=float(fb[~vmask].sum()),
                sim_time_s=float(t_all[ci, cong_i]),
                jain=_jain(fb[vmask]),
                dnf=dnf,
                warmup_ok=base.warmup_ok and res.warmup_ok))
    return runs


def run_candidates(panel: Sequence[PanelCell],
                   candidates: Sequence[Candidate], *,
                   n_iters: int = 12, warmup: int = 3,
                   max_steps: int = 200_000, chunk: int = 2048,
                   stride: int = 8, mesh=None,
                   launcher=None) -> List[CellRun]:
    """Score every candidate on every panel cell in one batched call:
    geometries pad into one GeometryDims bucket (routing is traced data,
    so mixed-policy candidates share the compile) and params carry
    (cell, candidate x {baseline, congested}) lanes. The uniform-row
    special case of :func:`run_candidate_rows`.

    ``mesh``/``launcher`` shard the candidate LANES across devices via
    the sweep launcher (launch/sweep.py): panels are typically a handful
    of cells but candidate batches grow with the search space, so the
    lane axis is the one worth splitting. The default per-device
    dispatcher keeps results bit-identical to the single-device call."""
    return run_candidate_rows(panel, [list(candidates)] * len(panel),
                              n_iters=n_iters, warmup=warmup,
                              max_steps=max_steps, chunk=chunk,
                              stride=stride, mesh=mesh, launcher=launcher)


# --------------------------------------------------------------------------
# Shared simulator-backed point scoring (autotune's table tier)
# --------------------------------------------------------------------------


@lru_cache(maxsize=1024)
def _times_table(system_name: str, n_nodes: int, victim: str,
                 aggressor: str, vector_bytes: float, profile: cong.Profile,
                 candidate: Candidate, n_iters: int,
                 warmup: int) -> Tuple[float, float]:
    cell = PanelCell(name="point", system=get_system(system_name),
                     n_nodes=n_nodes, victim=victim, aggressor=aggressor,
                     vector_bytes=float(vector_bytes), profile=profile)
    run = run_candidates([cell], [candidate], n_iters=n_iters,
                         warmup=warmup)[0]
    return run.t_uncongested_s, run.t_congested_s


def simulated_times(system_name: str, n_nodes: int, victim: str,
                    aggressor: str, vector_bytes: float,
                    profile: cong.Profile, *,
                    candidate: Optional[Candidate] = None,
                    n_iters: int = 20, warmup: int = 4
                    ) -> Tuple[float, float]:
    """(t_uncongested, t_congested) for one cell — THE simulator-backed
    scoring path, shared by the mitigation search (a 1-candidate panel)
    and autotune.predict_simulated's lru-cached table tier.

    The lru table behind it is *agent-aware*: it is keyed on the
    candidate as well as the (system, scale, traffic, profile) point, so
    a search agent re-scoring a point it (or any other agent) already
    evaluated hits the table instead of re-tracing and re-running the
    simulator — ``Profile`` and ``Candidate`` are frozen dataclasses of
    hashables, so they key directly. Inspect/clear via
    :func:`simulated_times_cache_info` / ``_times_table.cache_clear``."""
    cand = candidate if candidate is not None else default_candidate()
    return _times_table(system_name, int(n_nodes), victim, aggressor,
                        float(vector_bytes), profile, cand, int(n_iters),
                        int(warmup))


def simulated_times_cache_info():
    """Hit/miss counters of the agent-aware point table (test hook)."""
    return _times_table.cache_info()


def sawtooth_cv(system_name: str, n_nodes: int, coll: str,
                vector_bytes: float, candidate: Candidate, *,
                n_iters: int = 25, dt: float = 20e-6,
                max_steps: int = 200_000) -> float:
    """Coefficient of variation of the steady-state victim goodput trace
    on a self-congestion run (no aggressors) under ``candidate`` — the
    Fig. 3 sawtooth amplitude metric (test_fabric.test_obs1): high CV =
    bang-bang CC oscillation, low CV = damped response."""
    system = get_system(system_name)
    topo = bench.machine_topology(system, n_nodes)
    nodes = bench.allocate(system, n_nodes)
    flows = cong.build_flowset(topo, nodes, [], coll, "", vector_bytes,
                               routing_mode=system.static_routing,
                               k_max=system.k_max)
    geom = sim.make_geometry(topo, flows)
    params = sim.make_params(system.cc, dt=dt,
                             bytes_per_iter=flows.bytes_per_iter,
                             host_caps=flows.host_caps,
                             env=cong.no_congestion().params(),
                             policy=default_policy(system))
    chunk, stride = 2048, 8
    out = sim.run_cell(geom, candidate.apply(params, default_policy(system)),
                       jnp.asarray(n_iters, jnp.int32), chunk=chunk,
                       max_chunks=-(-max_steps // chunk), stride=stride)
    res = sim.summarize(out, n_iters=n_iters, warmup=5, dt=dt, chunk=chunk,
                        stride=stride)
    tr = res.victim_rate_trace
    tr = tr[len(tr) // 3:]
    tr = tr[tr > 0]
    if len(tr) == 0 or tr.mean() == 0:
        return 0.0
    return float(tr.std() / tr.mean())


# --------------------------------------------------------------------------
# Gradient tier: differentiate victim slowdown through the fluid scan
# --------------------------------------------------------------------------

# continuous knobs the gradient tier may descend (ints excluded)
GRAD_KNOBS = tuple(k for k in SEARCH_BOUNDS if k not in _INT_KNOBS)


def _sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def _to_bounds(theta, lo, hi):
    return lo + (hi - lo) * _sigmoid(theta)


def _from_bounds(v, lo, hi):
    frac = np.clip((v - lo) / (hi - lo), 1e-4, 1 - 1e-4)
    return float(np.log(frac / (1 - frac)))


def victim_objective(geom: sim.FabricGeometry, p: sim.SimParams,
                     n_steps: int):
    """Negative mean victim goodput over a fixed-length scan — the
    differentiable surrogate for victim slowdown (no early exit: the
    while_loop runner is not reverse-mode differentiable)."""
    state = sim.init_state(geom, p)
    state, gp = jax.lax.scan(lambda s, _: sim.step(geom, p, s), state,
                             None, length=n_steps)
    return -jnp.mean(gp)


def gradient_refine(geom: sim.FabricGeometry, base: sim.SimParams,
                    knobs: Sequence[str], *, steps: int = 8,
                    lr: float = 0.25, n_steps: int = 800) -> Dict:
    """Descend the selected continuous knobs from ``base`` (projected
    into their bounds via a sigmoid reparameterization) with Adam.
    Returns the best knob values seen and the objective history."""
    knobs = list(knobs)
    for k in knobs:
        if k not in GRAD_KNOBS:
            raise KeyError(f"{k!r} is not a continuous searchable knob")
    bounds = np.array([SEARCH_BOUNDS[k] for k in knobs], np.float64)
    lo = jnp.asarray(bounds[:, 0], jnp.float32)
    hi = jnp.asarray(bounds[:, 1], jnp.float32)
    theta0 = jnp.asarray(
        [_from_bounds(float(getattr(base, k)), *SEARCH_BOUNDS[k])
         for k in knobs], jnp.float32)

    def loss(theta):
        vals = _to_bounds(theta, lo, hi)
        p = dataclasses.replace(base, **{k: vals[i]
                                         for i, k in enumerate(knobs)})
        return victim_objective(geom, p, n_steps)

    grad_fn = jax.jit(jax.value_and_grad(loss))
    m = v = jnp.zeros_like(theta0)
    theta, best_theta = theta0, theta0
    best = float("inf")
    history = []
    for t in range(1, steps + 1):
        val, g = grad_fn(theta)
        val = float(val)
        history.append(val)
        if val < best:
            best, best_theta = val, theta
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** t)
        vh = v / (1 - 0.999 ** t)
        theta = theta - lr * mh / (jnp.sqrt(vh) + 1e-8)
    vals = np.asarray(_to_bounds(best_theta, lo, hi))
    return {"knobs": {k: float(vals[i]) for i, k in enumerate(knobs)},
            "objective": best, "history": history}
