"""Traffic-program IR: collective schedules compiled to phase programs.

The paper's central claim is that congestion impact depends on the
*temporal structure* of traffic, not just its aggregate volume: a ring
AllReduce is 2(n-1) barrier-synchronized neighbor exchanges, a pairwise
AlltoAll is n-1 disjoint pairings, an incast is a serialized fan-in — and
each stresses the fabric differently from a flattened "all flows at once"
blob. This module is the IR between the schedule definitions
(collectives.py) and the fluid simulator:

* A :class:`JobSpec` names one tenant: a node set, a collective kind, a
  vector size, and how its schedule is lowered (``phased`` step-by-step
  vs flattened, optional per-phase compute gap, envelope gating for
  aggressor-style jobs, ``endless`` background loops).
* :func:`compile_phases` lowers one job to a list of :class:`PhaseSpec`
  — each a set of (src, dst, bytes) flows plus a compute-gap duration —
  using the same schedules collectives.py executes on device: ring
  AllGather step k sends shard r-k along the ring, pairwise AlltoAll
  step k pairs rank r with r^k (r+k for non-power-of-two n), incast
  fans in one source per step.
* :func:`compile_programs` packs any number of jobs into one
  :class:`TrafficProgram`: flat per-flow arrays (src, dst, bytes, job id,
  phase id) plus per-job phase tables (phase count, per-phase gaps) with
  fixed shapes, so the whole multi-job mix runs inside one jitted scan
  (simulator.py executes the program; phase advance is barrier-gated on
  the slowest member flow, preserving DESIGN.md §7 straggler semantics).

Every compiled program is validated against the analytic
``collectives.wire_bytes_model``: per-rank bytes summed over phases and
the serialized step count must match the model exactly (:func:`check_program`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.collectives import wire_bytes_model

# Endless background loop (paper §III-A: aggressors loop "endlessly");
# congestion.AGGRESSOR_BYTES re-exports this.
ENDLESS_BYTES = 1e30

# flow_phase sentinel: the flow is a member of EVERY phase of its job
# (uniform schedules — e.g. ring steps reuse the same n neighbor edges —
# store one flow row per edge instead of one per (phase, edge))
WILDCARD_PHASE = -1

# collective kind (congestion.py naming) -> wire_bytes_model kind
WIRE_KIND = {
    "ring_allgather": "ring_all_gather",
    "ring_allreduce": "ring_all_reduce",
    "alltoall": "linear_all_to_all",
    "pairwise_alltoall": "pairwise_all_to_all",
    "incast": "incast",
}


@dataclasses.dataclass(frozen=True)
class PhaseSpec:
    """One schedule step: flows that transmit concurrently, then a
    compute gap before the job's barrier releases the next phase."""

    flows: Tuple[Tuple[int, int, float], ...]  # (src, dst, bytes)
    gap_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One tenant's traffic program, declaratively.

    ``nodes=None`` lets the case builder fill in an interleaved share of
    the allocation (:func:`split_nodes`). ``sweep_bytes`` marks the job's
    bytes as linear in the swept vector size (bench grids); background
    jobs keep their own fixed volume. ``endless`` collapses the schedule
    to a single never-completing phase (the paper's aggressor loop), and
    ``envelope_gated`` subjects injection to the congestion envelope.
    """

    name: str
    collective: str
    vector_bytes: float = 1.0
    nodes: Optional[Tuple[int, ...]] = None
    phased: bool = True
    gap_s: float = 0.0
    envelope_gated: bool = False
    endless: bool = False
    sweep_bytes: bool = True

    def with_nodes(self, nodes) -> "JobSpec":
        return dataclasses.replace(self, nodes=tuple(int(x) for x in nodes))


@dataclasses.dataclass
class TrafficProgram:
    """Packed multi-job flow program (the simulator's static input).

    Flow arrays are flat over every (job, phase, flow); per-job tables
    are padded to the longest program so shapes stay vmap-stable.
    """

    jobs: Tuple[JobSpec, ...]
    src: np.ndarray  # (F,) int32
    dst: np.ndarray  # (F,) int32
    bytes_per_phase: np.ndarray  # (F,) float64
    flow_job: np.ndarray  # (F,) int32
    flow_phase: np.ndarray  # (F,) int32
    n_phases: np.ndarray  # (J,) int32
    phase_gap: np.ndarray  # (J, P_max) float32
    env_gated: np.ndarray  # (J,) bool
    sweep_mask: np.ndarray  # (F,) bool — bytes scale with swept size

    @property
    def n_jobs(self) -> int:
        return len(self.jobs)

    @property
    def n_flows(self) -> int:
        return len(self.src)

    def job_names(self) -> List[str]:
        return [j.name for j in self.jobs]


# --------------------------------------------------------------------------
# Schedule lowering (mirrors collectives.py step for step)
# --------------------------------------------------------------------------


def _flat_flows(nodes: Sequence[int], kind: str,
                v: float) -> List[Tuple[int, int, float]]:
    """Flattened (single-phase) flow set — congestion.collective_flows'
    shapes, kept here so congestion.py can delegate."""
    nodes = list(nodes)
    n = len(nodes)
    if n < 2:
        return []
    out: List[Tuple[int, int, float]] = []
    if kind == "ring_allgather":
        per = v * (n - 1) / n
        out = [(nodes[i], nodes[(i + 1) % n], per) for i in range(n)]
    elif kind == "ring_allreduce":
        per = 2.0 * v * (n - 1) / n
        out = [(nodes[i], nodes[(i + 1) % n], per) for i in range(n)]
    elif kind in ("alltoall", "pairwise_alltoall"):
        per = v / n
        out = [(i, j, per) for i in nodes for j in nodes if i != j]
    elif kind == "incast":
        out = [(i, nodes[0], v) for i in nodes[1:]]
    else:
        raise KeyError(kind)
    return out


def _ring_phases(nodes: Sequence[int], v: float, steps: int) -> List[Tuple]:
    """``steps`` barrier-gated ring exchanges of one V/n shard each
    (AllGather: n-1 steps; AllReduce: 2(n-1) = ReduceScatter + AllGather).
    Step k of the AG half moves the shard of rank r-k to the ring
    neighbor — the shard *identity* rotates but the wire pattern is the
    same n neighbor flows every step, which is exactly what the fluid
    model sees."""
    nodes = list(nodes)
    n = len(nodes)
    per = v / n
    ring = [(nodes[i], nodes[(i + 1) % n], per) for i in range(n)]
    return [tuple(ring) for _ in range(steps)]


def _pairwise_phases(nodes: Sequence[int], v: float) -> List[Tuple]:
    """n-1 phases; phase k pairs rank r with r XOR k when n is a power of
    two (disjoint transpositions — each step is a perfect matching), else
    with r+k mod n (the shifted-exchange schedule of
    collectives.pairwise_all_to_all)."""
    nodes = list(nodes)
    n = len(nodes)
    per = v / n
    phases = []
    pow2 = n & (n - 1) == 0
    for k in range(1, n):
        if pow2:
            flows = [(nodes[i], nodes[i ^ k], per) for i in range(n)]
        else:
            flows = [(nodes[i], nodes[(i + k) % n], per) for i in range(n)]
        phases.append(tuple(flows))
    return phases


def _incast_phases(nodes: Sequence[int], v: float) -> List[Tuple]:
    """Serialized fan-in: one source per phase sends its full vector to
    the root (wire_bytes_model counts incast as n-1 serialized steps)."""
    nodes = list(nodes)
    return [((nodes[k], nodes[0], v),) for k in range(1, len(nodes))]


def compile_phases(kind: str, nodes: Sequence[int], vector_bytes: float,
                   *, phased: bool = True,
                   gap_s: float = 0.0) -> List[PhaseSpec]:
    """Lower one collective to its phase list. ``phased=False`` flattens
    the schedule into a single phase carrying the full per-iteration
    volume (the pre-IR simulator behavior, kept as the baseline shape)."""
    n = len(list(nodes))
    if n < 2:
        return []
    if not phased:
        return [PhaseSpec(tuple(_flat_flows(nodes, kind, vector_bytes)),
                          gap_s)]
    if kind == "ring_allgather":
        phases = _ring_phases(nodes, vector_bytes, n - 1)
    elif kind == "ring_allreduce":
        # 2(n-1) shard-sized steps (ReduceScatter + AllGather); the 2x
        # wire volume comes from the doubled step count, not the shard
        phases = _ring_phases(nodes, vector_bytes, 2 * (n - 1))
    elif kind in ("alltoall", "pairwise_alltoall"):
        phases = _pairwise_phases(nodes, vector_bytes)
    elif kind == "incast":
        phases = _incast_phases(nodes, vector_bytes)
    else:
        raise KeyError(kind)
    return [PhaseSpec(fl, gap_s) for fl in phases]


def compile_job(job: JobSpec) -> List[PhaseSpec]:
    """Lower one job. Endless jobs become a single phase whose flows
    never drain (the paper's aggressor loop); the envelope then shapes
    their injection over time."""
    if job.nodes is None:
        raise ValueError(f"job {job.name!r} has no node assignment")
    if job.endless:
        flows = tuple((s, d, ENDLESS_BYTES)
                      for s, d, _ in _flat_flows(job.nodes, job.collective,
                                                 1.0))
        return [PhaseSpec(flows, 0.0)] if flows else []
    return compile_phases(job.collective, job.nodes, job.vector_bytes,
                          phased=job.phased, gap_s=job.gap_s)


# --------------------------------------------------------------------------
# Packing + validation
# --------------------------------------------------------------------------


def compile_programs(jobs: Sequence[JobSpec],
                     validate: bool = True) -> TrafficProgram:
    """Pack jobs into one flat program (and validate non-endless jobs
    against the analytic wire-byte model)."""
    jobs = tuple(jobs)
    if not jobs:
        raise ValueError("no jobs")
    per_job = [compile_job(j) for j in jobs]
    for job, phases in zip(jobs, per_job):
        if not any(ph.flows for ph in phases):
            raise ValueError(
                f"job {job.name!r} ({job.collective} on "
                f"{len(job.nodes or ())} nodes) lowers to zero flows — "
                "every job needs at least 2 nodes; use a larger "
                "allocation or fewer tenants")
    src, dst, byt, fjob, fphase = [], [], [], [], []
    n_phases = np.ones((len(jobs),), np.int32)
    p_max = max((len(ph) for ph in per_job), default=1) or 1
    phase_gap = np.zeros((len(jobs), p_max), np.float32)
    for ji, phases in enumerate(per_job):
        n_phases[ji] = max(len(phases), 1)
        for pi, phase in enumerate(phases):
            phase_gap[ji, pi] = phase.gap_s
        if len(phases) > 1 and all(ph.flows == phases[0].flows
                                   for ph in phases):
            # uniform schedule (ring steps): one wildcard row per edge,
            # re-armed at every phase entry, instead of n_phases copies
            phases = [PhaseSpec(phases[0].flows)]
            pids = [WILDCARD_PHASE]
        else:
            pids = list(range(len(phases)))
        for pi, phase in zip(pids, phases):
            for (s, d, b) in phase.flows:
                src.append(s)
                dst.append(d)
                byt.append(b)
                fjob.append(ji)
                fphase.append(pi)
    prog = TrafficProgram(
        jobs=jobs,
        src=np.asarray(src, np.int32), dst=np.asarray(dst, np.int32),
        bytes_per_phase=np.asarray(byt, np.float64),
        flow_job=np.asarray(fjob, np.int32),
        flow_phase=np.asarray(fphase, np.int32),
        n_phases=n_phases, phase_gap=phase_gap,
        env_gated=np.array([j.envelope_gated for j in jobs]),
        sweep_mask=np.array([jobs[j].sweep_bytes and not jobs[j].endless
                             for j in fjob], bool)
        if fjob else np.zeros((0,), bool))
    if validate:
        check_program(prog)
    return prog


def job_wire_stats(prog: TrafficProgram, ji: int) -> Dict[str, float]:
    """Observed (max per-rank bytes, serialized steps) for job ``ji``.
    A wildcard flow transmits its bytes once per phase."""
    mask = prog.flow_job == ji
    steps = int(prog.n_phases[ji])
    per_rank: Dict[int, float] = {}
    for s, p, b in zip(prog.src[mask], prog.flow_phase[mask],
                       prog.bytes_per_phase[mask]):
        mult = steps if p == WILDCARD_PHASE else 1
        per_rank[int(s)] = per_rank.get(int(s), 0.0) + float(b) * mult
    return {"bytes": max(per_rank.values(), default=0.0), "steps": steps}


def check_program(prog: TrafficProgram) -> None:
    """Phased programs must conserve the analytic schedule exactly:
    per-rank bytes summed over phases == wire_bytes_model bytes, and the
    phase count == the model's serialized step count."""
    for ji, job in enumerate(prog.jobs):
        if job.endless or job.nodes is None:
            continue
        n = len(job.nodes)
        if n < 2:
            continue
        model = wire_bytes_model(WIRE_KIND[job.collective], n,
                                 job.vector_bytes)
        got = job_wire_stats(prog, ji)
        if not np.isclose(got["bytes"], model["bytes"], rtol=1e-6):
            raise ValueError(
                f"job {job.name!r} ({job.collective}, n={n}): per-rank "
                f"bytes {got['bytes']:.6g} != model {model['bytes']:.6g}")
        want_steps = model["steps"] if job.phased else 1
        if job.collective == "alltoall" and job.phased:
            # phased alltoall uses the pairwise schedule's step count
            want_steps = wire_bytes_model("pairwise_all_to_all", n,
                                          job.vector_bytes)["steps"]
        if got["steps"] != want_steps:
            raise ValueError(
                f"job {job.name!r}: {got['steps']} phases != "
                f"{want_steps} model steps")


# --------------------------------------------------------------------------
# Program padding (scale-batched geometry buckets, DESIGN.md §11)
# --------------------------------------------------------------------------

# Name of the synthetic job that owns padding flow rows. It is
# envelope-gated (so pad flows are never victims) and its flows carry 0
# bytes (so they are never ``alive`` in the simulator).
PAD_JOB_NAME = "_pad"


def pad_rows(x: np.ndarray, n: int, fill) -> np.ndarray:
    """Pad axis 0 of ``x`` to length ``n`` with ``fill``, keeping dtype —
    THE padding idiom (program tables, geometry fields, per-flow params
    all share it; keep one copy so fill/dtype semantics cannot drift)."""
    out = np.full((n,) + x.shape[1:], fill, x.dtype)
    out[: len(x)] = x
    return out


def pad_program(prog: TrafficProgram, *, n_flows: int, n_jobs: int,
                n_phases: int) -> TrafficProgram:
    """Pad a program's flat arrays and job tables to bucket dims.

    Padding flows are (0 -> 0, 0 bytes) rows owned by a synthetic
    :data:`PAD_JOB_NAME` job appended at index ``n_jobs - 1``; padding
    jobs run an empty single-phase program. :func:`check_program` stays
    *exact on the valid prefix*: it iterates ``prog.jobs`` only (padding
    jobs are appended after the real ones) and masks flows by owning job,
    so padded rows can never perturb the wire-byte validation.
    """
    F, J, P = prog.n_flows, len(prog.n_phases), int(prog.phase_gap.shape[1])
    if n_flows < F or n_jobs < J or n_phases < P:
        raise ValueError(f"pad_program: target ({n_flows}, {n_jobs}, "
                         f"{n_phases}) smaller than ({F}, {J}, {P})")
    if n_flows > F and n_jobs == J:
        raise ValueError("padding flows need a padding job to own them: "
                         "grow n_jobs alongside n_flows")

    pad_j = n_jobs - 1  # all pad flows attach to the last pad job
    phase_gap = np.zeros((n_jobs, n_phases), np.float32)
    phase_gap[:J, :P] = prog.phase_gap
    return TrafficProgram(
        jobs=prog.jobs,  # real jobs only: check_program sees the prefix
        src=pad_rows(prog.src, n_flows, 0),
        dst=pad_rows(prog.dst, n_flows, 0),
        bytes_per_phase=pad_rows(prog.bytes_per_phase, n_flows, 0.0),
        flow_job=pad_rows(prog.flow_job, n_flows, pad_j),
        flow_phase=pad_rows(prog.flow_phase, n_flows, 0),
        n_phases=pad_rows(prog.n_phases, n_jobs, 1),
        phase_gap=phase_gap,
        env_gated=pad_rows(prog.env_gated, n_jobs, True),
        sweep_mask=pad_rows(prog.sweep_mask, n_flows, False))


def split_nodes(nodes: Sequence[int],
                jobs: Sequence[JobSpec]) -> List[JobSpec]:
    """Interleave an allocation among jobs missing a node set (paper
    §III-A: round-robin striping maximizes network sharing). Jobs that
    already carry nodes keep them, and their nodes are excluded from the
    striping so tenants never share a NIC by accident."""
    pinned = {int(x) for j in jobs if j.nodes is not None for x in j.nodes}
    avail = np.asarray([int(x) for x in nodes if int(x) not in pinned])
    need = [i for i, j in enumerate(jobs) if j.nodes is None]
    out = list(jobs)
    for slot, ji in enumerate(need):
        out[ji] = jobs[ji].with_nodes(avail[slot::len(need)])
    return out
