"""Parameterized congestion envelopes (paper §III-C/D, extended).

An envelope modulates aggressor injection intensity over simulated time.
Historically this was a host-side callback producing a per-step 0/1 array;
it is now *data*: a fixed-size component table evaluated by a traceable
function of sim time, so envelopes ride through ``jax.jit``/``jax.vmap``
and a sweep over (burst, pause) duty cycles batches into one compile.

An envelope is up to :data:`ENV_COMPONENTS` weighted components, each a row
``[kind, p0, p1, weight, seed]``:

* ``off``     — 0 everywhere (baseline runs).
* ``steady``  — 1 everywhere (§III-C).
* ``bursty``  — square wave, ``p0`` seconds on / ``p1`` seconds off (§III-D).
* ``ramp``    — linear onset 0 -> 1 over ``p0`` seconds, then hold (models
  tenants gradually starting — a congestion onset the paper's square
  profiles cannot express).
* ``random``  — random telegraph: time slots of length ``p0`` are on with
  probability ``p0/(p0+p1)`` via a counter-hash PRNG, so the *mean* duty
  cycle matches the equivalent bursty profile while burst placement is
  irregular (multi-tenant background traffic is not periodic).

Component weights sum the contributions and the result is clipped to
[0, 1]; a mix of components models multi-tenant aggressor blends.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from .fabric.routing import splitmix64_hilo

ENV_OFF = 0
ENV_STEADY = 1
ENV_BURSTY = 2
ENV_RAMP = 3
ENV_RANDOM = 4

ENV_COMPONENTS = 4  # fixed component slots per envelope (vmap-stable shape)

_KIND_IDS = {"off": ENV_OFF, "steady": ENV_STEADY, "bursty": ENV_BURSTY,
             "ramp": ENV_RAMP, "random": ENV_RANDOM}


def envelope_at(env, t):
    """Traceable envelope value at sim time ``t`` (scalar in [0, 1]).

    ``env`` is an (ENV_COMPONENTS, 5) float array of component rows. Written
    in jnp so it lives inside the simulator step under jit/vmap.
    """
    import jax.numpy as jnp

    kind = env[:, 0].astype(jnp.int32)
    p0, p1, w, seed = env[:, 1], env[:, 2], env[:, 3], env[:, 4]
    period = jnp.maximum(p0 + p1, 1e-12)
    slot_len = jnp.maximum(p0, 1e-12)
    on_bursty = ((t % period) < p0).astype(jnp.float32)
    on_ramp = jnp.clip(t / slot_len, 0.0, 1.0)
    slot = jnp.floor(t / slot_len).astype(jnp.uint32)
    # splitmix64 of (seed:32 | slot:32): full-period counter PRNG, every
    # output bit avalanches (replaces a weak LCG-style mix; DESIGN.md §15)
    h_hi, _ = splitmix64_hilo(seed.astype(jnp.uint32), slot, xp=jnp)
    u = ((h_hi >> jnp.uint32(8)) & jnp.uint32(0xFFFFFF)) \
        .astype(jnp.float32) / jnp.float32(0x1000000)
    on_random = (u < p0 / period).astype(jnp.float32)
    val = jnp.select(
        [kind == ENV_STEADY, kind == ENV_BURSTY, kind == ENV_RAMP,
         kind == ENV_RANDOM],
        [jnp.ones_like(on_ramp), on_bursty, on_ramp, on_random],
        jnp.zeros_like(on_ramp))
    return jnp.clip(jnp.sum(w * val), 0.0, 1.0)


def envelope_np(env: np.ndarray, t: np.ndarray) -> np.ndarray:
    """NumPy mirror of :func:`envelope_at`, vectorized over a time array
    (host-side plotting / property tests / legacy callers)."""
    t = np.asarray(t, np.float64)[..., None]  # (..., 1) vs (C,) components
    kind = env[:, 0].astype(np.int64)
    p0, p1, w, seed = env[:, 1], env[:, 2], env[:, 3], env[:, 4]
    period = np.maximum(p0 + p1, 1e-12)
    slot_len = np.maximum(p0, 1e-12)
    on_bursty = ((t % period) < p0).astype(np.float64)
    on_ramp = np.clip(t / slot_len, 0.0, 1.0)
    # mod before the cast: off/steady rows leave slot_len at its 1e-12
    # floor, whose huge quotient would otherwise overflow the uint32 cast
    # (the selected value ignores those rows either way)
    slot = np.mod(np.floor(t / slot_len), 2.0 ** 32).astype(np.uint32)
    seed_u = np.broadcast_to(seed.astype(np.uint32), slot.shape)
    h_hi, _ = splitmix64_hilo(seed_u, slot)
    u = ((h_hi >> np.uint32(8)) & np.uint32(0xFFFFFF)).astype(np.float64) \
        / float(0x1000000)
    on_random = (u < p0 / period).astype(np.float64)
    val = np.select(
        [kind == ENV_STEADY, kind == ENV_BURSTY, kind == ENV_RAMP,
         kind == ENV_RANDOM],
        [np.ones_like(on_ramp), on_bursty, on_ramp, on_random], 0.0)
    return np.clip((w * val).sum(-1), 0.0, 1.0)


# --------------------------------------------------------------------------
# Declarative profile objects
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Profile:
    """A named congestion profile; ``params()`` lowers it to the component
    table the simulator consumes."""

    kind: str  # "off" | "steady" | "bursty" | "ramp" | "random" | "mix"
    burst_s: float = 0.0
    pause_s: float = 0.0
    seed: int = 0
    components: Tuple[Tuple["Profile", float], ...] = ()

    def params(self) -> np.ndarray:
        rows = np.zeros((ENV_COMPONENTS, 5), np.float32)
        comps = self.components if self.kind == "mix" else ((self, 1.0),)
        if len(comps) > ENV_COMPONENTS:
            raise ValueError(
                f"mix of {len(comps)} components exceeds {ENV_COMPONENTS}")
        for i, (prof, w) in enumerate(comps):
            if prof.kind == "mix":
                raise ValueError("nested mixes are not supported")
            rows[i] = (_KIND_IDS[prof.kind], prof.burst_s, prof.pause_s,
                       w, prof.seed)
        return rows

    def envelope(self, t0: float, n: int, dt: float) -> np.ndarray:
        """Sampled envelope values (host side; legacy array interface)."""
        t = t0 + np.arange(n) * dt
        return envelope_np(self.params(), t).astype(np.float32)

    def label(self) -> str:
        if self.kind in ("off", "steady"):
            return self.kind
        if self.kind == "bursty":
            return f"bursty {self.burst_s * 1e3:g}/{self.pause_s * 1e3:g}ms"
        if self.kind == "ramp":
            return f"ramp {self.burst_s * 1e3:g}ms"
        if self.kind == "random":
            return (f"random {self.burst_s * 1e3:g}/"
                    f"{self.pause_s * 1e3:g}ms s{self.seed}")
        parts = ", ".join(f"{w:g}*{p.label()}" for p, w in self.components)
        return f"mix({parts})"


def steady() -> Profile:
    return Profile("steady")


def bursty(burst_s: float, pause_s: float) -> Profile:
    return Profile("bursty", burst_s, pause_s)


def no_congestion() -> Profile:
    return Profile("off")


def ramp(ramp_s: float) -> Profile:
    """Aggressors linearly ramp from idle to full blast over ``ramp_s``."""
    return Profile("ramp", ramp_s)


def random_onoff(burst_s: float, pause_s: float, seed: int = 1) -> Profile:
    """Random telegraph with the same mean duty cycle as bursty(b, p)."""
    return Profile("random", burst_s, pause_s, seed=seed)


def multi_tenant(*weighted: Tuple[Profile, float]) -> Profile:
    """Weighted blend of tenant envelopes (e.g. three bursty tenants with
    different periods and phases sharing the aggressor nodes)."""
    return Profile("mix", components=tuple(weighted))
