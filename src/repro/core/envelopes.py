"""Parameterized congestion envelopes (paper §III-C/D, extended).

An envelope modulates aggressor injection intensity over simulated time.
Historically this was a host-side callback producing a per-step 0/1 array;
it is now *data*: a fixed-size component table evaluated by a traceable
function of sim time, so envelopes ride through ``jax.jit``/``jax.vmap``
and a sweep over (burst, pause) duty cycles batches into one compile.

An envelope is up to :data:`ENV_COMPONENTS` weighted components, each a row
``[kind, p0, p1, weight, seed]``:

* ``off``     — 0 everywhere (baseline runs).
* ``steady``  — 1 everywhere (§III-C).
* ``bursty``  — square wave, ``p0`` seconds on / ``p1`` seconds off (§III-D).
* ``ramp``    — linear onset 0 -> 1 over ``p0`` seconds, then hold (models
  tenants gradually starting — a congestion onset the paper's square
  profiles cannot express).
* ``random``  — random telegraph: time slots of length ``p0`` are on with
  probability ``p0/(p0+p1)`` via a counter-hash PRNG, so the *mean* duty
  cycle matches the equivalent bursty profile while burst placement is
  irregular (multi-tenant background traffic is not periodic).

Component weights sum the contributions and the result is clipped to
[0, 1]; a mix of components models multi-tenant aggressor blends.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from .fabric.routing import splitmix64_hilo

ENV_OFF = 0
ENV_STEADY = 1
ENV_BURSTY = 2
ENV_RAMP = 3
ENV_RANDOM = 4

ENV_COMPONENTS = 4  # fixed component slots per envelope (vmap-stable shape)

_KIND_IDS = {"off": ENV_OFF, "steady": ENV_STEADY, "bursty": ENV_BURSTY,
             "ramp": ENV_RAMP, "random": ENV_RANDOM}


def envelope_at(env, t):
    """Traceable envelope value at sim time ``t`` (scalar in [0, 1]).

    ``env`` is an (ENV_COMPONENTS, 5) float array of component rows. Written
    in jnp so it lives inside the simulator step under jit/vmap.
    """
    import jax.numpy as jnp

    kind = env[:, 0].astype(jnp.int32)
    p0, p1, w, seed = env[:, 1], env[:, 2], env[:, 3], env[:, 4]
    period = jnp.maximum(p0 + p1, 1e-12)
    slot_len = jnp.maximum(p0, 1e-12)
    on_bursty = ((t % period) < p0).astype(jnp.float32)
    on_ramp = jnp.clip(t / slot_len, 0.0, 1.0)
    # mod before the cast: off/steady rows leave slot_len at its 1e-12
    # floor, whose huge quotient would otherwise hit an out-of-range
    # float->uint32 cast (platform-dependent under XLA; the selected
    # value ignores those rows, but the lane must still be well-defined)
    slot = jnp.mod(jnp.floor(t / slot_len), 2.0 ** 32).astype(jnp.uint32)
    # splitmix64 of (seed:32 | slot:32): full-period counter PRNG, every
    # output bit avalanches (replaces a weak LCG-style mix; DESIGN.md §15)
    h_hi, _ = splitmix64_hilo(seed.astype(jnp.uint32), slot, xp=jnp)
    u = ((h_hi >> jnp.uint32(8)) & jnp.uint32(0xFFFFFF)) \
        .astype(jnp.float32) / jnp.float32(0x1000000)
    on_random = (u < p0 / period).astype(jnp.float32)
    val = jnp.select(
        [kind == ENV_STEADY, kind == ENV_BURSTY, kind == ENV_RAMP,
         kind == ENV_RANDOM],
        [jnp.ones_like(on_ramp), on_bursty, on_ramp, on_random],
        jnp.zeros_like(on_ramp))
    return jnp.clip(jnp.sum(w * val), 0.0, 1.0)


def envelope_np(env: np.ndarray, t: np.ndarray) -> np.ndarray:
    """NumPy mirror of :func:`envelope_at`, vectorized over a time array
    (host-side plotting / property tests / legacy callers).

    All per-component arithmetic runs in float32 so slot indices and
    telegraph bins match the traced path *bit-for-bit*, including at
    large ``t`` where a float64 quotient would floor into a different
    slot than the simulator's float32 one.
    """
    t = np.asarray(t, np.float32)[..., None]  # (..., 1) vs (C,) components
    env = np.asarray(env, np.float32)
    kind = env[:, 0].astype(np.int64)
    p0, p1, w, seed = env[:, 1], env[:, 2], env[:, 3], env[:, 4]
    period = np.maximum(p0 + p1, np.float32(1e-12))
    slot_len = np.maximum(p0, np.float32(1e-12))
    on_bursty = ((t % period) < p0).astype(np.float32)
    on_ramp = np.clip(t / slot_len, np.float32(0), np.float32(1))
    # mod before the cast: off/steady rows leave slot_len at its 1e-12
    # floor, whose huge quotient would otherwise overflow the uint32 cast
    # (the selected value ignores those rows either way)
    slot = np.mod(np.floor(t / slot_len),
                  np.float32(2.0 ** 32)).astype(np.uint32)
    seed_u = np.broadcast_to(seed.astype(np.uint32), slot.shape)
    h_hi, _ = splitmix64_hilo(seed_u, slot)
    u = ((h_hi >> np.uint32(8)) & np.uint32(0xFFFFFF)).astype(np.float32) \
        / np.float32(0x1000000)
    on_random = (u < p0 / period).astype(np.float32)
    val = np.select(
        [kind == ENV_STEADY, kind == ENV_BURSTY, kind == ENV_RAMP,
         kind == ENV_RANDOM],
        [np.ones_like(on_ramp), on_bursty, on_ramp, on_random],
        np.float32(0))
    return np.clip((w * val).sum(-1, dtype=np.float32),
                   np.float32(0), np.float32(1))


# --------------------------------------------------------------------------
# Per-link fault envelopes (flapping links, dying optics; DESIGN.md §16)
# --------------------------------------------------------------------------
#
# Where the aggressor envelope above modulates *injection*, a fault table
# modulates per-link *capacity*: a fixed-size table of event rows
# ``[kind, t_start, duration, severity, link_group, seed]`` lowered to a
# multiplicative scale on ``caps_finite`` inside the jitted step. Rows
# target structural link groups (see the GROUP_* ids, stamped onto
# ``FabricGeometry.link_group`` by ``make_geometry``), so one table
# expresses "the hottest link flaps" or "every optic in the fabric ages"
# without touching geometry shapes. An all-``none`` table lowers to an
# exact scale of 1.0 — multiplying by it is bit-identical to the
# no-fault engine (the inertness contract the tests pin).

FAULT_NONE = 0     # inert row
FAULT_OUTAGE = 1   # hard capacity drop inside [t_start, t_start+duration)
FAULT_FLAP = 2     # random telegraph: slots down with prob `severity`
FAULT_DEGRADE = 3  # dying optic: linear decay over `duration`, persists
FAULT_JITTER = 4   # per-slot random capacity wobble inside the window

FAULT_EVENTS = 8   # fixed event slots per table (vmap-stable shape)
FAULT_FIELDS = 6   # [kind, t_start, duration, severity, link_group, seed]

# capacity scale floor: caps_eff divides queue-delay terms, so a fault can
# never lower a link to exactly 0 (2**-10 keeps f32 division well away
# from inf while being ~60 dB down — an unusable but well-defined link)
FAULT_FLOOR = 2.0 ** -10

# telegraph slot length for flap/jitter events. Real optics flap on
# second scales; the engine's iteration timescale is compressed the same
# way the paper's 1000-iteration runs are, so slots are sized to span a
# handful of collective iterations.
FLAP_SLOT_S = 250e-6

# structural link groups (values of FabricGeometry.link_group). Group 0
# is reserved for the sink/padding lanes and never matches an event row.
GROUP_NONE = 0
GROUP_EDGE_UP = 1    # host -> leaf switch (injection edge)
GROUP_EDGE_DOWN = 2  # leaf switch -> host (delivery edge)
GROUP_FABRIC = 3     # switch -> switch
GROUP_HOT = 4        # the single most-traversed link (overrides the above)
# Switch-level group (ROADMAP item 4 follow-up): the busiest switch's
# whole incident link set fails as one unit — a line-card / PSU loss,
# not a single cable. Stamped on a SEPARATE geometry array
# (FabricGeometry.link_sw_group) so promoting a switch can never
# re-label the per-link groups existing event rows target: with no
# GROUP_SWITCH row in a table the extra match is all-False and the
# fault scale is bit-identical to the pre-switch-group engine.
GROUP_SWITCH = 5

_FAULT_IDS = {"none": FAULT_NONE, "outage": FAULT_OUTAGE,
              "flap": FAULT_FLAP, "degrade": FAULT_DEGRADE,
              "jitter": FAULT_JITTER}
_GROUP_LABELS = {GROUP_NONE: "none", GROUP_EDGE_UP: "up",
                 GROUP_EDGE_DOWN: "down", GROUP_FABRIC: "fab",
                 GROUP_HOT: "hot", GROUP_SWITCH: "sw"}


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault-event row; ``severity`` is the fraction of capacity lost
    (outage/degrade), the slot down-probability (flap), or the wobble
    amplitude (jitter)."""

    kind: str  # "outage" | "flap" | "degrade" | "jitter"
    t_start: float
    duration: float
    severity: float
    link_group: int = GROUP_HOT
    seed: int = 1

    def label(self) -> str:
        g = _GROUP_LABELS.get(self.link_group, str(self.link_group))
        return (f"{self.kind}[{g} {self.severity:g} "
                f"@{self.t_start * 1e3:g}+{self.duration * 1e3:g}ms]")


def outage(t_start: float, duration: float, severity: float = 1.0,
           link_group: int = GROUP_HOT, seed: int = 1) -> FaultEvent:
    """Hard capacity loss for the window (severity 1.0 = link down)."""
    return FaultEvent("outage", t_start, duration, severity, link_group, seed)


def flap(t_start: float, duration: float, duty: float = 0.3,
         link_group: int = GROUP_HOT, seed: int = 1) -> FaultEvent:
    """Flapping link: FLAP_SLOT_S slots inside the window go down
    (to FAULT_FLOOR) with probability ``duty`` via the counter PRNG."""
    return FaultEvent("flap", t_start, duration, duty, link_group, seed)


def degrade(t_start: float, duration: float, severity: float = 0.8,
            link_group: int = GROUP_HOT, seed: int = 1) -> FaultEvent:
    """Dying optic: capacity decays linearly to ``1 - severity`` over
    ``duration`` and *stays* degraded afterwards."""
    return FaultEvent("degrade", t_start, duration, severity,
                      link_group, seed)


def jitter(t_start: float, duration: float, severity: float = 0.5,
           link_group: int = GROUP_FABRIC, seed: int = 1) -> FaultEvent:
    """Per-slot uniform capacity wobble in [1-severity, 1] (marginal
    links / thermal throttling) inside the window."""
    return FaultEvent("jitter", t_start, duration, severity,
                      link_group, seed)


def switch_outage(t_start: float, duration: float, severity: float = 1.0,
                  seed: int = 1) -> FaultEvent:
    """The busiest switch loses (a fraction of) EVERY incident link for
    the window — a line-card / PSU failure rather than a single cable.
    Targets GROUP_SWITCH, which matches against the geometry's
    ``link_sw_group`` array (the promoted switch's whole link set)."""
    return FaultEvent("outage", t_start, duration, severity,
                      GROUP_SWITCH, seed)


def fault_table(events=()) -> np.ndarray:
    """Lower events to the fixed (FAULT_EVENTS, FAULT_FIELDS) table the
    step consumes; unused rows are ``none`` (scale 1)."""
    events = tuple(events)
    if len(events) > FAULT_EVENTS:
        raise ValueError(
            f"{len(events)} fault events exceed {FAULT_EVENTS} slots")
    rows = np.zeros((FAULT_EVENTS, FAULT_FIELDS), np.float32)
    for i, e in enumerate(events):
        rows[i] = (_FAULT_IDS[e.kind], e.t_start, e.duration, e.severity,
                   e.link_group, e.seed)
    return rows


def no_fault_table() -> np.ndarray:
    """The all-``none`` table: multiplying caps by its scale (exactly 1.0)
    is bit-identical to running without a table. Grids force it onto
    lanes without faults so every lane shares one pytree structure."""
    return fault_table(())


def fault_scale_at(fault, link_group, t, link_sw_group=None):
    """Traceable per-link capacity scale at sim time ``t``.

    ``fault`` is a (FAULT_EVENTS, FAULT_FIELDS) float array and
    ``link_group`` the geometry's (L+1,) group ids; returns an (L+1,)
    float32 scale in [FAULT_FLOOR, 1]. Rows multiply, so overlapping
    events compound. Evaluated in the jitted step *outside* the kernel
    launch — the scaled caps ride in as a plain operand.

    ``link_sw_group`` is the optional second structural channel
    (GROUP_SWITCH on the promoted switch's incident links, 0 elsewhere):
    a row matches a link through EITHER array. With no GROUP_SWITCH row
    in the table the second match is all-False and the result is
    bit-identical to the single-channel scale (the unused-guard contract
    tests/test_faults.py pins).
    """
    import jax.numpy as jnp

    kind = fault[:, 0].astype(jnp.int32)
    t0, dur, sev = fault[:, 1], fault[:, 2], fault[:, 3]
    grp = fault[:, 4].astype(jnp.int32)
    seed = fault[:, 5]
    rel = t - t0
    in_win = (rel >= 0.0) & (rel < dur)
    # telegraph slot hash, shared by flap and jitter. Same mod-before-cast
    # guard as envelope_at, plus a clamp to rel >= 0: a negative quotient
    # mod 2**32 can *round up to exactly 2**32* in f32 (2**32 - small is
    # not representable), recreating the out-of-range cast
    slot = jnp.mod(jnp.floor(jnp.maximum(rel, 0.0)
                             / jnp.float32(FLAP_SLOT_S)),
                   2.0 ** 32).astype(jnp.uint32)
    h_hi, _ = splitmix64_hilo(seed.astype(jnp.uint32), slot, xp=jnp)
    u = ((h_hi >> jnp.uint32(8)) & jnp.uint32(0xFFFFFF)) \
        .astype(jnp.float32) / jnp.float32(0x1000000)
    s_outage = jnp.where(in_win, 1.0 - sev, 1.0)
    s_flap = jnp.where(in_win & (u < sev), 0.0, 1.0)
    s_degrade = jnp.where(
        rel >= 0.0,
        1.0 - sev * jnp.clip(rel / jnp.maximum(dur, 1e-9), 0.0, 1.0), 1.0)
    s_jitter = jnp.where(in_win, 1.0 - sev * u, 1.0)
    s = jnp.select(
        [kind == FAULT_OUTAGE, kind == FAULT_FLAP, kind == FAULT_DEGRADE,
         kind == FAULT_JITTER],
        [s_outage, s_flap, s_degrade, s_jitter], jnp.ones_like(sev))
    s = jnp.maximum(s, jnp.float32(FAULT_FLOOR))
    lg = link_group.astype(jnp.int32)
    match = (grp[:, None] == lg[None, :]) & (kind[:, None] != FAULT_NONE) \
        & (lg[None, :] != GROUP_NONE)
    if link_sw_group is not None:
        sg = link_sw_group.astype(jnp.int32)
        match = match | ((grp[:, None] == sg[None, :])
                         & (kind[:, None] != FAULT_NONE)
                         & (sg[None, :] != GROUP_NONE))
    return jnp.prod(jnp.where(match, s[:, None], jnp.float32(1.0)), axis=0)


def fault_scale_np(fault: np.ndarray, link_group: np.ndarray,
                   t: float, link_sw_group=None) -> np.ndarray:
    """NumPy mirror of :func:`fault_scale_at` at one scalar time (float32
    arithmetic throughout, bit-matching the traced path)."""
    fault = np.asarray(fault, np.float32)
    link_group = np.asarray(link_group, np.int32)
    kind = fault[:, 0].astype(np.int32)
    t0, dur, sev = fault[:, 1], fault[:, 2], fault[:, 3]
    grp = fault[:, 4].astype(np.int32)
    rel = np.float32(t) - t0
    in_win = (rel >= 0) & (rel < dur)
    slot = np.mod(np.floor(np.maximum(rel, np.float32(0))
                           / np.float32(FLAP_SLOT_S)),
                  np.float32(2.0 ** 32)).astype(np.uint32)
    h_hi, _ = splitmix64_hilo(fault[:, 5].astype(np.uint32), slot)
    u = ((h_hi >> np.uint32(8)) & np.uint32(0xFFFFFF)).astype(np.float32) \
        / np.float32(0x1000000)
    one = np.float32(1)
    s_outage = np.where(in_win, one - sev, one)
    s_flap = np.where(in_win & (u < sev), np.float32(0), one)
    s_degrade = np.where(
        rel >= 0,
        one - sev * np.clip(rel / np.maximum(dur, np.float32(1e-9)),
                            np.float32(0), one), one)
    s_jitter = np.where(in_win, one - sev * u, one)
    s = np.select([kind == FAULT_OUTAGE, kind == FAULT_FLAP,
                   kind == FAULT_DEGRADE, kind == FAULT_JITTER],
                  [s_outage, s_flap, s_degrade, s_jitter], one)
    s = np.maximum(s, np.float32(FAULT_FLOOR)).astype(np.float32)
    match = (grp[:, None] == link_group[None, :]) \
        & (kind[:, None] != FAULT_NONE) & (link_group[None, :] != GROUP_NONE)
    if link_sw_group is not None:
        sg = np.asarray(link_sw_group, np.int32)
        match = match | ((grp[:, None] == sg[None, :])
                         & (kind[:, None] != FAULT_NONE)
                         & (sg[None, :] != GROUP_NONE))
    return np.prod(np.where(match, s[:, None], one),
                   axis=0, dtype=np.float32)


# --------------------------------------------------------------------------
# Declarative profile objects
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Profile:
    """A named congestion profile; ``params()`` lowers it to the component
    table the simulator consumes."""

    kind: str  # "off" | "steady" | "bursty" | "ramp" | "random" | "mix"
    burst_s: float = 0.0
    pause_s: float = 0.0
    seed: int = 0
    components: Tuple[Tuple["Profile", float], ...] = ()
    # link-fault events riding on this lane (lowered separately via
    # fault_params — they scale link capacity, not aggressor injection)
    faults: Tuple[FaultEvent, ...] = ()
    # intra-node stage capacity as a fraction of the NIC rate; 0 = stage
    # inert (node_cap lowers to +inf)
    node_cap_frac: float = 0.0

    def params(self) -> np.ndarray:
        rows = np.zeros((ENV_COMPONENTS, 5), np.float32)
        if self.kind == "mix":
            if not self.components:
                raise ValueError(
                    "mix profile with zero components would silently "
                    "lower to an all-off table; use no_congestion() for "
                    "an intentionally idle aggressor")
            comps = self.components
        else:
            comps = ((self, 1.0),)
        if len(comps) > ENV_COMPONENTS:
            raise ValueError(
                f"mix of {len(comps)} components exceeds {ENV_COMPONENTS}")
        for i, (prof, w) in enumerate(comps):
            if prof.kind == "mix":
                raise ValueError("nested mixes are not supported")
            rows[i] = (_KIND_IDS[prof.kind], prof.burst_s, prof.pause_s,
                       w, prof.seed)
        return rows

    def fault_params(self):
        """(FAULT_EVENTS, FAULT_FIELDS) table, or None when the profile
        carries no fault events (keeps the legacy no-fault trace)."""
        return fault_table(self.faults) if self.faults else None

    def envelope(self, t0: float, n: int, dt: float) -> np.ndarray:
        """Sampled envelope values (host side; legacy array interface)."""
        t = t0 + np.arange(n) * dt
        return envelope_np(self.params(), t).astype(np.float32)

    def _base_label(self) -> str:
        if self.kind in ("off", "steady"):
            return self.kind
        if self.kind == "bursty":
            base = f"bursty {self.burst_s * 1e3:g}/{self.pause_s * 1e3:g}ms"
            # degenerate duty cycles render honestly: burst 0 is off,
            # pause 0 is steady-on, not a plausible-looking square wave
            if self.burst_s <= 0:
                base += "(=off)"
            elif self.pause_s <= 0:
                base += "(=on)"
            return base
        if self.kind == "ramp":
            base = f"ramp {self.burst_s * 1e3:g}ms"
            return base + ("(=step)" if self.burst_s <= 0 else "")
        if self.kind == "random":
            base = (f"random {self.burst_s * 1e3:g}/"
                    f"{self.pause_s * 1e3:g}ms s{self.seed}")
            if self.burst_s <= 0:
                base += "(=off)"
            elif self.pause_s <= 0:
                base += "(=on)"
            return base
        parts = ", ".join(f"{w:g}*{p.label()}" for p, w in self.components)
        if self.components and not any(w for _, w in self.components):
            return f"mix({parts})(=off)"
        return f"mix({parts})"

    def label(self) -> str:
        out = self._base_label()
        if self.faults:
            out += "+" + ",".join(e.label() for e in self.faults)
        if self.node_cap_frac > 0:
            out += f"+node{self.node_cap_frac:g}x"
        return out


def steady() -> Profile:
    return Profile("steady")


def bursty(burst_s: float, pause_s: float) -> Profile:
    return Profile("bursty", burst_s, pause_s)


def no_congestion() -> Profile:
    return Profile("off")


def ramp(ramp_s: float) -> Profile:
    """Aggressors linearly ramp from idle to full blast over ``ramp_s``."""
    return Profile("ramp", ramp_s)


def random_onoff(burst_s: float, pause_s: float, seed: int = 1) -> Profile:
    """Random telegraph with the same mean duty cycle as bursty(b, p)."""
    return Profile("random", burst_s, pause_s, seed=seed)


def multi_tenant(*weighted: Tuple[Profile, float]) -> Profile:
    """Weighted blend of tenant envelopes (e.g. three bursty tenants with
    different periods and phases sharing the aggressor nodes)."""
    return Profile("mix", components=tuple(weighted))


def with_faults(profile: Profile, *events: FaultEvent) -> Profile:
    """The profile with link-fault events appended to its lane."""
    return dataclasses.replace(profile,
                               faults=tuple(profile.faults) + tuple(events))


def with_node_cap(profile: Profile, frac: float) -> Profile:
    """The profile with the intra-node stage armed at ``frac`` x the NIC
    rate (NVLink/PCIe contention ahead of the NIC; DESIGN.md §16)."""
    return dataclasses.replace(profile, node_cap_frac=float(frac))


def needs_fault_table(profiles) -> bool:
    """True when any lane of a grid carries fault events — then *every*
    lane must carry a table (the inert one if need be) so stacked
    SimParams share one pytree structure."""
    return any(p.faults for p in profiles)
