"""Congestion-injection harness (paper §III) on the traffic-program IR.

Implements the paper's methodology exactly:
  * interleaved victim/aggressor node split (§III-A): node 0 -> victims,
    node 1 -> aggressors, node 2 -> victims, ... "maximizing network
    resource sharing and, thus, congestion";
  * aggressor patterns: AlltoAll (intermediate-switch stress) and Incast
    (edge stress), run in an endless loop;
  * congestion profiles: steady (§III-C) and bursty (§III-D) with
    configurable (burst length, inter-burst pause) — the duty cycle —
    plus the extended traceable envelope families (ramp onset, random
    telegraph, multi-tenant mixes) defined in envelopes.py.

Every experiment is a *program* of jobs (traffic.JobSpec): the paper's
victim/aggressor setup is the two-job special case (a flattened victim
plus an endless envelope-gated aggressor), and the same builder packs
arbitrary multi-job mixes — phased collectives, two training tenants,
N-tenant fair-share — into one FlowSet executed inside the jitted scan.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.core import traffic
from repro.core.collectives import wire_bytes_model
# Re-exported envelope layer (traceable profiles live in envelopes.py so
# the simulator can import them without a cycle).
from repro.core.envelopes import (ENV_COMPONENTS, FAULT_EVENTS,  # noqa: F401
                                  FAULT_FIELDS, GROUP_EDGE_DOWN,
                                  GROUP_EDGE_UP, GROUP_FABRIC, GROUP_HOT,
                                  GROUP_SWITCH, FaultEvent, Profile, bursty,
                                  degrade, envelope_at, envelope_np,
                                  fault_scale_at, fault_scale_np, fault_table,
                                  flap, jitter, multi_tenant,
                                  needs_fault_table, no_congestion,
                                  no_fault_table, outage, ramp, random_onoff,
                                  steady, switch_outage, with_faults,
                                  with_node_cap)
from repro.core.fabric.routing import assign_paths
from repro.core.fabric.simulator import FlowSet, pack_paths
from repro.core.fabric.topology import Topology
from repro.core.traffic import JobSpec  # noqa: F401  (re-export)


def interleaved_split(n_nodes: int) -> Tuple[np.ndarray, np.ndarray]:
    """Paper §III-A: alternate nodes between victims and aggressors."""
    ids = np.arange(n_nodes)
    return ids[ids % 2 == 0], ids[ids % 2 == 1]


# --------------------------------------------------------------------------
# Flow construction for victim/aggressor collectives
# --------------------------------------------------------------------------


def collective_flows(nodes: Sequence[int], kind: str,
                     vector_bytes: float) -> List[Tuple[int, int, float]]:
    """(src, dst, bytes_per_iteration) triples for one flattened
    collective (the traffic IR's single-phase lowering).

    Matches the paper's custom algorithms: ring AllGather (each rank streams
    (n-1)/n of the vector along the ring), linear AlltoAll (all pairs, V/n
    each), ring AllReduce (2x ring traffic), Incast (everyone -> one node).
    """
    return traffic._flat_flows(nodes, kind, vector_bytes)


AGGRESSOR_BYTES = traffic.ENDLESS_BYTES  # endless loop (paper §III-A)


def build_program_flowset(topo: Topology, jobs: Sequence[traffic.JobSpec],
                          routing_mode: str = "deterministic",
                          k_max: int = 4, seed: int = 0,
                          validate: bool = True,
                          pad_to: Tuple[int, int, int] = None,
                          policy_tables: bool = False) -> FlowSet:
    """Compile a multi-job traffic program and bind it to a topology:
    per-flow paths, NIC caps, and the packed phase tables the simulator
    executes. One FlowSet = one geometry = one JIT entry for every cell
    of a sweep over this program.

    ``pad_to=(n_flows, n_jobs, n_phases)`` pads the program to bucket
    dims (traffic.pad_program) so flow sets of different node counts
    share one array shape; padding rows are inert by construction
    (0-byte flows of an envelope-gated pad job). Validation runs on the
    real prefix either way."""
    prog = traffic.compile_programs(jobs, validate=validate)
    if pad_to is not None:
        prog = traffic.pad_program(prog, n_flows=pad_to[0],
                                   n_jobs=pad_to[1], n_phases=pad_to[2])
        if validate:
            traffic.check_program(prog)  # still exact on the valid prefix
    return bind_program(topo, prog, routing_mode=routing_mode, k_max=k_max,
                        seed=seed, policy_tables=policy_tables)


def bind_program(topo: Topology, prog: traffic.TrafficProgram,
                 routing_mode: str = "deterministic", k_max: int = 4,
                 seed: int = 0, policy_tables: bool = False) -> FlowSet:
    """Bind an already-compiled (possibly hand-assembled) TrafficProgram
    to a topology — the binding half of :func:`build_program_flowset`,
    exposed so callers that assemble programs outside the JobSpec
    compiler (core/workload.py's stochastic short-flow rows) reuse the
    exact same path/NIC/routing lowering."""
    src_dst = [(int(s), int(d)) for s, d in zip(prog.src, prog.dst)]
    paths_per_flow = [topo.paths(s, d) for s, d in src_dst]
    sink = len(topo.caps)
    paths, n_paths, plen = pack_paths(paths_per_flow, sink, k_max)
    is_victim = ~prog.env_gated[prog.flow_job] if prog.n_flows \
        else np.zeros((0,), bool)
    choice = assign_paths(routing_mode, src_dst, paths_per_flow,
                          len(topo.caps), seed)
    # ``policy_tables=True`` additionally computes every static table a
    # traced routing policy may read (POLICY_ECMP / POLICY_NSLB are
    # per-cell data — mitigation/search sweeps them on ONE geometry);
    # the mode the caller asked for is reused verbatim so legacy
    # fixed_choice and its traced twin stay bit-identical. Off by
    # default: the NSLB greedy is O(F*K*hops) host-side Python, and the
    # non-mitigation paths only ever dispatch the policy matching
    # fixed_choice (FlowSet falls back to it), so sweeps that never
    # cross-select a policy skip the cost.
    alt = {routing_mode: choice}
    if policy_tables:
        for mode in ("ecmp", "nslb"):
            if mode not in alt:
                alt[mode] = assign_paths(mode, src_dst, paths_per_flow,
                                         len(topo.caps), seed)
    # injection-link capacity per flow (the host's NIC rate)
    host_caps = np.array(
        [topo.caps[p[0][0]] if p and p[0] else topo.caps.max()
         for p in paths_per_flow])
    src_id = np.array([s for s, _ in src_dst], np.int32)
    return FlowSet(paths=paths, n_paths=n_paths, path_len=plen,
                   is_victim=is_victim,
                   bytes_per_iter=prog.bytes_per_phase,
                   fixed_choice=choice, host_caps=host_caps, src_id=src_id,
                   ecmp_choice=alt.get("ecmp"), nslb_choice=alt.get("nslb"),
                   flow_job=prog.flow_job, flow_phase=prog.flow_phase,
                   n_phases=prog.n_phases, phase_gap=prog.phase_gap,
                   sweep_mask=prog.sweep_mask, job_names=prog.job_names())


def build_flowset(topo: Topology, victim_nodes, aggressor_nodes,
                  victim_coll: str, aggr_coll: str, vector_bytes: float,
                  routing_mode: str = "deterministic",
                  k_max: int = 4, seed: int = 0,
                  phased: bool = False,
                  policy_tables: bool = False) -> FlowSet:
    """The paper's two-job program: one victim collective (flattened by
    default; ``phased=True`` lowers its step schedule) plus an endless
    envelope-gated aggressor on the interleaved node split."""
    jobs = [traffic.JobSpec("victim", victim_coll, vector_bytes,
                            nodes=tuple(int(x) for x in victim_nodes),
                            phased=phased)]
    if aggr_coll and len(aggressor_nodes) >= 2:
        jobs.append(traffic.JobSpec(
            "aggressor", aggr_coll,
            nodes=tuple(int(x) for x in aggressor_nodes),
            endless=True, envelope_gated=True, sweep_bytes=False))
    return build_program_flowset(topo, jobs, routing_mode=routing_mode,
                                 k_max=k_max, seed=seed,
                                 policy_tables=policy_tables)


def latency_model(kind: str, n: int, per_step_s: float = 2e-6) -> float:
    """Fixed per-iteration latency: serialized schedule steps x per-msg lat."""
    steps = wire_bytes_model(traffic.WIRE_KIND[kind], n, 1.0)["steps"]
    return steps * per_step_s
