"""Congestion-injection harness (paper §III).

Implements the paper's methodology exactly:
  * interleaved victim/aggressor node split (§III-A): node 0 -> victims,
    node 1 -> aggressors, node 2 -> victims, ... "maximizing network
    resource sharing and, thus, congestion";
  * aggressor patterns: AlltoAll (intermediate-switch stress) and Incast
    (edge stress), run in an endless loop;
  * congestion profiles: steady (§III-C) and bursty (§III-D) with
    configurable (burst length, inter-burst pause) — the duty cycle —
    plus the extended traceable envelope families (ramp onset, random
    telegraph, multi-tenant mixes) defined in envelopes.py.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.core.collectives import wire_bytes_model
# Re-exported envelope layer (traceable profiles live in envelopes.py so
# the simulator can import them without a cycle).
from repro.core.envelopes import (ENV_COMPONENTS, Profile, bursty,  # noqa: F401
                                  envelope_at, envelope_np, multi_tenant,
                                  no_congestion, ramp, random_onoff, steady)
from repro.core.fabric.routing import assign_paths
from repro.core.fabric.simulator import FlowSet, pack_paths
from repro.core.fabric.topology import Topology


def interleaved_split(n_nodes: int) -> Tuple[np.ndarray, np.ndarray]:
    """Paper §III-A: alternate nodes between victims and aggressors."""
    ids = np.arange(n_nodes)
    return ids[ids % 2 == 0], ids[ids % 2 == 1]


# --------------------------------------------------------------------------
# Flow construction for victim/aggressor collectives
# --------------------------------------------------------------------------


def collective_flows(nodes: Sequence[int], kind: str,
                     vector_bytes: float) -> List[Tuple[int, int, float]]:
    """(src, dst, bytes_per_iteration) triples for one collective.

    Matches the paper's custom algorithms: ring AllGather (each rank streams
    (n-1)/n of the vector along the ring), linear AlltoAll (all pairs, V/n
    each), ring AllReduce (2x ring traffic), Incast (everyone -> one node).
    """
    nodes = list(nodes)
    n = len(nodes)
    if n < 2:
        return []
    out = []
    if kind == "ring_allgather":
        per = vector_bytes * (n - 1) / n
        for i in range(n):
            out.append((nodes[i], nodes[(i + 1) % n], per))
    elif kind == "ring_allreduce":
        per = 2.0 * vector_bytes * (n - 1) / n
        for i in range(n):
            out.append((nodes[i], nodes[(i + 1) % n], per))
    elif kind == "alltoall":
        per = vector_bytes / n
        for i in nodes:
            for j in nodes:
                if i != j:
                    out.append((i, j, per))
    elif kind == "incast":
        root = nodes[0]
        for i in nodes[1:]:
            out.append((i, root, vector_bytes))
    else:
        raise KeyError(kind)
    return out


AGGRESSOR_BYTES = 1e30  # endless loop (paper §III-A)


def build_flowset(topo: Topology, victim_nodes, aggressor_nodes,
                  victim_coll: str, aggr_coll: str, vector_bytes: float,
                  routing_mode: str = "deterministic",
                  k_max: int = 4, seed: int = 0) -> FlowSet:
    vflows = collective_flows(victim_nodes, victim_coll, vector_bytes)
    aflows = (collective_flows(aggressor_nodes, aggr_coll, 1.0)
              if aggr_coll else [])
    src_dst = [(s, d) for s, d, _ in vflows + aflows]
    paths_per_flow = [topo.paths(s, d) for s, d in src_dst]
    sink = len(topo.caps)
    paths, n_paths, plen = pack_paths(paths_per_flow, sink, k_max)
    is_victim = np.array([True] * len(vflows) + [False] * len(aflows))
    bpi = np.array([b for _, _, b in vflows]
                   + [AGGRESSOR_BYTES] * len(aflows), np.float64)
    choice = assign_paths(routing_mode, src_dst, paths_per_flow,
                          len(topo.caps), seed)
    # injection-link capacity per flow (the host's NIC rate)
    host_caps = np.array(
        [topo.caps[p[0][0]] if p and p[0] else topo.caps.max()
         for p in paths_per_flow])
    src_id = np.array([s for s, _ in src_dst], np.int32)
    return FlowSet(paths=paths, n_paths=n_paths, path_len=plen,
                   is_victim=is_victim, bytes_per_iter=bpi,
                   fixed_choice=choice, host_caps=host_caps, src_id=src_id)


def latency_model(kind: str, n: int, per_step_s: float = 2e-6) -> float:
    """Fixed per-iteration latency: serialized schedule steps x per-msg lat."""
    steps = wire_bytes_model({
        "ring_allgather": "ring_all_gather",
        "ring_allreduce": "ring_all_reduce",
        "alltoall": "linear_all_to_all",
        "incast": "incast",
    }[kind], n, 1.0)["steps"]
    return steps * per_step_s
