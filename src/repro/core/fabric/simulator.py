"""JAX fluid flow-level fabric simulator — pure-functional core.

Multi-job flow *programs* (traffic.py) traverse a :class:`Topology` under
a congestion-control model (cc.py) and a routing policy. The inner loop
is a ``jax.lax.scan`` over fixed-dt timesteps:

  1. injection demand from per-flow CC rate limits, gated by phase
     membership (a flow transmits only while its job is in its phase),
  2. per-flow path choice by the cell's *traced* routing policy — a
     ``lax.switch`` over SimParams.policy (fixed / ECMP / NSLB tables,
     adaptive min-queue, flowlet re-pathing), so cells with different
     routing policies batch in one compile (mitigation lab),
  3. staged feed-forward propagation (FIFO fluid sharing per hop),
  4. queue integration (offered load vs capacity) + ECN/credit signals,
  5. CC rate update per fabric model + optional backpressure spreading,
  6. per-job phase advance — barrier-gated on the slowest member flow
     (DESIGN.md §7 straggler semantics) plus an optional compute gap —
     and program-completion bookkeeping (a job wrapping its last phase
     is one iteration of the paper's 1000-iteration protocol, scaled:
     see bench.py).

The engine is split into two pytrees:

* :class:`FabricGeometry` — the static structure of one experiment (packed
  paths, link capacities, switch adjacency). Constant across a parameter
  sweep; its array shapes key the JIT cache.
* :class:`SimParams` — everything a sweep varies: CC scalars, ``dt``,
  per-flow bytes targets, and the congestion-envelope parameters. All
  leaves are traced, so a grid of cells batches under ``jax.vmap`` with a
  single compile (bench.run_grid).

CC kind and the congestion envelope are *data*: the per-kind update is a
``lax.switch`` over branch functions and the aggressor envelope is a
traceable function of sim time (congestion.envelope_at), so cells with
different fabrics and different burst/pause duty cycles coexist in one
batched call. Approximations are documented in DESIGN.md; the validation
targets are the paper's observed *behaviors* (sawtooth, NSLB flat-line,
incast collapse, duty-cycle sensitivity), which emerge from the mechanisms,
not from fitting.
"""
from __future__ import annotations

import collections
import dataclasses
import os
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fabric.cc import (CCParams, KIND_AI_ECN, KIND_DCQCN, KIND_IB,
                                  KIND_SLINGSHOT, ROUTE_ADAPTIVE, ROUTE_FIXED)
from repro.core.fabric.routing import (POLICY_ADAPTIVE, POLICY_ECMP,
                                       POLICY_FIXED, POLICY_FLOWLET,
                                       POLICY_NSLB)
from repro.core.fabric.topology import Topology
from repro.core.envelopes import (ENV_COMPONENTS, GROUP_EDGE_DOWN,
                                  GROUP_EDGE_UP, GROUP_FABRIC, GROUP_HOT,
                                  GROUP_SWITCH, envelope_at, fault_scale_at,
                                  no_congestion)
from repro.core.traffic import pad_rows
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref

# Fixed iteration-time buffer: n_iters is traced (no recompile across
# protocols); completed iterations beyond the buffer fold into the last slot.
TDONE_SLOTS = 96
_TDONE_ARANGE = np.arange(TDONE_SLOTS)  # hoisted iteration-slot ids

# ---------------------------------------------------------------------------
# Step-core backend: the memory-bound scatter core of each step (NIC limit,
# backpressure segment-sums, H-hop propagation, queue update) is extracted
# into repro.kernels — ``ref`` is the pure-jnp oracle (the original lax
# code, the default off-TPU), ``pallas`` the fused kernel
# (kernels/fabric_step.py, DESIGN.md §13). Resolution order: explicit
# ``backend=`` argument > set_step_backend() > $REPRO_FABRIC_KERNEL > auto
# (pallas on TPU, ref elsewhere). The public entries resolve EAGERLY in a
# thin Python wrapper and pass the resolved name as a static jit argument,
# so switching backends never serves stale compiles.
STEP_BACKENDS = ("auto", "ref", "pallas")
_step_backend_override: Optional[str] = None


def set_step_backend(backend: Optional[str]) -> None:
    """Process-wide step-core backend override ('auto' | 'ref' |
    'pallas'); None restores env-var/auto resolution."""
    global _step_backend_override
    if backend is not None and backend not in STEP_BACKENDS:
        raise ValueError(f"unknown step backend {backend!r}; "
                         f"expected one of {STEP_BACKENDS}")
    _step_backend_override = backend


def resolve_step_backend(backend: Optional[str] = None) -> str:
    """Resolve to a concrete backend name ('ref' or 'pallas')."""
    b = backend or _step_backend_override \
        or os.environ.get("REPRO_FABRIC_KERNEL", "auto")
    if b not in STEP_BACKENDS:
        raise ValueError(f"unknown step backend {b!r}; "
                         f"expected one of {STEP_BACKENDS}")
    if b == "auto":
        b = "pallas" if jax.default_backend() == "tpu" else "ref"
    return b

# How often each jitted engine entry has been TRACED (== compiled) since
# import. Python side effects run only while tracing, so the increments
# below fire once per compile; tests assert a whole scale sweep costs at
# most one compile per geometry bucket (DESIGN.md §11).
TRACE_COUNTS: collections.Counter = collections.Counter()


def trace_count(entry: str = None) -> int:
    """Total traces of one engine entry (or all entries)."""
    if entry is None:
        return sum(TRACE_COUNTS.values())
    return TRACE_COUNTS[entry]


def check_iter_budget(n_iters: int) -> None:
    if n_iters > TDONE_SLOTS:
        raise ValueError(
            f"n_iters={n_iters} exceeds the {TDONE_SLOTS}-slot iteration "
            "buffer (raise TDONE_SLOTS or lower n_iters)")


# ---------------------------------------------------------------------------
# Persistent compilation cache: reruns of the engine skip XLA compilation
# entirely (the jaxpr trace still runs, but it is milliseconds next to the
# multi-second XLA compile of the chunked while_loop). Enabled either
# explicitly (launch.sweep / launch.dryrun) or ambiently via
# $REPRO_COMPILE_CACHE_DIR, which every public engine entry checks lazily.
# ---------------------------------------------------------------------------

_COMPILE_CACHE_DIR: Optional[str] = None
COMPILE_CACHE_ENV = "REPRO_COMPILE_CACHE_DIR"


def ensure_compile_cache(cache_dir: Optional[str] = None, *,
                         min_compile_secs: float = 0.0) -> Optional[str]:
    """Point XLA's persistent compilation cache at ``cache_dir`` (or
    ``$REPRO_COMPILE_CACHE_DIR``). Idempotent and cheap once configured;
    returns the active cache dir, or None when neither source names one.
    ``min_entry_size_bytes=-1`` caches every entry regardless of size —
    on CPU the engine executables are small but cost seconds to build."""
    global _COMPILE_CACHE_DIR
    if _COMPILE_CACHE_DIR is not None:
        # first activation wins: a process-wide cache must not silently
        # re-point mid-run (half the entries would land elsewhere)
        return _COMPILE_CACHE_DIR
    cache_dir = cache_dir or os.environ.get(COMPILE_CACHE_ENV)
    if not cache_dir:
        return None
    cache_dir = os.path.abspath(cache_dir)
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs",
                      float(min_compile_secs))
    try:
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except AttributeError:  # pragma: no cover - older jax without the knob
        pass
    _COMPILE_CACHE_DIR = cache_dir
    return cache_dir


@dataclasses.dataclass
class FlowSet:
    """Static flow structure for one experiment (a packed traffic
    program: every flow belongs to one phase of one job)."""

    paths: np.ndarray  # (F, K, H) link ids, pad = L (sink)
    n_paths: np.ndarray  # (F,)
    path_len: np.ndarray  # (F, K) hop counts (for minimal-path bias)
    is_victim: np.ndarray  # (F,) bool — flow of a non-envelope-gated job
    bytes_per_iter: np.ndarray  # (F,) bytes per phase visit; endless ~inf
    fixed_choice: np.ndarray  # (F,) host-side static assignment
    host_caps: np.ndarray  # (F,) injection-link capacity per flow
    src_id: np.ndarray  # (F,) source node (NIC injection limiting)
    # --- traced-policy static tables (POLICY_ECMP / POLICY_NSLB read
    # these regardless of which mode built fixed_choice; default to the
    # fixed assignment so legacy flow sets stay policy-invariant) ---
    ecmp_choice: Optional[np.ndarray] = None  # (F,)
    nslb_choice: Optional[np.ndarray] = None  # (F,)
    # --- traffic-program tables (defaulted for legacy flat flow sets) ---
    flow_job: Optional[np.ndarray] = None  # (F,) owning job id
    flow_phase: Optional[np.ndarray] = None  # (F,) phase within the job
    n_phases: Optional[np.ndarray] = None  # (J,) program length per job
    phase_gap: Optional[np.ndarray] = None  # (J, P) compute gap per phase
    sweep_mask: Optional[np.ndarray] = None  # (F,) bytes scale with sweep
    job_names: Optional[List[str]] = None

    def __post_init__(self):
        if self.ecmp_choice is None:
            self.ecmp_choice = np.asarray(self.fixed_choice, np.int32)
        if self.nslb_choice is None:
            self.nslb_choice = np.asarray(self.fixed_choice, np.int32)
        # Legacy construction (no program tables): victims are job 0
        # phase 0, aggressors job 1 phase 0, both single-phase loops.
        if self.flow_job is None:
            self.flow_job = np.where(self.is_victim, 0, 1).astype(np.int32)
        if self.flow_phase is None:
            self.flow_phase = np.zeros(len(self.is_victim), np.int32)
        if self.n_phases is None:
            n_jobs = int(self.flow_job.max()) + 1 if len(self.flow_job) \
                else 1
            self.n_phases = np.ones((n_jobs,), np.int32)
        if self.phase_gap is None:
            self.phase_gap = np.zeros((len(self.n_phases), 1), np.float32)
        if self.sweep_mask is None:
            self.sweep_mask = np.asarray(self.is_victim, bool)
        if self.job_names is None:
            self.job_names = [f"job{j}" for j in range(len(self.n_phases))]

    @property
    def n_flows(self) -> int:
        return len(self.is_victim)

    @property
    def n_jobs(self) -> int:
        return len(self.n_phases)


def pack_paths(paths_per_flow: List[List[List[int]]], sink: int, k_max: int = 4):
    F = len(paths_per_flow)
    H = max((len(p) for ps in paths_per_flow for p in ps), default=1)
    out = np.full((F, k_max, H), sink, np.int32)
    n_paths = np.zeros((F,), np.int32)
    plen = np.zeros((F, k_max), np.int32)
    for f, ps in enumerate(paths_per_flow):
        ps = ps[:k_max] if ps else [[]]
        n_paths[f] = len(ps)
        for k, p in enumerate(ps):
            out[f, k, : len(p)] = p
            plen[f, k] = len(p)
    return out, n_paths, plen


# --------------------------------------------------------------------------
# Static geometry pytree
# --------------------------------------------------------------------------


@partial(jax.tree_util.register_dataclass,
         data_fields=["caps_pad", "caps_finite", "dst_sw", "src_sw", "paths",
                      "n_paths", "spray_choice", "path_len", "is_victim",
                      "fixed_choice", "ecmp_choice", "nslb_choice", "src_id",
                      "flow_job", "flow_phase", "n_phases", "phase_gap",
                      "link_group", "link_sw_group"],
         meta_fields=["L", "n_sw", "n_src", "n_jobs", "intra_node"])
@dataclasses.dataclass(frozen=True)
class FabricGeometry:
    """Everything structural: link capacities, switch adjacency, packed
    flow paths, and the traffic-program tables (which job/phase each flow
    belongs to, program lengths, compute gaps). Built once per
    (topology, flow program); shared by every cell of a parameter sweep.

    Routing policy is NOT part of the geometry: it is traced per-cell
    data (``SimParams.policy``), so geometries differing only in routing
    stack into one bucket. The geometry carries every *static* choice
    table a traced policy may read (fixed / ecmp / nslb)."""

    caps_pad: jnp.ndarray  # (L+1,) with inf sink
    caps_finite: jnp.ndarray  # (L+1,) with 1.0 sink
    dst_sw: jnp.ndarray  # (L+1,) switch fed by each link (0 = host)
    src_sw: jnp.ndarray  # (L+1,) switch feeding each link (0 = host)
    paths: jnp.ndarray  # (F, K, H)
    n_paths: jnp.ndarray  # (F,)
    spray_choice: jnp.ndarray  # (F,) deterministic sprayed home path
    path_len: jnp.ndarray  # (F, K) float
    is_victim: jnp.ndarray  # (F,) bool
    fixed_choice: jnp.ndarray  # (F,) host-side static assignment
    ecmp_choice: jnp.ndarray  # (F,) POLICY_ECMP table
    nslb_choice: jnp.ndarray  # (F,) POLICY_NSLB table
    src_id: jnp.ndarray  # (F,)
    flow_job: jnp.ndarray  # (F,) owning job per flow
    flow_phase: jnp.ndarray  # (F,) phase membership per flow
    n_phases: jnp.ndarray  # (J,) program length per job
    phase_gap: jnp.ndarray  # (J, P) compute gap after each phase
    # structural fault-targeting groups per link (envelopes.GROUP_*);
    # 0 on the sink and padding so event rows can never touch them
    link_group: jnp.ndarray  # (L+1,) int32
    # second structural channel: GROUP_SWITCH on every link incident to
    # the busiest switch (a whole switch failing as one unit), 0
    # elsewhere. Separate from link_group so the promotion can never
    # re-label the ids existing event rows target (bit-identity when no
    # row uses GROUP_SWITCH — envelopes.fault_scale_at).
    link_sw_group: jnp.ndarray  # (L+1,) int32
    L: int
    n_sw: int
    n_src: int
    n_jobs: int
    # static flag arming the intra-node (NVLink/PCIe) stage ahead of the
    # NIC limit; 0 keeps the legacy trace free of the extra scatter
    intra_node: int = 0

    @property
    def n_flows(self) -> int:
        return self.is_victim.shape[0]


def make_geometry(topo: Topology, flows: FlowSet, prune: bool = True,
                  intra_node: bool = False) -> FabricGeometry:
    """Bind a flow set to a topology.

    ``prune=True`` (default) restricts the per-link state arrays to the
    links actually referenced by some flow path, remapping link ids
    densely (and likewise switch/source ids). An allocation of tens of
    nodes on a multi-thousand-node machine touches a few hundred links,
    so this shrinks every per-step scatter from machine size to
    allocation size. Untouched links can never interact with a flow
    (their queues stay 0 and no path reads them), so pruning leaves all
    flow-visible outputs bit-identical — tests/test_grid.py asserts it.
    """
    L_full = len(topo.caps)
    paths_np = np.asarray(flows.paths)
    if prune:
        used = np.unique(paths_np[paths_np < L_full]).astype(np.int64)
    else:
        used = np.arange(L_full, dtype=np.int64)
    L = len(used)
    remap = np.full((L_full + 1,), L, np.int32)
    remap[used] = np.arange(L, dtype=np.int32)
    paths_np = remap[paths_np]  # old sink (== L_full) -> new sink (== L)
    caps = np.asarray(topo.caps, np.float64)[used]
    caps_pad = jnp.asarray(np.concatenate([caps, [np.inf]]), jnp.float32)
    caps_finite = jnp.asarray(np.concatenate([caps, [1.0]]), jnp.float32)
    # link <-> switch adjacency for backpressure spreading
    sw_ids: dict = {}
    dst_sw = np.zeros(L + 1, np.int32)
    src_sw = np.zeros(L + 1, np.int32)
    for li, gi in enumerate(used):
        a, b = topo.link_names[int(gi)]
        if not (isinstance(b, tuple) and b[0] == "h"):
            dst_sw[li] = 1 + sw_ids.setdefault(b, len(sw_ids))
        if not (isinstance(a, tuple) and a[0] == "h"):
            src_sw[li] = 1 + sw_ids.setdefault(a, len(sw_ids))
    n_sw = len(sw_ids) + 2  # 0 == "no switch" (host endpoints)
    # structural fault-targeting groups: edge-up / edge-down / fabric from
    # the endpoint kinds, then the single most-path-traversed link is
    # promoted to GROUP_HOT ("the flapping link" / "the dying optic" —
    # deterministic, so fault scenarios target it without naming ids).
    # The sink (index L) stays GROUP_NONE and is untouchable by events.
    link_group = np.zeros(L + 1, np.int32)
    for li, gi in enumerate(used):
        a, b = topo.link_names[int(gi)]
        if isinstance(a, tuple) and a[0] == "h":
            link_group[li] = GROUP_EDGE_UP
        elif isinstance(b, tuple) and b[0] == "h":
            link_group[li] = GROUP_EDGE_DOWN
        else:
            link_group[li] = GROUP_FABRIC
    traversals = np.bincount(paths_np[paths_np < L].ravel(), minlength=L)
    if traversals.size and traversals.max() > 0:
        link_group[int(np.argmax(traversals))] = GROUP_HOT
    # switch-level group: the busiest switch (max summed path traversals
    # over its incident links) contributes its WHOLE link set — the
    # deterministic switch analog of GROUP_HOT, so switch_outage events
    # target it without naming ids. Kept in a separate array; the sink
    # (index L) and host endpoints (switch id 0) stay GROUP_NONE.
    link_sw_group = np.zeros(L + 1, np.int32)
    if traversals.size and traversals.max() > 0:
        sw_load = np.zeros(n_sw, np.float64)
        np.add.at(sw_load, src_sw[:L], traversals)
        np.add.at(sw_load, dst_sw[:L], traversals)
        sw_load[0] = 0.0  # "no switch" (host endpoints) is not a switch
        if sw_load.max() > 0:
            hot_sw = int(np.argmax(sw_load))
            incident = (src_sw[:L] == hot_sw) | (dst_sw[:L] == hot_sw)
            link_sw_group[:L][incident] = GROUP_SWITCH
    # source (NIC) ids densified the same way
    src_raw = np.asarray(flows.src_id, np.int64)
    if prune and len(src_raw):
        _, src_dense = np.unique(src_raw, return_inverse=True)
        n_src = int(src_dense.max()) + 1
    else:
        src_dense = src_raw
        n_src = int(src_raw.max()) + 1 if len(src_raw) else 1
    # sprayed "home" path per flow: deterministic hash spread over the
    # candidates so concurrent flows do not herd onto one port
    F = flows.n_flows
    spray = (np.arange(F, dtype=np.int64) * 2654435761 % (1 << 31)) \
        % np.maximum(flows.n_paths, 1)
    return FabricGeometry(
        caps_pad=caps_pad, caps_finite=caps_finite,
        dst_sw=jnp.asarray(dst_sw), src_sw=jnp.asarray(src_sw),
        paths=jnp.asarray(paths_np), n_paths=jnp.asarray(flows.n_paths),
        spray_choice=jnp.asarray(spray.astype(np.int32)),
        path_len=jnp.asarray(flows.path_len, jnp.float32),
        is_victim=jnp.asarray(flows.is_victim),
        fixed_choice=jnp.asarray(flows.fixed_choice),
        ecmp_choice=jnp.asarray(flows.ecmp_choice, jnp.int32),
        nslb_choice=jnp.asarray(flows.nslb_choice, jnp.int32),
        src_id=jnp.asarray(src_dense.astype(np.int32)),
        flow_job=jnp.asarray(flows.flow_job, jnp.int32),
        flow_phase=jnp.asarray(flows.flow_phase, jnp.int32),
        n_phases=jnp.asarray(flows.n_phases, jnp.int32),
        phase_gap=jnp.asarray(flows.phase_gap, jnp.float32),
        link_group=jnp.asarray(link_group),
        link_sw_group=jnp.asarray(link_sw_group),
        L=L, n_sw=n_sw, n_src=n_src, n_jobs=flows.n_jobs,
        intra_node=int(bool(intra_node)))


# --------------------------------------------------------------------------
# Geometry padding: heterogeneous topologies in one batch (DESIGN.md §11)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GeometryDims:
    """Bucket shape every member geometry is padded to. Equal dims make
    FabricGeometry pytrees stackable: the meta fields become identical,
    so ``jax.vmap`` batches the data fields (routing policy is traced
    SimParams data, not meta — mixed-routing cells share a bucket)."""

    n_links: int  # L (sink lives at index n_links)
    n_flows: int
    k_max: int
    max_hops: int
    n_sw: int
    n_src: int
    n_jobs: int
    n_phases: int
    # 0/1 flag, not a size: never rounded up by the bucket policy (a
    # pow2 round would turn 0 into 1 and arm the stage for every bucket)
    intra_node: int = 0


def geometry_dims(geom: FabricGeometry) -> GeometryDims:
    return GeometryDims(
        n_links=geom.L, n_flows=geom.n_flows,
        k_max=int(geom.paths.shape[1]), max_hops=int(geom.paths.shape[2]),
        n_sw=geom.n_sw, n_src=geom.n_src, n_jobs=geom.n_jobs,
        n_phases=int(geom.phase_gap.shape[1]),
        intra_node=int(geom.intra_node))


_DIM_FLAG_FIELDS = ("intra_node",)


def bucket_dims(geoms: Sequence[FabricGeometry],
                round_up=None) -> GeometryDims:
    """Elementwise max over member dims, optionally rounded up (the
    bucket-size policy — bench rounds to powers of two so different cell
    sets resolve to the same bucket shape and reuse compiles). Flag
    fields max without rounding: a bucket mixing stage-on and stage-off
    cells arms the stage, and stage-off members run it inert
    (node_cap=inf is bit-identical — DESIGN.md §16)."""
    dims = [geometry_dims(g) for g in geoms]
    out = {}
    for f in dataclasses.fields(GeometryDims):
        v = max(getattr(d, f.name) for d in dims)
        if round_up is not None and f.name not in _DIM_FLAG_FIELDS:
            v = round_up(v)
        out[f.name] = v
    return GeometryDims(**out)


def pad_geometry(geom: FabricGeometry, dims: GeometryDims) -> FabricGeometry:
    """Pad one geometry to a bucket shape with provably inert padding.

    Padding rows are constructed so the padded run is *bit-identical* to
    the unpadded run of the same cell (tests/test_grid.py):

    * pad links ([L, n_links)) are referenced by no path and see zero
      arrival, so their queues stay at exactly 0.0;
    * pad flows carry a sink-only path, zero path length and ``is_victim
      == False``; their byte budget (SimParams) must be 0, which keeps
      them out of ``alive`` forever — they inject 0.0 into every scatter;
    * pad jobs have ``n_phases == 1`` and no member flows; their phase
      counter free-runs without touching any real job's barrier;
    * pad switches/sources are referenced by no link/flow.

    The old sink (index ``geom.L``) is remapped to the new sink
    (``dims.n_links``) everywhere in the path table.
    """
    cur = geometry_dims(geom)
    for f in dataclasses.fields(GeometryDims):
        if getattr(dims, f.name) < getattr(cur, f.name):
            raise ValueError(
                f"pad_geometry: {f.name}={getattr(dims, f.name)} < "
                f"current {getattr(cur, f.name)}")
    L_old, L_new = geom.L, dims.n_links
    F, J = dims.n_flows, dims.n_jobs

    paths = np.asarray(geom.paths)
    paths = np.where(paths >= L_old, L_new, paths).astype(np.int32)
    padded_paths = np.full((F, dims.k_max, dims.max_hops), L_new, np.int32)
    padded_paths[: paths.shape[0], : paths.shape[1], : paths.shape[2]] = paths

    path_len = np.zeros((F, dims.k_max), np.float32)
    pl = np.asarray(geom.path_len)
    path_len[: pl.shape[0], : pl.shape[1]] = pl

    caps_pad = np.full((L_new + 1,), np.inf, np.float32)
    caps_pad[:L_old] = np.asarray(geom.caps_pad)[:L_old]
    caps_finite = np.ones((L_new + 1,), np.float32)
    caps_finite[:L_old] = np.asarray(geom.caps_finite)[:L_old]
    dst_sw = np.zeros((L_new + 1,), np.int32)
    dst_sw[:L_old] = np.asarray(geom.dst_sw)[:L_old]
    src_sw = np.zeros((L_new + 1,), np.int32)
    src_sw[:L_old] = np.asarray(geom.src_sw)[:L_old]
    # pad links stay GROUP_NONE: no fault event can ever scale them
    link_group = np.zeros((L_new + 1,), np.int32)
    link_group[:L_old] = np.asarray(geom.link_group)[:L_old]
    link_sw_group = np.zeros((L_new + 1,), np.int32)
    link_sw_group[:L_old] = np.asarray(geom.link_sw_group)[:L_old]

    n_phases = pad_rows(np.asarray(geom.n_phases), J, 1)
    phase_gap = np.zeros((J, dims.n_phases), np.float32)
    pg = np.asarray(geom.phase_gap)
    phase_gap[: pg.shape[0], : pg.shape[1]] = pg

    return FabricGeometry(
        caps_pad=jnp.asarray(caps_pad), caps_finite=jnp.asarray(caps_finite),
        dst_sw=jnp.asarray(dst_sw), src_sw=jnp.asarray(src_sw),
        paths=jnp.asarray(padded_paths),
        n_paths=jnp.asarray(pad_rows(np.asarray(geom.n_paths), F, 1)),
        spray_choice=jnp.asarray(pad_rows(np.asarray(geom.spray_choice), F, 0)),
        path_len=jnp.asarray(path_len),
        is_victim=jnp.asarray(pad_rows(np.asarray(geom.is_victim), F, False)),
        fixed_choice=jnp.asarray(pad_rows(np.asarray(geom.fixed_choice), F, 0)),
        ecmp_choice=jnp.asarray(pad_rows(np.asarray(geom.ecmp_choice), F, 0)),
        nslb_choice=jnp.asarray(pad_rows(np.asarray(geom.nslb_choice), F, 0)),
        src_id=jnp.asarray(pad_rows(np.asarray(geom.src_id), F,
                                 dims.n_src - 1)),
        flow_job=jnp.asarray(pad_rows(np.asarray(geom.flow_job), F, J - 1)),
        flow_phase=jnp.asarray(pad_rows(np.asarray(geom.flow_phase), F, 0)),
        n_phases=jnp.asarray(n_phases), phase_gap=jnp.asarray(phase_gap),
        link_group=jnp.asarray(link_group),
        link_sw_group=jnp.asarray(link_sw_group),
        L=L_new, n_sw=dims.n_sw, n_src=dims.n_src, n_jobs=J,
        intra_node=int(dims.intra_node))


def stack_geometries(geoms: Sequence[FabricGeometry]) -> FabricGeometry:
    """Stack same-shape geometries into one batched pytree (leading cell
    axis on every data field). All meta fields must agree; pad to a
    common :class:`GeometryDims` first. Routing policy is traced data
    (SimParams.policy), so mixed-routing cells stack freely."""
    metas = {(g.L, g.n_sw, g.n_src, g.n_jobs, g.intra_node) for g in geoms}
    if len(metas) != 1:
        raise ValueError(f"cannot stack geometries with differing meta "
                         f"fields: {sorted(metas)}")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *geoms)


# --------------------------------------------------------------------------
# Traced sweep parameters
# --------------------------------------------------------------------------


@partial(jax.tree_util.register_dataclass,
         data_fields=["dt", "bytes_per_iter", "host_caps", "env", "policy",
                      "flowlet_gap_s", "flow_start", "fct_mask", "fault",
                      "node_cap", "kind",
                      "qmax_bytes", "kmin", "kmax", "md", "rai_frac",
                      "cc_interval_s", "hol_factor", "hol_start",
                      "min_rate_frac", "follow_tau_s", "follow_gain",
                      "thresh_adapt", "burst_jitter", "iter_drain"],
         meta_fields=[])
@dataclasses.dataclass(frozen=True)
class SimParams:
    """Traced per-cell parameters. Every leaf is an array, so a stack of
    cells (leading batch axis on each leaf) vmaps through the engine."""

    dt: jnp.ndarray  # () seconds
    bytes_per_iter: jnp.ndarray  # (F,)
    host_caps: jnp.ndarray  # (F,)
    env: jnp.ndarray  # (ENV_COMPONENTS, 5) congestion-envelope components
    # routing policy id (routing.POLICY_*) + flowlet idle-gap threshold —
    # traced, so mixed-routing grids batch in one compile
    policy: jnp.ndarray  # () int32
    flowlet_gap_s: jnp.ndarray  # () seconds
    # stochastic-workload fields (core/workload.py): a flow is eligible
    # only once sim time reaches its start (Poisson arrivals), and
    # fct_mask selects which flows feed the FCT histogram (short flows).
    # Scalar 0.0 defaults reproduce legacy behavior bit-for-bit.
    flow_start: jnp.ndarray  # () or (F,) seconds
    fct_mask: jnp.ndarray  # () or (F,) 0/1 weight
    # link-fault event table (envelopes.fault_scale_at). None keeps the
    # legacy no-fault trace byte-identical (an absent pytree leaf); grids
    # mixing fault and clean lanes put the inert all-``none`` table on
    # the clean lanes so stacked params share one structure.
    fault: Optional[jnp.ndarray]  # (FAULT_EVENTS, FAULT_FIELDS) or None
    # intra-node stage capacity in bytes/s (scalar or (n_src,)); +inf is
    # exactly inert, so stage-on buckets can host stage-off cells
    node_cap: jnp.ndarray  # () or (n_src,)
    # CC scalars (cc.CCParams lowered to data; kind selects the update
    # rule — scalar per cell, or (F,) for per-flow/tenant CC mixes)
    kind: jnp.ndarray  # () or (F,) int32
    qmax_bytes: jnp.ndarray
    kmin: jnp.ndarray
    kmax: jnp.ndarray
    md: jnp.ndarray
    rai_frac: jnp.ndarray
    cc_interval_s: jnp.ndarray
    hol_factor: jnp.ndarray
    hol_start: jnp.ndarray
    min_rate_frac: jnp.ndarray
    follow_tau_s: jnp.ndarray
    follow_gain: jnp.ndarray
    thresh_adapt: jnp.ndarray
    burst_jitter: jnp.ndarray
    iter_drain: jnp.ndarray


def make_params(cc: CCParams, *, dt: float, bytes_per_iter: np.ndarray,
                host_caps: np.ndarray, env: np.ndarray,
                policy: int = POLICY_FIXED,
                flowlet_gap_s: float = 200e-6,
                flow_start=0.0, fct_mask=0.0,
                fault=None, node_cap=np.inf) -> SimParams:
    f32 = lambda v: jnp.asarray(v, jnp.float32)
    return SimParams(
        dt=f32(dt), bytes_per_iter=f32(bytes_per_iter),
        host_caps=f32(host_caps), env=f32(env),
        policy=jnp.asarray(policy, jnp.int32),
        flowlet_gap_s=f32(flowlet_gap_s),
        flow_start=f32(flow_start), fct_mask=f32(fct_mask),
        fault=None if fault is None else f32(fault),
        node_cap=f32(node_cap),
        kind=jnp.asarray(cc.kind, jnp.int32),
        qmax_bytes=f32(cc.qmax_bytes), kmin=f32(cc.kmin), kmax=f32(cc.kmax),
        md=f32(cc.md), rai_frac=f32(cc.rai_frac),
        cc_interval_s=f32(cc.cc_interval_s), hol_factor=f32(cc.hol_factor),
        hol_start=f32(cc.hol_start), min_rate_frac=f32(cc.min_rate_frac),
        follow_tau_s=f32(cc.follow_tau_s), follow_gain=f32(cc.follow_gain),
        thresh_adapt=f32(1.0 if cc.thresh_adapt else 0.0),
        burst_jitter=f32(cc.burst_jitter), iter_drain=f32(cc.iter_drain))


def stack_params(params: List[SimParams]) -> SimParams:
    """Stack per-cell SimParams into one batched pytree (leading axis)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *params)


# --------------------------------------------------------------------------
# Pure step / run functions
# --------------------------------------------------------------------------


def init_state(geom: FabricGeometry, p: SimParams, metrics: bool = False):
    """Initial scan carry. ``metrics=True`` adds the streaming-statistics
    accumulators (core/metrics.py): O(bins + F + J) extra state,
    independent of step count. ``_step_impl`` detects the extra keys and
    emits the matching updates — the flag is structural (dict keys), so
    it is static under jit without an extra argument."""
    F, J = geom.n_flows, geom.n_jobs
    state = _base_state(geom, p)
    if metrics:
        from repro.core import metrics as met
        state.update({
            # time each flow (re-)armed its current byte budget: short
            # flows arm at their Poisson arrival, tenant flows at every
            # phase entry — completion at t samples FCT = t - armed_t
            "armed_t": jnp.zeros((F,), jnp.float32) + p.flow_start,
            "h_qd": jnp.zeros((met.NBINS,), jnp.float32),
            "h_fct": jnp.zeros((met.NBINS,), jnp.float32),
            "wn": jnp.zeros((J,), jnp.float32),
            "wmean": jnp.zeros((J,), jnp.float32),
            "wm2": jnp.zeros((J,), jnp.float32),
        })
    return state


def _base_state(geom: FabricGeometry, p: SimParams):
    F, J = geom.n_flows, geom.n_jobs
    return {
        "c": p.host_caps,
        "rem": p.bytes_per_iter,
        "q": jnp.zeros((geom.L + 1,), jnp.float32),
        "arr": jnp.zeros((geom.L + 1,), jnp.float32),
        "thresh": jnp.full((geom.L + 1,), jnp.float32(1.0)) * p.kmin
        * p.qmax_bytes,
        "last_dec": jnp.zeros((F,), jnp.float32),
        # --- traced-routing state: flowlet current path + idle time,
        # per-flow delivered-bytes accumulator (mitigation scoring)
        "rc": geom.spray_choice,
        "idle": jnp.zeros((F,), jnp.float32),
        "fbytes": jnp.zeros((F,), jnp.float32),
        # --- traffic-program state: per-job phase counter, remaining
        # compute gap of the current phase, completed program iterations
        "ph": jnp.zeros((J,), jnp.int32),
        "gap": geom.phase_gap[:, 0],
        "it": jnp.zeros((J,), jnp.int32),
        "t_done": jnp.zeros((J, TDONE_SLOTS), jnp.float32),
        "qd_acc": jnp.zeros((), jnp.float32),
        "t": jnp.zeros((), jnp.float32),
    }


def _cc_update(p: SimParams, c, a, fmark, fstrength, can_dec):
    """Branchless CC dispatch: the per-fabric rate update is a lax.switch
    over ``p.kind``, so fabric kind is data (vmap lowers it to a select
    across branches — cells with different fabrics batch together)."""
    inc = p.rai_frac * p.host_caps * (p.dt / 1e-3)
    # credit-window follower (ib / slingshot); tau guarded for the kinds
    # that leave it at 0 — their branches never read ``f``.
    f = 1.0 - jnp.exp(-p.dt / jnp.maximum(p.follow_tau_s, 1e-9))

    def dcqcn(_):
        dec = fmark & can_dec
        return jnp.where(dec, c * p.md, c + inc), dec

    def ib(_):
        # credit semantics: the send window tracks what actually drains
        # (hop-by-hop credits), SYMMETRICALLY — senders pause when the
        # downstream buffer fills and resume the instant it drains. The
        # overshoot keeps the hot buffer fed (full, not at the mark
        # point); FECN/BECN marking is the slower outer loop.
        c2 = (1 - f) * c + f * jnp.maximum(
            a * p.follow_gain, p.min_rate_frac * p.host_caps)
        dec = fmark & can_dec
        return jnp.where(dec, c2 * p.md, c2 + inc), dec

    def slingshot(_):
        # throttle only flows actually bottlenecked
        bottlenecked = fmark & (a < 0.95 * c)
        c2 = jnp.where(bottlenecked,
                       (1 - f) * c + f * a * p.follow_gain,
                       c + inc)
        return c2, bottlenecked & can_dec

    def ai_ecn(_):
        dec = fmark & can_dec
        return jnp.where(dec, c * (1.0 - (1.0 - p.md) * fstrength),
                         c + inc), dec

    branches = [None] * 4
    branches[KIND_DCQCN] = dcqcn
    branches[KIND_IB] = ib
    branches[KIND_SLINGSHOT] = slingshot
    branches[KIND_AI_ECN] = ai_ecn
    if p.kind.ndim == 0:
        # scalar kind per cell: lax.switch (vmap lowers it to a select)
        return jax.lax.switch(p.kind, branches, None)
    # per-flow kind (F,) — tenant CC mixes inside ONE cell (workload.py):
    # evaluate every branch and select elementwise. jnp.select returns the
    # chosen branch's exact value, so a uniform vector matches the scalar
    # path bit-for-bit.
    outs = [b(None) for b in branches]
    preds = [p.kind == k for k in range(len(branches))]
    return (jnp.select(preds, [c2 for c2, _ in outs], outs[0][0]),
            jnp.select(preds, [d for _, d in outs], outs[0][1]))


def step(geom: FabricGeometry, p: SimParams, state,
         backend: Optional[str] = None):
    return _step_impl(geom, p, state, with_aux=False,
                      backend=resolve_step_backend(backend))


def step_debug(geom: FabricGeometry, p: SimParams, state,
               backend: Optional[str] = None):
    """Like :func:`step` but also returns an aux dict of internal rates
    (injection, per-stage link loads/served rates, effective capacities)
    for the invariant test suite. The state update is the identical
    computation — the aux branch only adds read-only observers."""
    return _step_impl(geom, p, state, with_aux=True,
                      backend=resolve_step_backend(backend))


def _step_impl(geom: FabricGeometry, p: SimParams, state, with_aux: bool,
               backend: str = "ref"):
    dt = p.dt
    # aggressor envelope: traceable function of sim time (no host callback)
    env_t = envelope_at(p.env, state["t"])
    # phase membership: a flow transmits only while its job's phase
    # counter sits on the flow's phase (and its phase bytes remain);
    # negative phase id = wildcard, member of every phase
    # (traffic.WILDCARD_PHASE — uniform ring schedules)
    in_phase = (geom.flow_phase == state["ph"][geom.flow_job]) \
        | (geom.flow_phase < 0)
    # flow_start gates stochastic arrivals (workload.py); the scalar 0.0
    # default keeps the predicate all-true — legacy runs are bit-identical
    alive = (state["rem"] > 0) & in_phase & (state["t"] >= p.flow_start)
    active = (geom.is_victim | (env_t > 0)) & alive
    gate = jnp.where(geom.is_victim, 1.0, env_t) * alive
    inject = state["c"] * gate
    # (The NIC injection limit now lives in the fused step core below —
    # it has no data dependence on routing, so applying it after the
    # path choice is bit-identical.)

    # ---- link-fault engine (envelopes.fault_scale_at, DESIGN.md §16) ----
    # Per-link capacity scale at sim time t, folded into the caps operand
    # OUTSIDE the kernel launch so both step-core backends consume
    # already-scaled capacities and the fused kernel body is untouched.
    # p.fault is None on the legacy path (absent pytree leaf — the trace
    # is byte-identical to a build without the feature); the all-``none``
    # table lowers to an exact 1.0 scale, and caps * 1.0 is bit-exact for
    # finite positive f32 capacities (the inertness contract the
    # fault-table tests pin on every state leaf).
    caps_lk = geom.caps_finite
    if p.fault is not None:
        caps_lk = caps_lk * fault_scale_at(p.fault, geom.link_group,
                                           state["t"],
                                           link_sw_group=geom.link_sw_group)

    # ---- optional intra-node stage (NVLink/PCIe ahead of the NIC) ----
    # Flows sharing a source node proportionally split the node's
    # internal bandwidth BEFORE the NIC limit — the same fluid share rule
    # the core applies per NIC, one stage earlier (Tarraga-Moreno et al.;
    # DESIGN.md §16). The flag is geometry meta (static), so flag-off
    # traces carry none of these ops; node_cap == +inf makes the stage an
    # exact no-op (scale 1.0), letting stage-on buckets host stage-off
    # cells bit-identically.
    if geom.intra_node:
        nload = jnp.zeros((geom.n_src,), jnp.float32) \
            .at[geom.src_id].add(inject)
        ncap = p.node_cap + jnp.zeros((geom.n_src,), jnp.float32)
        nscale = jnp.minimum(1.0, ncap / jnp.maximum(nload, 1.0))
        inject = inject * nscale[geom.src_id]

    # ---- routing: traced per-cell policy (lax.switch over p.policy) ----
    # Static tables (fixed / ecmp / nslb) read precomputed host-side
    # assignments; dynamic policies score candidates by queue occupancy.
    # Under vmap the switch lowers to a select, so one compile serves a
    # grid mixing every policy. The candidate scores are hoisted out of
    # the branches and computed ONCE — the dominant engine entries are
    # batched (run_cells/_hetero evaluate every branch anyway), so
    # sharing the (F, K, H) occupancy gather halves its per-step cost.
    # ``occ`` is shared with the backpressure stage of the core.
    occ = state["q"] / p.qmax_bytes
    score = jnp.max(occ[geom.paths], axis=2) \
        + 0.05 * geom.path_len / jnp.maximum(geom.path_len[:, :1], 1)
    score = jnp.where(np.arange(geom.paths.shape[1])[None, :]
                      < geom.n_paths[:, None], score, jnp.inf)
    best = jnp.argmin(score, axis=1)
    best_score = jnp.min(score, axis=1)

    def _hysteresis(anchor):
        # Production AR does NOT send every flow to the globally least-
        # loaded port (that herds and oscillates): a flow leaves its
        # anchor path only when its occupancy is clearly worse than the
        # best alternative.
        a_score = jnp.take_along_axis(score, anchor[:, None], 1)[:, 0]
        return jnp.where(a_score > best_score + 0.10, best, anchor)

    def _route_adaptive(_):
        # anchored on the sprayed home path, re-evaluated every step
        return _hysteresis(geom.spray_choice), state["rc"]

    def _route_flowlet(_):
        # flowlet re-pathing: keep the current path while the flow
        # transmits; once its idle gap exceeds the traced threshold the
        # next burst re-evaluates — anchored on the CURRENT path with
        # the same hysteresis as adaptive (all-idle flows re-picking a
        # global argmin would herd onto one uplink), but only at flowlet
        # boundaries (idle resets on activity below, so a live flow
        # never re-orders mid-burst).
        rc = jnp.where(state["idle"] >= p.flowlet_gap_s,
                       _hysteresis(state["rc"]), state["rc"])
        return rc, rc

    route_branches = [None] * 5
    route_branches[POLICY_FIXED] = lambda _: (geom.fixed_choice, state["rc"])
    route_branches[POLICY_ECMP] = lambda _: (geom.ecmp_choice, state["rc"])
    route_branches[POLICY_NSLB] = lambda _: (geom.nslb_choice, state["rc"])
    route_branches[POLICY_ADAPTIVE] = _route_adaptive
    route_branches[POLICY_FLOWLET] = _route_flowlet
    choice, rc_new = jax.lax.switch(p.policy, route_branches, None)
    idle_new = jnp.where(active, 0.0, state["idle"] + dt)
    plinks = jnp.take_along_axis(
        geom.paths, choice[:, None, None], axis=1)[:, 0]  # (F, H)
    valid = plinks < geom.L

    # ---- fused step core (NIC limit, backpressure stall, staged
    # propagation, queue update) ----
    # The memory-bound scatter/segment-sum core lives in repro.kernels:
    # kernels/ref.py holds the original lax code verbatim (the oracle and
    # CPU default), kernels/fabric_step.py the fused Pallas kernel. The
    # physics — why backpressure is share-weighted, why propagation is
    # feed-forward FIFO fluid sharing — is documented on the oracle and
    # in DESIGN.md §13.
    if backend == "pallas":
        core = kernel_ops.fabric_step_core(
            plinks, inject, geom.src_id, p.host_caps, state["q"], occ,
            caps_lk, geom.src_sw, geom.dst_sw, dt, p.qmax_bytes,
            p.hol_factor, p.hol_start, p.burst_jitter,
            n_src=geom.n_src, n_sw=geom.n_sw, with_aux=with_aux)
    else:
        core = kernel_ref.fabric_step_core(
            plinks, inject, geom.src_id, p.host_caps, state["q"], occ,
            caps_lk, geom.src_sw, geom.dst_sw, dt, p.qmax_bytes,
            p.hol_factor, p.hol_start, p.burst_jitter,
            n_src=geom.n_src, n_sw=geom.n_sw, with_aux=with_aux)
    inject = core["inject"]  # NIC-scaled
    a = core["achieved"]  # achieved end-to-end rate
    arrival = core["arrival"]
    caps_eff = core["caps_eff"]
    served_stage_max = core["served_stage_max"]
    q = core["q_new"]

    # ---- signals ----
    # AI-ECN: threshold tracks a fraction of the observed queue so
    # marking strength is proportional, not bang-bang. thresh_adapt == 0
    # keeps the static kmin threshold.
    adapted = jnp.clip(0.9 * state["thresh"] + 0.1 * (0.5 * q + p.kmin
                                                      * p.qmax_bytes),
                       0.05 * p.qmax_bytes, p.kmax * p.qmax_bytes)
    thresh = jnp.where(p.thresh_adapt > 0, adapted, state["thresh"])
    over_thresh = q > thresh
    fmark = jnp.any(over_thresh[plinks] & valid, axis=1)
    # proportional mark strength (ai_ecn) in [0, 1]
    strength_l = jnp.clip((q - thresh)
                          / (p.kmax * p.qmax_bytes - thresh + 1.0),
                          0.0, 1.0)
    fstrength = jnp.max(jnp.where(valid, strength_l[plinks], 0.0), axis=1)

    # ---- CC update (lax.switch over fabric kind) ----
    can_dec = state["last_dec"] >= p.cc_interval_s
    c, dec = _cc_update(p, state["c"], a, fmark, fstrength, can_dec)
    # CC state only evolves for flows that are actually transmitting —
    # an idle flow (finished its iteration early, or paused aggressor)
    # keeps its rate limit.
    c = jnp.where(active, c, state["c"])
    dec = dec & active
    c = jnp.clip(c, p.min_rate_frac * p.host_caps, p.host_caps)
    last_dec = jnp.where(dec, 0.0, state["last_dec"] + dt)

    # ---- progress + phase/program bookkeeping ----
    rem = state["rem"] - a * dt
    # completion event: the flow was eligible and its budget crossed zero
    # this very step (captured before `enter` re-arms rem below)
    done_now = alive & (rem <= 0)
    t_new = state["t"] + dt
    # per-job barrier: a phase completes only when its SLOWEST member
    # flow has drained (straggler semantics, DESIGN.md §7) ...
    busy = jnp.zeros((geom.n_jobs,), jnp.int32).at[geom.flow_job].max(
        (in_phase & (rem > 0)).astype(jnp.int32)) > 0
    # ... then the compute gap of the phase runs before the barrier
    # releases the next phase (gap == 0 -> advance in the same step,
    # which is exactly the pre-program iteration semantics)
    gap = state["gap"] - dt * (~busy)
    advance = ~busy & (gap <= 0)
    ph_next = jnp.where(advance,
                        (state["ph"] + 1) % geom.n_phases, state["ph"])
    wrap = advance & (state["ph"] + 1 >= geom.n_phases)
    gap = jnp.where(advance,
                    jnp.take_along_axis(geom.phase_gap, ph_next[:, None],
                                        axis=1)[:, 0], gap)
    # flows of the newly-entered phase reload their byte budget
    # (wildcard flows re-arm at every phase entry)
    enter = advance[geom.flow_job] \
        & ((geom.flow_phase == ph_next[geom.flow_job])
           | (geom.flow_phase < 0))
    rem = jnp.where(enter, p.bytes_per_iter, rem)
    # a job wrapping phase 0 completed one program iteration
    it = state["it"]
    slot = jnp.minimum(it, TDONE_SLOTS - 1)
    onehot = _TDONE_ARANGE[None, :] == slot[:, None]
    t_done = jnp.where(wrap[:, None] & onehot, t_new, state["t_done"])
    it = it + wrap.astype(jnp.int32)
    # synchronization gap between iterations of the primary (measured)
    # job partially drains queues
    q = jnp.where(wrap[0], q * p.iter_drain, q)

    # queueing delay experienced by victim flows (seconds) — against the
    # fault-scaled capacity: a drained-down link serves its queue slower
    qdel = jnp.max(jnp.where(valid, (q / caps_lk)[plinks], 0.0),
                   axis=1)
    mean_qdel = jnp.sum(qdel * geom.is_victim) / jnp.maximum(
        jnp.sum(geom.is_victim), 1)
    vict_goodput = jnp.sum(a * geom.is_victim)

    new_state = {"c": c, "rem": rem, "q": q, "arr": arrival,
                 "thresh": thresh, "last_dec": last_dec,
                 "rc": rc_new, "idle": idle_new,
                 "fbytes": state["fbytes"] + a * dt,
                 "ph": ph_next, "gap": gap, "it": it, "t_done": t_done,
                 "qd_acc": state["qd_acc"] + mean_qdel * dt, "t": t_new}

    if "h_qd" in state:  # streaming metrics carry (init_state(metrics=True))
        from repro.core import metrics as met
        # queue delay: every transmitting flow contributes one sample/step
        w_qd = active.astype(jnp.float32)
        h_qd = met.hist_add(state["h_qd"], qdel, w_qd, jnp)
        # completion: an alive flow whose budget crossed zero this step
        # (done is computed BEFORE the `enter` re-arm overwrote rem)
        fct = t_new - state["armed_t"]
        w_done = done_now.astype(jnp.float32)
        h_fct = met.hist_add(state["h_fct"], fct,
                             w_done * (p.fct_mask + jnp.zeros_like(fct)),
                             jnp)
        # per-tenant slowdown: FCT normalized by the flow's ideal
        # (uncontended line-rate) drain time, merged Welford-style per job
        ideal = p.bytes_per_iter / jnp.maximum(p.host_caps, 1.0)
        slow = fct / jnp.maximum(ideal, 1e-9)
        wn, wmean, wm2 = met.welford_update(
            state["wn"], state["wmean"], state["wm2"], slow, w_done,
            geom.flow_job, geom.n_jobs, jnp)
        new_state.update({
            "armed_t": jnp.where(enter, t_new, state["armed_t"]),
            "h_qd": h_qd, "h_fct": h_fct,
            "wn": wn, "wmean": wmean, "wm2": wm2})

    if with_aux:
        aux = {"inject": inject, "achieved": a, "arrival": arrival,
               "served_stage_max": served_stage_max, "caps_eff": caps_eff,
               "active": active, "advance": advance, "wrap": wrap,
               "qdel": qdel, "done": done_now}
        return new_state, vict_goodput, aux
    return new_state, vict_goodput


def _run_cell(geom: FabricGeometry, p: SimParams, n_iters,
              chunk: int, max_chunks: int, stride: int,
              backend: str = "ref", metrics: bool = False,
              with_trace: bool = True):
    """Run one cell to ``n_iters`` victim iterations (or the step budget),
    chunked so the early exit happens at chunk granularity. Pure and
    vmap-able: under vmap the while_loop runs until every cell finishes.

    ``metrics=True`` threads the streaming accumulators through the scan
    and returns them; ``with_trace=False`` drops the strided goodput
    buffer — the replay path's peak memory is then O(F + bins) per cell,
    independent of the step budget (no O(T) allocation at all)."""
    assert chunk % stride == 0, (chunk, stride)
    trace_chunk = chunk // stride
    state = init_state(geom, p, metrics=metrics)
    buf = jnp.zeros((max_chunks * trace_chunk if with_trace else 1,),
                    jnp.float32)

    def cond(carry):
        state, _, k = carry
        # job 0 is the primary (measured) job; background jobs loop for
        # as long as it runs and report however many programs they closed
        return (k < max_chunks) & (state["it"][0] < n_iters)

    def body(carry):
        state, buf, k = carry
        state, gp = jax.lax.scan(
            lambda s, _: _step_impl(geom, p, s, with_aux=False,
                                    backend=backend),
            state, None, length=chunk)
        if with_trace:
            buf = jax.lax.dynamic_update_slice(buf, gp[::stride],
                                               (k * trace_chunk,))
        return state, buf, k + 1

    state, buf, k = jax.lax.while_loop(
        cond, body, (state, buf, jnp.zeros((), jnp.int32)))
    out = {"t_done": state["t_done"], "it": state["it"],
           "qd_acc": state["qd_acc"], "t": state["t"],
           "fbytes": state["fbytes"],
           "trace": buf, "chunks": k}
    if metrics:
        out.update({k2: state[k2]
                    for k2 in ("h_qd", "h_fct", "wn", "wmean", "wm2")})
    return out


# The public entries resolve the step-core backend EAGERLY (a Python
# string) and forward it as a static jit argument: a backend switch via
# set_step_backend()/$REPRO_FABRIC_KERNEL is a different cache key, never
# a stale compile. TRACE_COUNTS increments live in the inner jitted
# functions so they still fire once per compile.


@partial(jax.jit, static_argnames=("chunk", "max_chunks", "stride",
                                   "backend", "metrics", "with_trace"))
def _run_cell_jit(geom, p, n_iters, *, chunk, max_chunks, stride, backend,
                  metrics=False, with_trace=True):
    TRACE_COUNTS["run_cell"] += 1
    return _run_cell(geom, p, n_iters, chunk, max_chunks, stride, backend,
                     metrics, with_trace)


def run_cell(geom: FabricGeometry, p: SimParams, n_iters,
             *, chunk: int = 2048, max_chunks: int = 98, stride: int = 8,
             backend: Optional[str] = None, metrics: bool = False,
             with_trace: bool = True):
    ensure_compile_cache()
    return _run_cell_jit(geom, p, n_iters, chunk=chunk,
                         max_chunks=max_chunks, stride=stride,
                         backend=resolve_step_backend(backend),
                         metrics=metrics, with_trace=with_trace)


@partial(jax.jit, static_argnames=("chunk", "max_chunks", "stride",
                                   "backend", "metrics", "with_trace"))
def _run_cells_jit(geom, params, n_iters, *, chunk, max_chunks, stride,
                   backend, metrics=False, with_trace=True):
    TRACE_COUNTS["run_cells"] += 1
    return jax.vmap(
        lambda pp: _run_cell(geom, pp, n_iters, chunk, max_chunks, stride,
                             backend, metrics, with_trace)
    )(params)


def run_cells(geom: FabricGeometry, params: SimParams, n_iters,
              *, chunk: int = 2048, max_chunks: int = 98, stride: int = 8,
              backend: Optional[str] = None, metrics: bool = False,
              with_trace: bool = True):
    """Batched engine: ``params`` has a leading cell axis on every leaf.
    One compile serves the whole grid; all cells advance in lockstep until
    the slowest finishes."""
    ensure_compile_cache()
    return _run_cells_jit(geom, params, n_iters, chunk=chunk,
                          max_chunks=max_chunks, stride=stride,
                          backend=resolve_step_backend(backend),
                          metrics=metrics, with_trace=with_trace)


@partial(jax.jit, static_argnames=("chunk", "max_chunks", "stride",
                                   "backend", "metrics", "with_trace"))
def _run_cells_hetero_jit(geoms, params, n_iters, *, chunk, max_chunks,
                          stride, backend, metrics=False, with_trace=True):
    TRACE_COUNTS["run_cells_hetero"] += 1

    def one_geom(g, ps):
        return jax.vmap(
            lambda pp: _run_cell(g, pp, n_iters, chunk, max_chunks, stride,
                                 backend, metrics, with_trace)
        )(ps)

    return jax.vmap(one_geom)(geoms, params)


def run_cells_hetero(geoms: FabricGeometry, params: SimParams, n_iters,
                     *, chunk: int = 2048, max_chunks: int = 98,
                     stride: int = 8, backend: Optional[str] = None,
                     mesh=None, shard_axis: str = "cell",
                     donate: bool = False, metrics: bool = False,
                     with_trace: bool = True):
    """Scale-batched engine: ``geoms`` is a stack of bucket-padded
    geometries (leading axis = topology cell) and ``params`` carries TWO
    leading axes — (topology cell, sub-cell) — so a whole
    (system x n_nodes) x (size x profile) grid runs in one compile.
    The nested vmap closes each geometry over its own sub-cell row, so
    path tables are not replicated per sub-cell.

    ``mesh`` partitions the batch across a 1-D device mesh with
    ``jax.shard_map`` instead: ``shard_axis='cell'`` splits the topology
    cells (geometries travel with their cells), ``'lane'`` splits the
    sub-cell lanes (geometries replicate — the mitigation search's
    candidate axis). Batches are padded to a mesh multiple by repeating
    lane 0 (finished lanes freeze under the vmapped while_loop, so real
    lanes are unaffected) and sliced back. NOTE: multi-device shard_map
    executables may differ from the single-device path by ~1 ulp in the
    float accumulators (XLA's partitioned compile reassociates — a
    measured, deterministic effect; DESIGN.md §14). The bit-exact
    multi-device path is launch.sweep's per-device dispatch."""
    ensure_compile_cache()
    backend = resolve_step_backend(backend)
    if mesh is None:
        return _run_cells_hetero_jit(geoms, params, n_iters, chunk=chunk,
                                     max_chunks=max_chunks, stride=stride,
                                     backend=backend, metrics=metrics,
                                     with_trace=with_trace)
    if shard_axis not in ("cell", "lane"):
        raise ValueError(f"shard_axis must be 'cell' or 'lane', "
                         f"got {shard_axis!r}")
    n_dev = int(mesh.devices.size)
    axis, = mesh.axis_names
    if shard_axis == "cell":
        n_real = _leading_dim(geoms)
        geoms = pad_batch(geoms, n_dev)
        params = pad_batch(params, n_dev)
    else:
        n_real = _leading_dim(params, axis=1)
        params = pad_batch(params, n_dev, axis=1)
    fn = _sharded_hetero_jit(mesh, axis, shard_axis, chunk, max_chunks,
                             stride, backend, donate, metrics, with_trace)
    out = fn(geoms, params, n_iters)
    take = 0 if shard_axis == "cell" else 1
    return {k: jax.lax.slice_in_dim(v, 0, n_real, axis=take)
            for k, v in out.items()}


def _leading_dim(tree, axis: int = 0) -> int:
    return int(jax.tree_util.tree_leaves(tree)[0].shape[axis])


def pad_batch(tree, multiple: int, axis: int = 0):
    """Pad every leaf's ``axis`` up to a multiple of ``multiple`` by
    repeating index 0 (a real, already-validated cell — never garbage:
    padded lanes run redundant work and are sliced off, and under the
    vmapped while_loop they cannot perturb real lanes)."""
    n = _leading_dim(tree, axis)
    target = -(-n // multiple) * multiple
    if target == n:
        return tree

    def pad(x):
        fill = np.repeat(np.take(np.asarray(x), [0], axis=axis),
                         target - n, axis=axis)
        return np.concatenate([np.asarray(x), fill], axis=axis)

    return jax.tree_util.tree_map(pad, tree)


# One jitted shard_map entry per (mesh, shard axis, static engine args):
# meshes are hashable, so the builder memoizes — re-launching on the same
# mesh reuses the executable (asserted via TRACE_COUNTS in test_sweep.py).
_SHARDED_JITS: dict = {}


def _sharded_hetero_jit(mesh, axis: str, shard_axis: str, chunk: int,
                        max_chunks: int, stride: int, backend: str,
                        donate: bool, metrics: bool = False,
                        with_trace: bool = True):
    key = (mesh, axis, shard_axis, chunk, max_chunks, stride, backend,
           donate, metrics, with_trace)
    fn = _SHARDED_JITS.get(key)
    if fn is not None:
        return fn
    from jax.sharding import PartitionSpec as P
    if shard_axis == "cell":
        in_specs = (P(axis), P(axis), P())
        out_specs = P(axis)
    else:  # lane: geometries replicate, sub-cell lanes split
        in_specs = (P(), P(None, axis), P())
        out_specs = P(None, axis)

    def sharded(geoms, params, n_iters):
        TRACE_COUNTS["run_cells_hetero_sharded"] += 1

        def shard(g, ps, ni):
            return jax.vmap(lambda gg, row: jax.vmap(
                lambda pp: _run_cell(gg, pp, ni, chunk, max_chunks,
                                     stride, backend, metrics,
                                     with_trace))(row))(g, ps)

        return jax.shard_map(shard, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)(
                                 geoms, params, n_iters)

    # buffer donation frees the params stack for the outputs; XLA CPU
    # does not implement donation (it would only warn), so gate on backend
    donate_argnums = (1,) if donate and jax.default_backend() != "cpu" \
        else ()
    fn = jax.jit(sharded, donate_argnums=donate_argnums)
    _SHARDED_JITS[key] = fn
    return fn


# --------------------------------------------------------------------------
# Result marshalling (host side)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SimResult:
    iter_times: np.ndarray  # (n_done - warmup,) seconds per victim iteration
    n_done: int
    mean_qdelay_s: float  # mean victim queueing delay per step
    victim_rate_trace: np.ndarray  # (T_sub,) aggregate victim goodput B/s
    time_trace: np.ndarray
    # False when the run finished too few iterations to discard the full
    # warmup prefix (n_done <= warmup): iter_times then holds only the
    # LAST completed iteration (closest to steady state) — a usable but
    # warmup-tainted estimate that callers must not report silently
    warmup_ok: bool = True


def _drop_warmup(times: np.ndarray, n_done: int, warmup: int):
    """Discard the warmup prefix of per-iteration times. When the run
    completed fewer than warmup+1 iterations, every iteration is warmup:
    keep only the last one (never silently average a warmup-dominated
    prefix — the pre-fix behavior) and report ``warmup_ok=False``."""
    if n_done > warmup:
        return times[warmup:], True
    return times[max(0, n_done - 1):], False


def summarize(out: dict, *, n_iters: int, warmup: int, dt: float,
              chunk: int, stride: int, cell: Optional[int] = None,
              job: int = 0) -> SimResult:
    """Build a :class:`SimResult` from (optionally batched) run outputs.
    ``job`` selects which job's program completions to report (0 = the
    primary job; background jobs may have closed fewer iterations)."""
    pick = (lambda x: np.asarray(x)) if cell is None else \
        (lambda x: np.asarray(x)[cell])
    n_done = min(int(pick(out["it"])[job]), n_iters, TDONE_SLOTS)
    t_done = pick(out["t_done"])[job][:n_done]
    iter_times = np.diff(np.concatenate([[0.0], t_done]))
    iter_times, warmup_ok = _drop_warmup(iter_times, n_done, warmup)
    total_t = float(pick(out["t"])) or 1e-9
    n_valid = int(pick(out["chunks"])) * (chunk // stride)
    trace = pick(out["trace"])[:n_valid]
    return SimResult(
        iter_times=iter_times,
        n_done=n_done,
        mean_qdelay_s=float(pick(out["qd_acc"])) / total_t,
        victim_rate_trace=trace,
        time_trace=np.arange(n_valid) * stride * dt,
        warmup_ok=warmup_ok,
    )


# --------------------------------------------------------------------------
# Object façade (compat): one geometry + one cc, sequential runs
# --------------------------------------------------------------------------


class FabricSim:
    """Thin wrapper over the pure-functional engine for single-experiment
    use. Sweeps should go through bench.run_grid, which batches cells."""

    def __init__(self, topo: Topology, flows: FlowSet, cc: CCParams,
                 routing: int = ROUTE_FIXED, dt: float = 10e-6,
                 maxmin_iters: int = 4, seed: int = 0):
        self.topo = topo
        self.flows = flows
        self.cc = cc
        self.dt = float(dt)
        # legacy routing flag (cc.ROUTE_*) -> traced policy id: FIXED
        # replays the host-side static table baked into the flow set
        self.policy = POLICY_ADAPTIVE if routing == ROUTE_ADAPTIVE \
            else POLICY_FIXED
        self.geom = make_geometry(topo, flows)

    def params(self, profile=None) -> SimParams:
        profile = profile or no_congestion()
        return make_params(
            self.cc, dt=self.dt, bytes_per_iter=self.flows.bytes_per_iter,
            host_caps=self.flows.host_caps, env=profile.params(),
            policy=self.policy)

    def run(self, *, n_iters: int = 60, warmup: int = 10, profile=None,
            max_steps: int = 400_000, chunk: int = 2048,
            trace_stride: int = 8) -> SimResult:
        """Run until ``n_iters`` victim iterations complete (or budget)."""
        check_iter_budget(n_iters)
        max_chunks = -(-max_steps // chunk)
        out = run_cell(self.geom, self.params(profile),
                       jnp.asarray(n_iters, jnp.int32), chunk=chunk,
                       max_chunks=max_chunks, stride=trace_stride)
        return summarize(out, n_iters=n_iters, warmup=warmup, dt=self.dt,
                         chunk=chunk, stride=trace_stride)
