"""JAX fluid flow-level fabric simulator.

Victim/aggressor flow sets traverse a :class:`Topology` under a congestion-
control model (cc.py) and a routing policy. The inner loop is a
``jax.lax.scan`` over fixed-dt timesteps:

  1. injection demand from per-flow CC rate limits,
  2. (adaptive routing) per-flow path choice by min queue occupancy,
  3. approximate max-min fair allocation (iterative proportional scaling),
  4. queue integration (offered load vs capacity) + ECN/credit signals,
  5. CC rate update per fabric model + optional backpressure spreading,
  6. victim-iteration completion bookkeeping (the paper's 1000-iteration
     protocol, scaled: see bench.py).

Approximations are documented in DESIGN.md; the validation targets are the
paper's observed *behaviors* (sawtooth, NSLB flat-line, incast collapse,
duty-cycle sensitivity), which emerge from the mechanisms, not from fitting.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fabric.cc import (CCParams, KIND_AI_ECN, KIND_DCQCN, KIND_IB,
                                  KIND_SLINGSHOT, ROUTE_ADAPTIVE, ROUTE_FIXED)
from repro.core.fabric.topology import Topology


@dataclasses.dataclass
class FlowSet:
    """Static flow structure for one experiment."""

    paths: np.ndarray  # (F, K, H) link ids, pad = L (sink)
    n_paths: np.ndarray  # (F,)
    path_len: np.ndarray  # (F, K) hop counts (for minimal-path bias)
    is_victim: np.ndarray  # (F,) bool
    bytes_per_iter: np.ndarray  # (F,) victim bytes; aggressors ~inf
    fixed_choice: np.ndarray  # (F,)
    host_caps: np.ndarray  # (F,) injection-link capacity per flow
    src_id: np.ndarray  # (F,) source node (NIC injection limiting)

    @property
    def n_flows(self) -> int:
        return len(self.is_victim)


def pack_paths(paths_per_flow: List[List[List[int]]], sink: int, k_max: int = 4):
    F = len(paths_per_flow)
    H = max((len(p) for ps in paths_per_flow for p in ps), default=1)
    out = np.full((F, k_max, H), sink, np.int32)
    n_paths = np.zeros((F,), np.int32)
    plen = np.zeros((F, k_max), np.int32)
    for f, ps in enumerate(paths_per_flow):
        ps = ps[:k_max] if ps else [[]]
        n_paths[f] = len(ps)
        for k, p in enumerate(ps):
            out[f, k, : len(p)] = p
            plen[f, k] = len(p)
    return out, n_paths, plen


@dataclasses.dataclass
class SimResult:
    iter_times: np.ndarray  # (n_done,) seconds per victim iteration
    n_done: int
    mean_qdelay_s: float  # mean victim queueing delay per step
    victim_rate_trace: np.ndarray  # (T_sub,) aggregate victim goodput B/s
    time_trace: np.ndarray


class FabricSim:
    def __init__(self, topo: Topology, flows: FlowSet, cc: CCParams,
                 routing: int = ROUTE_FIXED, dt: float = 10e-6,
                 maxmin_iters: int = 4, seed: int = 0):
        self.topo = topo
        self.flows = flows
        self.cc = cc
        self.routing = routing
        self.dt = float(dt)
        self.maxmin_iters = maxmin_iters
        L = len(topo.caps)
        self.L = L
        self.caps_pad = jnp.asarray(
            np.concatenate([topo.caps, [np.inf]]), jnp.float32)
        self.caps_finite = jnp.asarray(
            np.concatenate([topo.caps, [1.0]]), jnp.float32)
        # link <-> switch adjacency for backpressure spreading
        sw_ids: dict = {}
        dst_sw = np.zeros(L + 1, np.int32)
        src_sw = np.zeros(L + 1, np.int32)
        for li, (a, b) in enumerate(topo.link_names):
            if not (isinstance(b, tuple) and b[0] == "h"):
                dst_sw[li] = 1 + sw_ids.setdefault(b, len(sw_ids))
            if not (isinstance(a, tuple) and a[0] == "h"):
                src_sw[li] = 1 + sw_ids.setdefault(a, len(sw_ids))
        self.n_sw = len(sw_ids) + 2  # 0 == "no switch" (host endpoints)
        self.dst_sw = jnp.asarray(dst_sw, jnp.int32)
        self.src_sw = jnp.asarray(src_sw, jnp.int32)

        self.paths = jnp.asarray(flows.paths)
        self.n_paths = jnp.asarray(flows.n_paths)
        # sprayed "home" path per flow: deterministic hash spread over the
        # candidates so concurrent flows do not herd onto one port
        F = flows.n_flows
        spray = (np.arange(F, dtype=np.int64) * 2654435761 % (1 << 31)) \
            % np.maximum(flows.n_paths, 1)
        self.spray_choice = jnp.asarray(spray.astype(np.int32))
        self.path_len = jnp.asarray(flows.path_len, jnp.float32)
        self.is_victim = jnp.asarray(flows.is_victim)
        self.bytes_per_iter = jnp.asarray(flows.bytes_per_iter, jnp.float32)
        self.fixed_choice = jnp.asarray(flows.fixed_choice)
        self.host_caps = jnp.asarray(flows.host_caps, jnp.float32)
        self.src_id = jnp.asarray(flows.src_id, jnp.int32)
        self.n_src = int(flows.src_id.max()) + 1
        self._step_chunk = jax.jit(partial(self._run_chunk))

    # ------------------------------------------------------------------
    def init_state(self, max_iters: int):
        F = self.flows.n_flows
        cc = self.cc
        return {
            "c": self.host_caps,
            "rem": jnp.where(self.is_victim, self.bytes_per_iter, 1e30),
            "q": jnp.zeros((self.L + 1,), jnp.float32),
            "arr": jnp.zeros((self.L + 1,), jnp.float32),
            "thresh": jnp.full((self.L + 1,), cc.kmin * cc.qmax_bytes,
                               jnp.float32),
            "last_dec": jnp.zeros((F,), jnp.float32),
            "it": jnp.zeros((), jnp.int32),
            "t_done": jnp.zeros((max_iters,), jnp.float32),
            "qd_acc": jnp.zeros((), jnp.float32),
            "t": jnp.zeros((), jnp.float32),
        }

    # ------------------------------------------------------------------
    def _step(self, state, aggr_on):
        cc, dt = self.cc, self.dt
        F = self.flows.n_flows
        active = (self.is_victim | (aggr_on > 0)) & (state["rem"] > 0)
        inject = state["c"] * active
        # NIC limit: a source's flows share its injection link
        src_load = jnp.zeros((self.n_src,), jnp.float32).at[self.src_id].add(
            inject)
        scale = jnp.minimum(1.0, self.host_caps
                            / jnp.maximum(src_load[self.src_id], 1.0))
        inject = inject * scale

        # ---- routing: spray + congestion-triggered rerouting ----
        # Production AR does NOT send every flow to the globally least-loaded
        # port (that herds and oscillates); flows keep a sprayed home path
        # and move off it only when its occupancy is clearly worse than the
        # best alternative (hysteresis).
        if self.routing == ROUTE_ADAPTIVE:
            occ = state["q"] / cc.qmax_bytes
            score = jnp.max(occ[self.paths], axis=2) \
                + 0.05 * self.path_len / jnp.maximum(self.path_len[:, :1], 1)
            score = jnp.where(jnp.arange(self.paths.shape[1])[None, :]
                              < self.n_paths[:, None], score, jnp.inf)
            best = jnp.argmin(score, axis=1)
            home = self.spray_choice
            home_score = jnp.take_along_axis(score, home[:, None], 1)[:, 0]
            best_score = jnp.min(score, axis=1)
            choice = jnp.where(home_score > best_score + 0.10, best, home)
        else:
            choice = self.fixed_choice
        plinks = jnp.take_along_axis(
            self.paths, choice[:, None, None], axis=1)[:, 0]  # (F, H)
        valid = plinks < self.L

        # ---- lossless backpressure (credit/PFC head-of-line stall) ----
        # A switch whose egress queue saturates exhausts upstream credits /
        # emits PFC pauses; ingress links feeding that switch lose service,
        # stalling flows that traverse it (victims included). The stall is
        # weighted by the saturated egresses' share of the switch's traffic:
        # pause frames only cover buffer pools filled by hot-destined
        # packets, so a switch with one hot egress among many mostly-idle
        # ones only mildly degrades unrelated ingress traffic. This is the
        # congestion-tree mechanism behind the paper's Incast collapse.
        # Slingshot tracks per-flow state -> hol_factor == 0 (no stall).
        caps_eff = self.caps_finite
        if cc.hol_factor > 0.0:
            occ_prev = state["q"] / cc.qmax_bytes
            sat_l = jnp.clip((occ_prev - cc.hol_start)
                             / (1.0 - cc.hol_start), 0.0, 1.0)
            # share weighted by buffered bytes: traffic draining through
            # idle egresses holds no buffer and casts no backpressure
            hot_q = jnp.zeros((self.n_sw,), jnp.float32).at[
                self.src_sw].add(state["q"] * sat_l)
            tot_q = jnp.zeros((self.n_sw,), jnp.float32).at[
                self.src_sw].add(state["q"])
            share = hot_q / jnp.maximum(tot_q, 1.0)
            sw_sat = jnp.zeros((self.n_sw,), jnp.float32).at[
                self.src_sw].max(sat_l)
            stall = 1.0 - cc.hol_factor * sw_sat * share
            stall = stall.at[0].set(1.0)  # 0 == host endpoint
            caps_eff = self.caps_finite * stall[self.dst_sw]

        # ---- staged propagation + queues ----
        # Paths are feed-forward by fabric stage (host -> leaf -> spine ->
        # leaf -> host), so a flow's arrival rate at hop h is its injection
        # rate scaled down by every oversubscribed upstream hop (FIFO fluid
        # sharing). Queues then build only where arrivals genuinely exceed
        # service — an aggressor that is bottlenecked at its own NIC no
        # longer floods transit queues with phantom demand.
        r = inject
        arrival = jnp.zeros((self.L + 1,), jnp.float32)
        for h in range(plinks.shape[1]):
            lk = plinks[:, h]
            contrib = r * valid[:, h]
            load = jnp.zeros((self.L + 1,), jnp.float32).at[lk].add(contrib)
            arrival = arrival + load
            over = jnp.maximum(load / caps_eff, 1.0)
            r = jnp.where(valid[:, h], r / over[lk], r)
        a = r  # achieved end-to-end rate
        q = jnp.clip(state["q"] + (arrival * (1.0 + cc.burst_jitter)
                                   - caps_eff) * dt,
                     0.0, cc.qmax_bytes)
        q = q.at[self.L].set(0.0)

        # ---- signals ----
        thresh = state["thresh"]
        if cc.thresh_adapt:
            # AI-ECN: threshold tracks a fraction of the observed queue so
            # marking strength is proportional, not bang-bang.
            thresh = jnp.clip(0.9 * thresh + 0.1 * (0.5 * q + cc.kmin
                                                    * cc.qmax_bytes),
                              0.05 * cc.qmax_bytes, cc.kmax * cc.qmax_bytes)
        over_thresh = q > thresh
        fmark = jnp.any(over_thresh[plinks] & valid, axis=1)
        # proportional mark strength (ai_ecn) in [0, 1]
        strength_l = jnp.clip((q - thresh)
                              / (cc.kmax * cc.qmax_bytes - thresh + 1.0),
                              0.0, 1.0)
        fstrength = jnp.max(jnp.where(valid, strength_l[plinks], 0.0), axis=1)

        # ---- CC update ----
        c = state["c"]
        can_dec = state["last_dec"] >= cc.cc_interval_s
        inc = cc.rai_frac * self.host_caps * (dt / 1e-3)
        if cc.kind == KIND_DCQCN:
            dec = fmark & can_dec
            c = jnp.where(dec, c * cc.md, c + inc)
        elif cc.kind == KIND_AI_ECN:
            dec = fmark & can_dec
            c = jnp.where(dec, c * (1.0 - (1.0 - cc.md) * fstrength), c + inc)
        elif cc.kind == KIND_IB:
            # credit semantics: the send window tracks what actually drains
            # (hop-by-hop credits), SYMMETRICALLY — senders pause when the
            # downstream buffer fills and resume the instant it drains. The
            # overshoot keeps the hot buffer fed (full, not at the mark
            # point); FECN/BECN marking is the slower outer loop.
            f = 1.0 - jnp.exp(-dt / cc.follow_tau_s)
            c = (1 - f) * c + f * jnp.maximum(
                a * cc.follow_gain, cc.min_rate_frac * self.host_caps)
            dec = fmark & can_dec
            c = jnp.where(dec, c * cc.md, c + inc)
        else:  # slingshot: throttle only flows actually bottlenecked
            f = 1.0 - jnp.exp(-dt / cc.follow_tau_s)
            bottlenecked = fmark & (a < 0.95 * c)
            c = jnp.where(bottlenecked,
                          (1 - f) * c + f * a * cc.follow_gain,
                          c + inc)
            dec = bottlenecked & can_dec
        # CC state only evolves for flows that are actually transmitting —
        # an idle flow (finished its iteration early, or paused aggressor)
        # keeps its rate limit.
        c = jnp.where(active, c, state["c"])
        dec = dec & active
        c = jnp.clip(c, cc.min_rate_frac * self.host_caps, self.host_caps)
        last_dec = jnp.where(dec, 0.0, state["last_dec"] + dt)

        # ---- progress + iteration bookkeeping ----
        rem = state["rem"] - a * dt
        vdone = ~jnp.any(self.is_victim & (rem > 0))
        t_new = state["t"] + dt
        it = state["it"]
        slot = jnp.minimum(it, state["t_done"].shape[0] - 1)
        t_done = jnp.where(vdone, state["t_done"].at[slot].set(t_new),
                           state["t_done"])
        it = it + vdone.astype(jnp.int32)
        rem = jnp.where(vdone & self.is_victim, self.bytes_per_iter, rem)
        # synchronization gap between victim iterations partially drains queues
        if cc.iter_drain < 1.0:
            q = jnp.where(vdone, q * cc.iter_drain, q)

        # queueing delay experienced by victim flows (seconds)
        qdel = jnp.max(jnp.where(valid, (q / self.caps_finite)[plinks], 0.0),
                       axis=1)
        mean_qdel = jnp.sum(qdel * self.is_victim) / jnp.maximum(
            jnp.sum(self.is_victim), 1)
        vict_goodput = jnp.sum(a * self.is_victim)

        new_state = {"c": c, "rem": rem, "q": q, "arr": arrival,
                     "thresh": thresh,
                     "last_dec": last_dec, "it": it, "t_done": t_done,
                     "qd_acc": state["qd_acc"] + mean_qdel * dt, "t": t_new}
        return new_state, (vict_goodput, mean_qdel)

    def _run_chunk(self, state, envelope):
        return jax.lax.scan(self._step, state, envelope)

    # ------------------------------------------------------------------
    def run(self, *, n_iters: int = 60, warmup: int = 10,
            envelope_fn=None, max_steps: int = 400_000,
            chunk: int = 2048, trace_stride: int = 8) -> SimResult:
        """Run until ``n_iters`` victim iterations complete (or budget)."""
        state = self.init_state(n_iters + 8)
        traces, times = [], []
        steps = 0
        while steps < max_steps:
            t0 = steps * self.dt
            if envelope_fn is None:
                env = np.ones((chunk,), np.float32)
            else:
                env = envelope_fn(t0, chunk, self.dt).astype(np.float32)
            state, (gp, _) = self._step_chunk(state, jnp.asarray(env))
            traces.append(np.asarray(gp[::trace_stride]))
            times.append(t0 + np.arange(0, chunk, trace_stride) * self.dt)
            steps += chunk
            if int(state["it"]) >= n_iters:
                break
        n_done = min(int(state["it"]), n_iters)
        t_done = np.asarray(state["t_done"])[:n_done]
        iter_times = np.diff(np.concatenate([[0.0], t_done]))
        iter_times = iter_times[warmup:] if n_done > warmup else iter_times
        total_t = float(state["t"]) or 1e-9
        return SimResult(
            iter_times=iter_times,
            n_done=n_done,
            mean_qdelay_s=float(state["qd_acc"]) / total_t,
            victim_rate_trace=np.concatenate(traces) if traces else np.zeros(0),
            time_trace=np.concatenate(times) if times else np.zeros(0),
        )
