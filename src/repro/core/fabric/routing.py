"""Path-assignment policies (paper §II load balancing).

Static (host-side, resolved ahead of time):

* ``deterministic`` — always the first candidate path (legacy IB static).
* ``ecmp``          — splitmix64 hash of (salt, src, dst); hash collisions
                      leave links idle while others oversubscribe (paper
                      refs [9]-[13]). The mixer is an explicit integer
                      permutation, so path choices are reproducible across
                      platforms and unit-testable against fixed
                      expectations (Python's builtin ``hash`` is neither).
* ``nslb``          — Network Scale Load Balance (Huawei CE9855, ref [22]):
                      a flow-matrix computation assigns collision-free
                      uplinks per (source edge, destination edge) pair;
                      modeled as greedy min-load assignment over candidate
                      paths, processed per source so concurrent flows from
                      one source spread across distinct uplinks.

Traced (per-cell data, dispatched by ``lax.switch`` inside the simulator
step — the mitigation lab sweeps these as plain ``SimParams`` knobs, so a
grid mixing routing policies batches under one compile):

* ``POLICY_FIXED``    — the host-side static assignment baked into the
  geometry (whatever ``static_routing`` mode built it).
* ``POLICY_ECMP`` / ``POLICY_NSLB`` — the ecmp / nslb tables, selectable
  at trace time regardless of which mode built ``fixed_choice`` (bit-
  identical to a legacy geometry built with that mode).
* ``POLICY_ADAPTIVE`` — min-queue rerouting with a sprayed home path and
  hysteresis (IB AR / Slingshot), evaluated per step.
* ``POLICY_FLOWLET``  — flowlet re-pathing: a flow keeps its current path
  while transmitting and re-picks the least-loaded candidate when its
  idle gap exceeds a traced threshold (``SimParams.flowlet_gap_s``) —
  burst boundaries are the only safe re-ordering points.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

# Traced routing-policy ids (SimParams.policy; lax.switch in the step).
POLICY_FIXED = 0
POLICY_ECMP = 1
POLICY_NSLB = 2
POLICY_ADAPTIVE = 3
POLICY_FLOWLET = 4
N_POLICIES = 5

POLICY_NAMES: Dict[int, str] = {
    POLICY_FIXED: "fixed", POLICY_ECMP: "ecmp", POLICY_NSLB: "nslb",
    POLICY_ADAPTIVE: "adaptive", POLICY_FLOWLET: "flowlet",
}

# static_routing mode -> the traced policy that reproduces it bit-for-bit
STATIC_MODE_POLICY: Dict[str, int] = {
    "deterministic": POLICY_FIXED, "ecmp": POLICY_ECMP, "nslb": POLICY_NSLB,
}

_U64 = np.uint64
_SPLITMIX_GAMMA = 0x9E3779B97F4A7C15
_SPLITMIX_M1 = 0xBF58476D1CE4E5B9
_SPLITMIX_M2 = 0x94D049BB133111EB


def splitmix64(x) -> np.ndarray:
    """SplitMix64 finalizer: an explicit, platform-independent 64-bit
    mixer (Steele et al.). Accepts scalars or uint64 arrays; all
    arithmetic wraps mod 2^64 by construction."""
    with np.errstate(over="ignore"):  # wrap-around IS the algorithm
        x = (np.asarray(x, _U64) + _U64(_SPLITMIX_GAMMA))
        x = (x ^ (x >> _U64(30))) * _U64(_SPLITMIX_M1)
        x = (x ^ (x >> _U64(27))) * _U64(_SPLITMIX_M2)
        return x ^ (x >> _U64(31))


def ecmp_hash(src, dst, salt) -> np.ndarray:
    """Deterministic ECMP hash of (src, dst) under ``salt`` — two
    splitmix64 rounds so src and dst both avalanche. Vectorized over
    src/dst arrays."""
    s = np.asarray(src, _U64)
    d = np.asarray(dst, _U64)
    key = (splitmix64(_U64(salt)) << _U64(32)) ^ (s << _U64(1)) ^ d
    return splitmix64(splitmix64(key) ^ d)


def assign_paths(mode: str, flows_src_dst, paths_per_flow, n_links: int,
                 seed: int = 0) -> np.ndarray:
    F = len(paths_per_flow)
    choice = np.zeros((F,), np.int32)
    if mode == "deterministic":
        return choice
    if mode == "ecmp":
        if F == 0:
            return choice
        src = np.array([s for s, _ in flows_src_dst], np.uint64)
        dst = np.array([d for _, d in flows_src_dst], np.uint64)
        n = np.maximum([len(p) for p in paths_per_flow], 1).astype(np.uint64)
        return (ecmp_hash(src, dst, seed) % n).astype(np.int32)
    if mode == "nslb":
        # flow-matrix style: greedy min-max link usage, grouped by source so
        # one source's concurrent flows land on distinct uplinks.
        usage = np.zeros((n_links + 1,), np.int64)
        order = sorted(range(F), key=lambda f: (flows_src_dst[f][0],
                                                flows_src_dst[f][1]))
        for f in order:
            ps = paths_per_flow[f]
            if not ps:
                continue
            best_k, best_cost = 0, None
            for k, p in enumerate(ps):
                cost = (max((usage[l] for l in p), default=0),
                        sum(usage[l] for l in p))
                if best_cost is None or cost < best_cost:
                    best_k, best_cost = k, cost
            choice[f] = best_k
            for l in ps[best_k]:
                usage[l] += 1
        return choice
    raise KeyError(mode)
