"""Static path-assignment policies (paper §II load balancing).

* ``deterministic`` — always the first candidate path (legacy IB static).
* ``ecmp``          — hash of (src, dst); hash collisions leave links idle
                      while others oversubscribe (paper refs [9]-[13]).
* ``nslb``          — Network Scale Load Balance (Huawei CE9855, ref [22]):
                      a flow-matrix computation assigns collision-free
                      uplinks per (source edge, destination edge) pair;
                      modeled as greedy min-load assignment over candidate
                      paths, processed per source so concurrent flows from
                      one source spread across distinct uplinks.

Adaptive routing (IB AR / Slingshot) is *dynamic* and lives in the simulator
step (ROUTE_ADAPTIVE); these are the static policies resolved ahead of time.
"""
from __future__ import annotations

import numpy as np


def assign_paths(mode: str, flows_src_dst, paths_per_flow, n_links: int,
                 seed: int = 0) -> np.ndarray:
    F = len(paths_per_flow)
    choice = np.zeros((F,), np.int32)
    if mode == "deterministic":
        return choice
    if mode == "ecmp":
        rng = np.random.RandomState(seed)
        salt = rng.randint(1 << 30)
        for f, (s, d) in enumerate(flows_src_dst):
            n = max(1, len(paths_per_flow[f]))
            choice[f] = (hash((s, d, salt)) & 0x7FFFFFFF) % n
        return choice
    if mode == "nslb":
        # flow-matrix style: greedy min-max link usage, grouped by source so
        # one source's concurrent flows land on distinct uplinks.
        usage = np.zeros((n_links + 1,), np.int64)
        order = sorted(range(F), key=lambda f: (flows_src_dst[f][0],
                                                flows_src_dst[f][1]))
        for f in order:
            ps = paths_per_flow[f]
            if not ps:
                continue
            best_k, best_cost = 0, None
            for k, p in enumerate(ps):
                cost = (max((usage[l] for l in p), default=0),
                        sum(usage[l] for l in p))
                if best_cost is None or cost < best_cost:
                    best_k, best_cost = k, cost
            choice[f] = best_k
            for l in ps[best_k]:
                usage[l] += 1
        return choice
    raise KeyError(mode)
