"""Path-assignment policies (paper §II load balancing).

Static (host-side, resolved ahead of time):

* ``deterministic`` — always the first candidate path (legacy IB static).
* ``ecmp``          — splitmix64 hash of (salt, src, dst); hash collisions
                      leave links idle while others oversubscribe (paper
                      refs [9]-[13]). The mixer is an explicit integer
                      permutation, so path choices are reproducible across
                      platforms and unit-testable against fixed
                      expectations (Python's builtin ``hash`` is neither).
* ``nslb``          — Network Scale Load Balance (Huawei CE9855, ref [22]):
                      a flow-matrix computation assigns collision-free
                      uplinks per (source edge, destination edge) pair;
                      modeled as greedy min-load assignment over candidate
                      paths, processed per source so concurrent flows from
                      one source spread across distinct uplinks.

Traced (per-cell data, dispatched by ``lax.switch`` inside the simulator
step — the mitigation lab sweeps these as plain ``SimParams`` knobs, so a
grid mixing routing policies batches under one compile):

* ``POLICY_FIXED``    — the host-side static assignment baked into the
  geometry (whatever ``static_routing`` mode built it).
* ``POLICY_ECMP`` / ``POLICY_NSLB`` — the ecmp / nslb tables, selectable
  at trace time regardless of which mode built ``fixed_choice`` (bit-
  identical to a legacy geometry built with that mode).
* ``POLICY_ADAPTIVE`` — min-queue rerouting with a sprayed home path and
  hysteresis (IB AR / Slingshot), evaluated per step.
* ``POLICY_FLOWLET``  — flowlet re-pathing: a flow keeps its current path
  while transmitting and re-picks the least-loaded candidate when its
  idle gap exceeds a traced threshold (``SimParams.flowlet_gap_s``) —
  burst boundaries are the only safe re-ordering points.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

# Traced routing-policy ids (SimParams.policy; lax.switch in the step).
POLICY_FIXED = 0
POLICY_ECMP = 1
POLICY_NSLB = 2
POLICY_ADAPTIVE = 3
POLICY_FLOWLET = 4
N_POLICIES = 5

POLICY_NAMES: Dict[int, str] = {
    POLICY_FIXED: "fixed", POLICY_ECMP: "ecmp", POLICY_NSLB: "nslb",
    POLICY_ADAPTIVE: "adaptive", POLICY_FLOWLET: "flowlet",
}

# static_routing mode -> the traced policy that reproduces it bit-for-bit
STATIC_MODE_POLICY: Dict[str, int] = {
    "deterministic": POLICY_FIXED, "ecmp": POLICY_ECMP, "nslb": POLICY_NSLB,
}

_U64 = np.uint64
_SPLITMIX_GAMMA = 0x9E3779B97F4A7C15
_SPLITMIX_M1 = 0xBF58476D1CE4E5B9
_SPLITMIX_M2 = 0x94D049BB133111EB


def splitmix64(x) -> np.ndarray:
    """SplitMix64 finalizer: an explicit, platform-independent 64-bit
    mixer (Steele et al.). Accepts scalars or uint64 arrays; all
    arithmetic wraps mod 2^64 by construction."""
    with np.errstate(over="ignore"):  # wrap-around IS the algorithm
        x = (np.asarray(x, _U64) + _U64(_SPLITMIX_GAMMA))
        x = (x ^ (x >> _U64(30))) * _U64(_SPLITMIX_M1)
        x = (x ^ (x >> _U64(27))) * _U64(_SPLITMIX_M2)
        return x ^ (x >> _U64(31))


def splitmix64_hilo(hi, lo, xp=np):
    """:func:`splitmix64` on (hi, lo) uint32 limb pairs — the SAME mixer,
    emulated in 32-bit arithmetic so it runs inside a JAX trace (jnp has
    no uint64 without the global x64 flag; pass ``xp=jax.numpy``). Pinned
    equal to the uint64 reference in tests/test_fabric.py. All uint32
    arithmetic wraps mod 2^32 by construction (that IS the algorithm).

    Returns the mixed value as a (hi, lo) uint32 pair."""
    u32 = lambda v: xp.asarray(v, xp.uint32)
    mask16 = u32(0xFFFF)

    def mul32(a, b32):
        # full 64-bit product of two uint32 via 16-bit limbs -> (hi, lo)
        a0, a1 = a & mask16, a >> u32(16)
        b0, b1 = b32 & mask16, b32 >> u32(16)
        ll = a0 * b0
        mid = a0 * b1 + a1 * b0          # may wrap once: detect the carry
        carry_mid = (mid < a0 * b1).astype(xp.uint32)
        lo_ = ll + ((mid & mask16) << u32(16))
        carry_lo = (lo_ < ll).astype(xp.uint32)
        hi_ = a1 * b1 + (mid >> u32(16)) + (carry_mid << u32(16)) + carry_lo
        return hi_, lo_

    def add64(hi_, lo_, c_hi, c_lo):
        s_lo = lo_ + u32(c_lo)
        carry = (s_lo < lo_).astype(xp.uint32)
        return hi_ + u32(c_hi) + carry, s_lo

    def shr64_xor(hi_, lo_, k):
        # x ^= x >> k for k in (27, 30, 31) — always 0 < k < 32
        s_lo = (lo_ >> u32(k)) | (hi_ << u32(32 - k))
        s_hi = hi_ >> u32(k)
        return hi_ ^ s_hi, lo_ ^ s_lo

    def mul64(hi_, lo_, m):
        m_hi, m_lo = (m >> 32) & 0xFFFFFFFF, m & 0xFFFFFFFF
        p_hi, p_lo = mul32(lo_, u32(m_lo))
        return p_hi + lo_ * u32(m_hi) + hi_ * u32(m_lo), p_lo

    hi, lo = u32(hi), u32(lo)
    if xp is np:
        ctx = np.errstate(over="ignore")  # wrap-around IS the algorithm
    else:  # pragma: no cover - trivial null context for jnp
        import contextlib
        ctx = contextlib.nullcontext()
    with ctx:
        hi, lo = add64(hi, lo, _SPLITMIX_GAMMA >> 32,
                       _SPLITMIX_GAMMA & 0xFFFFFFFF)
        hi, lo = shr64_xor(hi, lo, 30)
        hi, lo = mul64(hi, lo, _SPLITMIX_M1)
        hi, lo = shr64_xor(hi, lo, 27)
        hi, lo = mul64(hi, lo, _SPLITMIX_M2)
        hi, lo = shr64_xor(hi, lo, 31)
    return hi, lo


def ecmp_hash(src, dst, salt) -> np.ndarray:
    """Deterministic ECMP hash of (src, dst) under ``salt`` — two
    splitmix64 rounds so src and dst both avalanche. Vectorized over
    src/dst arrays."""
    s = np.asarray(src, _U64)
    d = np.asarray(dst, _U64)
    key = (splitmix64(_U64(salt)) << _U64(32)) ^ (s << _U64(1)) ^ d
    return splitmix64(splitmix64(key) ^ d)


def assign_paths(mode: str, flows_src_dst, paths_per_flow, n_links: int,
                 seed: int = 0) -> np.ndarray:
    F = len(paths_per_flow)
    choice = np.zeros((F,), np.int32)
    if mode == "deterministic":
        return choice
    if mode == "ecmp":
        if F == 0:
            return choice
        src = np.array([s for s, _ in flows_src_dst], np.uint64)
        dst = np.array([d for _, d in flows_src_dst], np.uint64)
        n = np.maximum([len(p) for p in paths_per_flow], 1).astype(np.uint64)
        return (ecmp_hash(src, dst, seed) % n).astype(np.int32)
    if mode == "nslb":
        # flow-matrix style: greedy min-max link usage, grouped by source so
        # one source's concurrent flows land on distinct uplinks.
        usage = np.zeros((n_links + 1,), np.int64)
        order = sorted(range(F), key=lambda f: (flows_src_dst[f][0],
                                                flows_src_dst[f][1]))
        for f in order:
            ps = paths_per_flow[f]
            if not ps:
                continue
            best_k, best_cost = 0, None
            for k, p in enumerate(ps):
                cost = (max((usage[l] for l in p), default=0),
                        sum(usage[l] for l in p))
                if best_cost is None or cost < best_cost:
                    best_k, best_cost = k, cost
            choice[f] = best_k
            for l in ps[best_k]:
                usage[l] += 1
        return choice
    raise KeyError(mode)
