"""Evaluated-system presets (paper Table I), scaled to the allocation sizes
used in the paper's experiments (up to 256 nodes on the production machines).

Link rates follow Table I; effective per-node injection bandwidth:
  Leonardo  HDR   2x dual-port HDR100 -> 400 Gb/s higher-radix Dragonfly+
  CRESCO8   NDR   dual-port CX-7      -> 200 Gb/s, 1.67:1 blocking fat-tree
  LUMI      SS    4x200 Gb/s          -> 800 Gb/s Dragonfly
  HAICGU    EDR/RoCE 100 GE, single switch per 10-node partition
  Nanjing   RoCE-NSLB 200 GE, 2-leaf/2-spine
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.fabric import cc as cc_lib
from repro.core.fabric import topology as topo_lib
from repro.core.fabric.cc import CCParams, ROUTE_ADAPTIVE, ROUTE_FIXED
from repro.core.fabric.routing import (POLICY_ADAPTIVE, POLICY_FIXED,
                                       STATIC_MODE_POLICY)


@dataclasses.dataclass(frozen=True)
class SystemPreset:
    name: str
    fabric: str
    make_topology: Callable[[int], topo_lib.Topology]
    cc: CCParams
    routing: int  # simulator dynamic routing mode
    static_routing: str  # path pre-assignment policy
    machine_nodes: int = 0  # full-machine size; 0 = allocation-sized testbed
    k_max: int = 4  # AR group size: candidate paths a flow may use
    description: str = ""


def leonardo() -> SystemPreset:
    return SystemPreset(
        name="leonardo", fabric="HDR InfiniBand",
        make_topology=lambda n: topo_lib.dragonfly_plus(
            n, leaves_per_group=4, spines_per_group=4, nodes_per_leaf=8,
            host_gbit=400.0, global_gbit=400.0, name="leonardo"),
        cc=cc_lib.infiniband("hdr"), routing=ROUTE_ADAPTIVE,
        static_routing="deterministic", machine_nodes=3456, k_max=7,
        description="BullSequana X2135, Dragonfly+, adaptive routing; "
                    "256 nodes = 7.4% of the Booster partition")


def cresco8() -> SystemPreset:
    return SystemPreset(
        name="cresco8", fabric="NDR InfiniBand",
        make_topology=lambda n: topo_lib.fat_tree(
            n, nodes_per_leaf=16, taper=1.67, host_gbit=200.0,
            name="cresco8"),
        cc=cc_lib.infiniband("ndr"), routing=ROUTE_ADAPTIVE,
        static_routing="deterministic", machine_nodes=760, k_max=4,
        description="1.67:1 blocking fat-tree (10 spines; AR group of 4); "
                    "256 nodes = 33.7% of machine")


def lumi() -> SystemPreset:
    return SystemPreset(
        name="lumi", fabric="Cray Slingshot",
        make_topology=lambda n: topo_lib.dragonfly(
            n, routers_per_group=8, nodes_per_router=4, host_gbit=800.0,
            global_gbit=800.0, name="lumi"),
        cc=cc_lib.slingshot(), routing=ROUTE_ADAPTIVE,
        static_routing="deterministic", machine_nodes=2978, k_max=8,
        description="HPE Cray EX, Dragonfly, per-flow congestion management, "
                    "global-aware fine-grained AR; 256 nodes = 8.6% of the "
                    "GPU partition")


def haicgu_ib() -> SystemPreset:
    return SystemPreset(
        name="haicgu_ib", fabric="EDR InfiniBand",
        make_topology=lambda n: topo_lib.single_switch(
            n, link_gbit=100.0, name="haicgu_ib"),
        cc=cc_lib.infiniband("edr"), routing=ROUTE_FIXED,
        static_routing="deterministic",
        description="TaiShan 200 nodes, Mellanox EDR single switch")


def haicgu_ce8850() -> SystemPreset:
    return SystemPreset(
        name="haicgu_ce8850", fabric="RoCE (CE8850)",
        make_topology=lambda n: topo_lib.single_switch(
            n, link_gbit=100.0, name="haicgu_ce8850"),
        cc=cc_lib.dcqcn(), routing=ROUTE_FIXED,
        static_routing="deterministic",
        description="CE8850 DCQCN: unstable feedback -> sawtooth (Obs. 1)")


def nanjing(nslb: bool = True) -> SystemPreset:
    return SystemPreset(
        name="nanjing_nslb" if nslb else "nanjing_ecmp",
        fabric="RoCE-NSLB (CE9855)",
        make_topology=lambda n: topo_lib.leaf_spine(
            n, n_leaf=2, n_spine=2, host_gbit=200.0, up_gbit=200.0,
            name="nanjing"),
        cc=cc_lib.ai_ecn(), routing=ROUTE_FIXED,
        static_routing="nslb" if nslb else "ecmp",
        description="2-leaf/2-spine 200GE; NSLB flow-matrix load balancing")


def tpu_pod(nx: int = 16, ny: int = 16) -> SystemPreset:
    """The target platform: deterministic-routing 2D torus (ICI)."""
    return SystemPreset(
        name="tpu_pod", fabric="TPU ICI",
        make_topology=lambda n: topo_lib.torus2d(nx, ny, link_gbit=400.0,
                                                 name="tpu_pod"),
        cc=cc_lib.slingshot(), routing=ROUTE_FIXED,
        static_routing="deterministic",
        description="2D torus, deterministic DOR routing — congestion must "
                    "be avoided statically by the collective schedule")


PRESETS = {
    "leonardo": leonardo,
    "cresco8": cresco8,
    "lumi": lumi,
    "haicgu_ib": haicgu_ib,
    "haicgu_ce8850": haicgu_ce8850,
    "nanjing_nslb": lambda: nanjing(True),
    "nanjing_ecmp": lambda: nanjing(False),
    "tpu_pod": tpu_pod,
}


def get_system(name: str) -> SystemPreset:
    return PRESETS[name]()


def default_policy(system: SystemPreset) -> int:
    """Traced routing-policy id equivalent to the preset's legacy
    (routing, static_routing) pair — bit-identical by construction:
    adaptive presets route per-step; fixed presets replay the static
    table their ``static_routing`` mode produced (which the traced
    ecmp/nslb policies read straight from the geometry)."""
    if system.routing == ROUTE_ADAPTIVE:
        return POLICY_ADAPTIVE
    return STATIC_MODE_POLICY.get(system.static_routing, POLICY_FIXED)
