"""Congestion-control models for the evaluated fabrics (paper §II).

Each fabric's mechanism is reduced to a rate-based state machine applied at
fluid-simulation granularity:

* ``dcqcn``     — RoCE with ECN hard threshold + aggressive multiplicative
                  decrease and slow additive recovery (CE8850-like). The
                  bang-bang controller + queue-drain lag is what produces the
                  paper's Fig. 3 sawtooth (Obs. 1). PFC backstop -> HOL
                  blocking when ECN fails to hold the queue.
* ``ai_ecn``    — CE9855-like AI ECN: smooth (proportional) marking against a
                  dynamically-adjusted threshold -> damped, stable response.
* ``ib``        — InfiniBand: credit-based hop-by-hop flow control + slow
                  FECN/BECN end-to-end throttling. Credits are lossless and
                  keep the hot buffer FULL under sustained incast; the
                  congestion tree then stalls upstream ingress (coarse
                  VL-granular credits -> head-of-line blocking on victim
                  flows sharing any switch of the tree). ``hol_factor``
                  models how much of a congested switch's ingress capacity
                  the backpressure takes away — the paper's Fig. 5 Leonardo
                  Incast collapse is this term. Newer IB generations mark
                  earlier and isolate better (Obs. 2) -> lower hol_factor.
* ``slingshot`` — per-flow precise feedback: only flows actually contributing
                  to a bottleneck are throttled, fast recovery, per-flow
                  queue state -> no victim HOL (hol_factor = 0)
                  (paper §II-C, Obs. 4).
"""
from __future__ import annotations

import dataclasses

KIND_DCQCN = 0
KIND_IB = 1
KIND_SLINGSHOT = 2
KIND_AI_ECN = 3

ROUTE_FIXED = 0
ROUTE_ADAPTIVE = 1

# Bounded ranges for the mitigation lab's searchable knobs
# (mitigation/search.py validates every candidate against these; each key
# is a traced SimParams field). "kind" spans the four fabric CC models —
# swapping it is the firmware-upgrade axis (e.g. CE8850 DCQCN -> AI-ECN).
SEARCH_BOUNDS = {
    "kind": (0, 3),
    "md": (0.3, 0.95),
    "rai_frac": (0.002, 0.2),
    "cc_interval_s": (10e-6, 400e-6),
    "kmin": (0.05, 0.6),
    "kmax": (0.3, 0.95),
    "hol_factor": (0.0, 1.0),
    "hol_start": (0.3, 0.95),
    "min_rate_frac": (0.005, 0.1),
    "follow_tau_s": (0.0, 200e-6),
    "follow_gain": (0.9, 1.5),
    "thresh_adapt": (0.0, 1.0),
    "flowlet_gap_s": (20e-6, 2e-3),
}


@dataclasses.dataclass(frozen=True)
class CCParams:
    kind: int
    qmax_bytes: float = 4e6  # switch egress buffer per link
    kmin: float = 0.2  # marking threshold (fraction of qmax)
    kmax: float = 0.8  # upper marking point (ai_ecn proportional band)
    md: float = 0.5  # multiplicative decrease factor on mark
    rai_frac: float = 0.02  # additive increase, fraction of link cap per ms
    cc_interval_s: float = 50e-6  # min time between decreases per flow
    # --- lossless backpressure / head-of-line blocking ---
    hol_factor: float = 0.0  # ingress capacity lost when a switch saturates
    hol_start: float = 0.55  # egress-queue fraction where HOL stall begins
    min_rate_frac: float = 0.01
    follow_tau_s: float = 0.0  # credit-window time constant; 0 = no follow.
    # Credits track the achieved rate SYMMETRICALLY (pause when buffers
    # fill, resume the instant they drain) — unlike the slow FECN/BECN
    # marking loop, which only recovers at the additive-increase rate.
    follow_gain: float = 1.1  # credit overshoot: c target = gain * achieved
    thresh_adapt: bool = False  # AI-ECN dynamic threshold
    # Ethernet NIC arrival burstiness: queues build even at line rate
    # (0 for credit-based fabrics — credits prevent overshoot).
    burst_jitter: float = 0.0
    iter_drain: float = 1.0  # queue fraction kept across victim iterations


def dcqcn() -> CCParams:
    return CCParams(kind=KIND_DCQCN, md=0.5, rai_frac=0.008,
                    cc_interval_s=100e-6, kmin=0.15, qmax_bytes=6e6,
                    hol_factor=0.85, hol_start=0.7,
                    burst_jitter=0.12, iter_drain=0.3)


def ai_ecn() -> CCParams:
    return CCParams(kind=KIND_AI_ECN, md=0.85, rai_frac=0.05,
                    cc_interval_s=50e-6, kmin=0.1, kmax=0.7,
                    thresh_adapt=True, qmax_bytes=6e6,
                    hol_factor=0.6, hol_start=0.8,
                    burst_jitter=0.08, iter_drain=0.3)


def infiniband(gen: str = "hdr") -> CCParams:
    # newer generations: better-tuned marking (earlier, before the buffer is
    # deep in the HOL regime), faster recovery, and finer credit granularity
    # (less victim HOL) — paper Obs. 2: generation matters.
    #          md    rai    hol    kmin
    tune = {"edr": (0.75, 0.020, 0.95, 0.55),
            "hdr": (0.80, 0.030, 0.90, 0.50),
            "ndr": (0.80, 0.050, 0.45, 0.20)}
    md, rai, hol, kmin = tune[gen]
    return CCParams(kind=KIND_IB, md=md, rai_frac=rai, cc_interval_s=100e-6,
                    kmin=kmin, qmax_bytes=2e6,
                    hol_factor=hol, hol_start=0.55,
                    follow_tau_s=50e-6, follow_gain=1.3)


def slingshot() -> CCParams:
    return CCParams(kind=KIND_SLINGSHOT, md=0.9, rai_frac=0.1,
                    cc_interval_s=20e-6, kmin=0.3, qmax_bytes=2e6,
                    hol_factor=0.0, follow_tau_s=15e-6, follow_gain=1.05)
