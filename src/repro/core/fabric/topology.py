"""Fabric topologies with structured path enumeration.

Each builder returns a :class:`Topology` with directed capacitated links and
a per-(src,dst) candidate-path generator that exploits the topology's
structure (fat-tree: one path per spine; dragonfly: per global link; ...)
instead of generic graph search. Paths are lists of link indices.

Modeled systems (paper Table I): CRESCO8 blocking fat-tree, Leonardo
Dragonfly+, LUMI Dragonfly, HAICGU single switch, Nanjing 2-leaf/2-spine,
plus a TPU 2D-torus for the target platform.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import numpy as np

Link = Tuple[object, object]  # (endpoint_a, endpoint_b) directed


@dataclasses.dataclass
class Topology:
    name: str
    n_nodes: int
    caps: np.ndarray  # (L,) link capacity, bytes/s
    link_names: List[Link]
    link_index: Dict[Link, int]
    path_fn: Callable[[int, int], List[List[int]]]  # candidate paths
    link_src_switch: np.ndarray  # (L,) int id of the switch feeding each link
    meta: dict

    @property
    def n_links(self) -> int:
        return len(self.caps)

    def paths(self, src: int, dst: int) -> List[List[int]]:
        if src == dst:
            return [[]]
        return self.path_fn(src, dst)


class _Builder:
    def __init__(self):
        self.links: List[Link] = []
        self.caps: List[float] = []
        self.index: Dict[Link, int] = {}

    def add(self, a, b, cap_gbit: float) -> int:
        key = (a, b)
        if key in self.index:
            return self.index[key]
        idx = len(self.links)
        self.links.append(key)
        self.caps.append(cap_gbit * 1e9 / 8.0)  # Gb/s -> B/s
        self.index[key] = idx
        return idx

    def finish(self, name, n_nodes, path_fn, meta) -> Topology:
        src_sw = []
        switches: Dict[object, int] = {}
        for a, _ in self.links:
            if isinstance(a, tuple) and a[0] == "h":
                src_sw.append(-1)  # host injection link
            else:
                src_sw.append(switches.setdefault(a, len(switches)))
        return Topology(name, n_nodes, np.asarray(self.caps), self.links,
                        self.index, path_fn, np.asarray(src_sw, np.int32),
                        meta)


def _h(i):
    return ("h", i)


# --------------------------------------------------------------------------


def single_switch(n_nodes: int, link_gbit: float = 100.0,
                  name: str = "single_switch") -> Topology:
    b = _Builder()
    sw = ("sw", 0)
    for i in range(n_nodes):
        b.add(_h(i), sw, link_gbit)
        b.add(sw, _h(i), link_gbit)

    def path_fn(src, dst):
        return [[b.index[(_h(src), sw)], b.index[(sw, _h(dst))]]]

    return b.finish(name, n_nodes, path_fn, {"link_gbit": link_gbit})


def leaf_spine(n_nodes: int, n_leaf: int = 2, n_spine: int = 2,
               host_gbit: float = 200.0, up_gbit: float = 200.0,
               n_parallel: int = 2, name: str = "leaf_spine") -> Topology:
    """Nanjing lab: 2-leaf / 2-spine 200GE, ``n_parallel`` uplinks per
    leaf-spine pair (NSLB exploits the multiple path configurations)."""
    b = _Builder()
    # ceil so any node count maps to a valid leaf (matches fat_tree)
    per_leaf = (n_nodes + n_leaf - 1) // n_leaf
    for i in range(n_nodes):
        lf = ("leaf", i // per_leaf)
        b.add(_h(i), lf, host_gbit)
        b.add(lf, _h(i), host_gbit)
    for l in range(n_leaf):
        for s in range(n_spine):
            for p in range(n_parallel):
                b.add(("leaf", l), ("spine", s, p), up_gbit)
                b.add(("spine", s, p), ("leaf", l), up_gbit)

    def path_fn(src, dst):
        ls, ld = ("leaf", src // per_leaf), ("leaf", dst // per_leaf)
        inj, ej = b.index[(_h(src), ls)], b.index[(ld, _h(dst))]
        if ls == ld:
            return [[inj, ej]]
        return [[inj, b.index[(ls, ("spine", s, p))],
                 b.index[(("spine", s, p), ld)], ej]
                for s in range(n_spine) for p in range(n_parallel)]

    return b.finish(name, n_nodes, path_fn,
                    {"n_leaf": n_leaf, "n_spine": n_spine,
                     "n_parallel": n_parallel})


def fat_tree(n_nodes: int, nodes_per_leaf: int = 16, taper: float = 1.67,
             host_gbit: float = 200.0, name: str = "fat_tree") -> Topology:
    """2-level blocking fat-tree (CRESCO8: 1.67:1 taper, NDR 200 Gb/s)."""
    b = _Builder()
    n_leaf = (n_nodes + nodes_per_leaf - 1) // nodes_per_leaf
    n_spine = max(1, round(nodes_per_leaf / taper))
    for i in range(n_nodes):
        lf = ("leaf", i // nodes_per_leaf)
        b.add(_h(i), lf, host_gbit)
        b.add(lf, _h(i), host_gbit)
    for l in range(n_leaf):
        for s in range(n_spine):
            b.add(("leaf", l), ("spine", s), host_gbit)
            b.add(("spine", s), ("leaf", l), host_gbit)

    def path_fn(src, dst):
        ls, ld = ("leaf", src // nodes_per_leaf), ("leaf", dst // nodes_per_leaf)
        inj, ej = b.index[(_h(src), ls)], b.index[(ld, _h(dst))]
        if ls == ld:
            return [[inj, ej]]
        return [[inj, b.index[(ls, ("spine", s))],
                 b.index[(("spine", s), ld)], ej] for s in range(n_spine)]

    return b.finish(name, n_nodes, path_fn,
                    {"n_leaf": n_leaf, "n_spine": n_spine, "taper": taper})


def dragonfly(n_nodes: int, routers_per_group: int = 8,
              nodes_per_router: int = 4, host_gbit: float = 200.0,
              global_gbit: float = 200.0, n_valiant: int = 4,
              name: str = "dragonfly") -> Topology:
    """Dragonfly (LUMI-like): all-to-all routers inside a group, one global
    link between each pair of groups (assigned round-robin to routers)."""
    b = _Builder()
    per_group = routers_per_group * nodes_per_router
    n_groups = (n_nodes + per_group - 1) // per_group

    def router_of(i):
        return ("r", i // per_group, (i % per_group) // nodes_per_router)

    for i in range(n_nodes):
        b.add(_h(i), router_of(i), host_gbit)
        b.add(router_of(i), _h(i), host_gbit)
    for g in range(n_groups):
        for r1 in range(routers_per_group):
            for r2 in range(routers_per_group):
                if r1 != r2:
                    b.add(("r", g, r1), ("r", g, r2), host_gbit)
    # one global link per router per destination group (round-robin base +
    # parallel options) — Dragonfly provisions several globals per pair
    glinks: Dict[Tuple[int, int], list] = {}
    n_par = min(4, routers_per_group)
    for g1 in range(n_groups):
        for g2 in range(n_groups):
            if g1 == g2:
                continue
            opts = []
            for j in range(n_par):
                r1 = (g1 + g2 + j) % routers_per_group
                r2 = (g1 + g2 + j) % routers_per_group
                b.add(("r", g1, r1), ("r", g2, r2), global_gbit)
                opts.append((r1, r2))
            glinks[(g1, g2)] = opts

    def path_fn(src, dst):
        rs, rd = router_of(src), router_of(dst)
        gs, gd = rs[1], rd[1]
        inj, ej = b.index[(_h(src), rs)], b.index[(rd, _h(dst))]
        paths = []
        if gs == gd:
            if rs == rd:
                return [[inj, ej]]
            return [[inj, b.index[(rs, rd)], ej]]
        # minimal: rs -> gw_src -> gw_dst -> rd, one per parallel global link
        for r1, r2 in glinks[(gs, gd)]:
            p = [inj]
            if rs[2] != r1:
                p.append(b.index[(rs, ("r", gs, r1))])
            p.append(b.index[(("r", gs, r1), ("r", gd, r2))])
            if rd[2] != r2:
                p.append(b.index[(("r", gd, r2), rd)])
            p.append(ej)
            paths.append(p)
        # non-minimal (Valiant) via intermediate groups — the path diversity
        # that lets AR absorb AlltoAll transit contention (paper §II)
        seen = {gs, gd}
        stride = max(1, n_groups // (n_valiant + 1))
        for j in range(n_groups):
            gi = (min(gs, gd) + 1 + j * stride) % max(n_groups, 1)
            if gi in seen or len(paths) >= len(glinks.get((gs, gd), [0])) \
                    + n_valiant:
                continue
            seen.add(gi)
            ra, rb = glinks[(gs, gi)][j % n_par]
            rc, rdd = glinks[(gi, gd)][j % n_par]
            p = [inj]
            if rs[2] != ra:
                p.append(b.index[(rs, ("r", gs, ra))])
            p.append(b.index[(("r", gs, ra), ("r", gi, rb))])
            if rb != rc:
                p.append(b.index[(("r", gi, rb), ("r", gi, rc))])
            p.append(b.index[(("r", gi, rc), ("r", gd, rdd))])
            if rd[2] != rdd:
                p.append(b.index[(("r", gd, rdd), rd)])
            p.append(ej)
            paths.append(p)
        return paths

    return b.finish(name, n_nodes, path_fn,
                    {"n_groups": n_groups, "routers_per_group": routers_per_group})


def dragonfly_plus(n_nodes: int, leaves_per_group: int = 4,
                   spines_per_group: int = 4, nodes_per_leaf: int = 8,
                   host_gbit: float = 100.0, global_gbit: float = 100.0,
                   intra_factor: float = 2.0, n_valiant: int = 6,
                   name: str = "dragonfly_plus") -> Topology:
    """Dragonfly+ (Leonardo-like): groups are leaf/spine bipartite (non-
    blocking intra-group: uplink bw = downlink bw); spines hold the
    inter-group links (tapered globally)."""
    b = _Builder()
    per_group = leaves_per_group * nodes_per_leaf
    n_groups = (n_nodes + per_group - 1) // per_group
    up_gbit = host_gbit * nodes_per_leaf / spines_per_group \
        if intra_factor <= 0 else host_gbit * intra_factor

    def leaf_of(i):
        return ("lf", i // per_group, (i % per_group) // nodes_per_leaf)

    for i in range(n_nodes):
        b.add(_h(i), leaf_of(i), host_gbit)
        b.add(leaf_of(i), _h(i), host_gbit)
    for g in range(n_groups):
        for l in range(leaves_per_group):
            for s in range(spines_per_group):
                b.add(("lf", g, l), ("sp", g, s), up_gbit)
                b.add(("sp", g, s), ("lf", g, l), up_gbit)
    for g1 in range(n_groups):
        for g2 in range(n_groups):
            if g1 != g2:
                s = (g1 + g2) % spines_per_group
                b.add(("sp", g1, s), ("sp", g2, s), global_gbit)

    def path_fn(src, dst):
        ls, ld = leaf_of(src), leaf_of(dst)
        gs, gd = ls[1], ld[1]
        inj, ej = b.index[(_h(src), ls)], b.index[(ld, _h(dst))]
        if ls == ld:
            return [[inj, ej]]
        if gs == gd:
            return [[inj, b.index[(ls, ("sp", gs, s))],
                     b.index[(("sp", gs, s), ld)], ej]
                    for s in range(spines_per_group)]
        s = (gs + gd) % spines_per_group
        base = [inj, b.index[(ls, ("sp", gs, s))],
                b.index[(("sp", gs, s), ("sp", gd, s))],
                b.index[(("sp", gd, s), ld)], ej]
        paths = [base]
        # non-minimal through other groups' spine pairs, sampled across the
        # machine so concurrent flows can fan out over many transit groups
        stride = max(1, n_groups // (n_valiant + 1))
        seen = {gs, gd}
        for j in range(n_groups):
            gi = (min(gs, gd) + 1 + j * stride) % n_groups
            if gi in seen or len(paths) >= 1 + n_valiant:
                continue
            seen.add(gi)
            s1 = (gs + gi) % spines_per_group
            s2 = (gi + gd) % spines_per_group
            p = [inj, b.index[(ls, ("sp", gs, s1))],
                 b.index[(("sp", gs, s1), ("sp", gi, s1))]]
            if s1 != s2:
                p += [b.index[(("sp", gi, s1), ("lf", gi, 0))],
                      b.index[(("lf", gi, 0), ("sp", gi, s2))]]
            p += [b.index[(("sp", gi, s2), ("sp", gd, s2))],
                  b.index[(("sp", gd, s2), ld)], ej]
            paths.append(p)
        return paths

    return b.finish(name, n_nodes, path_fn,
                    {"n_groups": n_groups, "leaves_per_group": leaves_per_group,
                     "spines_per_group": spines_per_group})


def torus2d(nx: int, ny: int, link_gbit: float = 400.0,
            name: str = "torus2d") -> Topology:
    """TPU-style 2D torus; hosts are the routers (ICI), DOR X-then-Y routing."""
    b = _Builder()
    n = nx * ny

    def xy(i):
        return i % nx, i // nx

    for i in range(n):
        x, y = xy(i)
        for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            j = ((x + dx) % nx) + ((y + dy) % ny) * nx
            b.add(_h(i), _h(j), link_gbit)

    def hop(a, b_):
        return b.index[(_h(a), _h(b_))]

    def path_fn(src, dst):
        # dimension-ordered, minimal (both X directions tie-broken shortest)
        def walk(i, j):
            xs, ys = xy(i)
            xd, yd = xy(j)
            links = []
            while xs != xd:
                step = 1 if (xd - xs) % nx <= nx // 2 else -1
                nxt = ((xs + step) % nx) + ys * nx
                links.append(hop(xs + ys * nx, nxt))
                xs = (xs + step) % nx
            while ys != yd:
                step = 1 if (yd - ys) % ny <= ny // 2 else -1
                nxt = xs + ((ys + step) % ny) * nx
                links.append(hop(xs + ys * nx, nxt))
                ys = (ys + step) % ny
            return links

        return [walk(src, dst)]

    return b.finish(name, n, path_fn, {"nx": nx, "ny": ny})


# --------------------------------------------------------------------------
# Family registry: build any topology family by name at any node count.
# The scale-batched engine (bench.run_scale_grid) pads geometries of
# different families/scales to one bucket shape, so heterogeneous
# topologies stack under one vmap; this registry is how scenario builders
# and the property-test suite sample families generically.
# --------------------------------------------------------------------------

FAMILIES: Dict[str, Callable[..., Topology]] = {
    "single_switch": single_switch,
    "leaf_spine": leaf_spine,
    "fat_tree": fat_tree,
    "dragonfly": dragonfly,
    "dragonfly_plus": dragonfly_plus,
}


def make_family(family: str, n_nodes: int, **kwargs) -> Topology:
    """Build one named topology family at ``n_nodes`` (kwargs forwarded
    to the family builder)."""
    if family not in FAMILIES:
        raise KeyError(f"unknown topology family {family!r}; "
                       f"known: {sorted(FAMILIES)}")
    return FAMILIES[family](n_nodes, **kwargs)
