"""The paper's measurement protocol (§III): run the victim collective for a
fixed number of iterations under a congestion profile, discard warmup,
report mean iteration time and the uncongested/congested ratio.

The paper uses 1000 iterations / 100 warmup on real fabrics; the fluid
simulator converges much faster (no per-packet noise), so the default here
is 60/10 — scaled, and noted in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import congestion as cong
from repro.core.fabric.simulator import FabricSim
from repro.core.fabric.systems import SystemPreset


@dataclasses.dataclass
class BenchResult:
    system: str
    n_nodes: int
    victim: str
    aggressor: str
    profile: str
    vector_bytes: float
    t_uncongested_s: float
    t_congested_s: float
    ratio: float  # uncongested / congested (paper Fig. 5-8; higher = better)
    victim_goodput_gbps: float
    n_iters: tuple


def _mean_iter_time(res, lat: float) -> float:
    if len(res.iter_times) == 0:
        return float("inf")
    return float(np.mean(res.iter_times)) + lat + res.mean_qdelay_s


_TOPO_CACHE: dict = {}


def machine_topology(system: SystemPreset):
    """Full-machine topology (cached — reused across heatmap cells)."""
    key = system.name
    if key not in _TOPO_CACHE:
        _TOPO_CACHE[key] = system.make_topology(system.machine_nodes or 8)
    return _TOPO_CACHE[key]


def allocate(system: SystemPreset, n_nodes: int, seed: int = 7) -> np.ndarray:
    """Model a production batch-scheduler allocation: a scattered sample of
    the machine (the paper: 'we cannot fully control job allocations' —
    busy TOP500 systems hand out fragmented node sets). The interleaved
    victim/aggressor split then alternates within and across switches —
    the paper's maximal-sharing design (§III-A)."""
    machine = system.machine_nodes or n_nodes
    if n_nodes >= machine:
        return np.arange(machine)
    rng = np.random.RandomState(seed + n_nodes)
    return np.sort(rng.choice(machine, size=n_nodes, replace=False))


def run_point(system: SystemPreset, n_nodes: int, victim_coll: str,
              aggr_coll: str, vector_bytes: float,
              profile: cong.Profile, *, n_iters: int = 60, warmup: int = 10,
              dt: Optional[float] = None, max_steps: int = 200_000,
              return_traces: bool = False):
    """One heatmap cell: baseline (aggressors off) vs congested run."""
    topo = machine_topology(system)
    alloc = allocate(system, n_nodes)
    vidx, aidx = cong.interleaved_split(n_nodes)
    victims, aggressors = alloc[vidx], alloc[aidx]
    flows = cong.build_flowset(topo, victims, aggressors, victim_coll,
                               aggr_coll, vector_bytes,
                               routing_mode=system.static_routing,
                               k_max=system.k_max)
    n_v = len(victims)
    lat = cong.latency_model(victim_coll, n_v)
    # dt sized so one uncongested iteration spans ~100 steps
    if dt is None:
        per_flow = vector_bytes / max(n_v, 1)
        t_est = max(per_flow / (topo.caps.max()), 2e-6) * 2 + lat
        dt = float(np.clip(t_est / 100.0, 1e-6, 200e-6))

    sim = FabricSim(topo, flows, system.cc, routing=system.routing, dt=dt)
    base = sim.run(n_iters=n_iters, warmup=warmup,
                   envelope_fn=cong.no_congestion().envelope,
                   max_steps=max_steps)
    cong_res = sim.run(n_iters=n_iters, warmup=warmup,
                       envelope_fn=profile.envelope, max_steps=max_steps)
    t_u = _mean_iter_time(base, lat)
    t_c = _mean_iter_time(cong_res, lat)
    out = BenchResult(
        system=system.name, n_nodes=n_nodes, victim=victim_coll,
        aggressor=aggr_coll or "none", profile=profile.kind,
        vector_bytes=vector_bytes, t_uncongested_s=t_u, t_congested_s=t_c,
        ratio=t_u / t_c if t_c > 0 else 0.0,
        victim_goodput_gbps=float(np.mean(cong_res.victim_rate_trace[-200:])
                                  * 8 / 1e9)
        if len(cong_res.victim_rate_trace) else 0.0,
        n_iters=(base.n_done, cong_res.n_done),
    )
    if return_traces:
        return out, base, cong_res
    return out


def goodput_trace(system: SystemPreset, n_nodes: int, coll: str,
                  vector_bytes: float, *, n_iters: int = 40,
                  dt: float = 20e-6, max_steps: int = 200_000):
    """Self-congestion run (no aggressors) — Fig. 3 sawtooth experiments."""
    topo = machine_topology(system) if system.machine_nodes \
        else system.make_topology(n_nodes)
    nodes = allocate(system, n_nodes)
    flows = cong.build_flowset(topo, nodes, [], coll, "", vector_bytes,
                               routing_mode=system.static_routing,
                               k_max=system.k_max)
    sim = FabricSim(topo, flows, system.cc, routing=system.routing, dt=dt)
    res = sim.run(n_iters=n_iters, warmup=5,
                  envelope_fn=cong.no_congestion().envelope,
                  max_steps=max_steps)
    return res


def straggler_impact(system: SystemPreset, n_nodes: int, coll: str,
                     vector_bytes: float, *, slow_factor: float = 0.1,
                     n_iters: int = 25) -> dict:
    """Model a straggler as a degraded injection link (DESIGN.md §7):
    one node's NIC runs at ``slow_factor`` of line rate; a synchronous
    collective is gated by its slowest member, so the iteration time
    stretches toward 1/slow_factor. Runtime policy (fault.StepMonitor +
    elastic_plan) uses this as the model for when eviction pays."""
    import copy

    topo = machine_topology(system) if system.machine_nodes \
        else system.make_topology(n_nodes)
    nodes = allocate(system, n_nodes)
    flows = cong.build_flowset(topo, nodes, [], coll, "", vector_bytes,
                               routing_mode=system.static_routing,
                               k_max=system.k_max)
    sim = FabricSim(topo, flows, system.cc, routing=system.routing, dt=5e-6)
    base = sim.run(n_iters=n_iters, warmup=5,
                   envelope_fn=cong.no_congestion().envelope)

    topo_slow = copy.copy(topo)
    caps = topo.caps.copy()
    victim_node = int(nodes[len(nodes) // 2])
    for li, (a, b) in enumerate(topo.link_names):
        if a == ("h", victim_node) or b == ("h", victim_node):
            caps[li] = caps[li] * slow_factor
    topo_slow.caps = caps
    flows2 = cong.build_flowset(topo_slow, nodes, [], coll, "", vector_bytes,
                                routing_mode=system.static_routing,
                                k_max=system.k_max)
    sim2 = FabricSim(topo_slow, flows2, system.cc, routing=system.routing,
                     dt=5e-6)
    slow = sim2.run(n_iters=n_iters, warmup=5,
                    envelope_fn=cong.no_congestion().envelope)
    t_base = float(np.mean(base.iter_times)) if len(base.iter_times) else 0.0
    t_slow = float(np.mean(slow.iter_times)) if len(slow.iter_times) \
        else float("inf")
    return {"t_base_s": t_base, "t_straggler_s": t_slow,
            "slowdown": t_slow / t_base if t_base else float("inf")}
