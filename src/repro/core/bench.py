"""The paper's measurement protocol (§III): run the victim collective for a
fixed number of iterations under a congestion profile, discard warmup,
report mean iteration time and the uncongested/congested ratio.

The paper uses 1000 iterations / 100 warmup on real fabrics; the fluid
simulator converges much faster (no per-packet noise), so the default here
is 60/10 — scaled, and noted in EXPERIMENTS.md.

Two entry points:

* :func:`run_point` — one heatmap cell (baseline + congested, batched as a
  2-cell grid internally).
* :func:`run_grid` — a whole (vector size x profile x baseline/congested)
  grid on ONE flow set, executed by a single ``jit(vmap(...))`` call
  (simulator.run_cells). This is the fast path for the paper's Figs. 5-8
  sweeps: one compile, all cells advance in lockstep.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core import congestion as cong
from repro.core import traffic
from repro.core.fabric.simulator import (TDONE_SLOTS, FabricGeometry,
                                         SimParams, _drop_warmup,
                                         bucket_dims, check_iter_budget,
                                         make_geometry, make_params,
                                         pad_geometry, run_cell, run_cells,
                                         run_cells_hetero, stack_geometries,
                                         stack_params, summarize)
from repro.core.fabric.routing import splitmix64
from repro.core.fabric.systems import (SystemPreset, default_policy,
                                       get_system)

# One (system, n_nodes) cell of a scale-batched sweep; systems may be
# preset objects or registry names.
ScaleCell = Tuple[Union[str, SystemPreset], int]


@dataclasses.dataclass
class BenchResult:
    system: str
    n_nodes: int
    victim: str
    aggressor: str
    profile: str
    vector_bytes: float
    t_uncongested_s: float
    t_congested_s: float
    ratio: float  # uncongested / congested (paper Fig. 5-8; higher = better)
    victim_goodput_gbps: float
    n_iters: tuple
    # per-job mean iteration times of the congested cell, for multi-job
    # mixes: ((job_name, t_mean_s, n_done), ...) over jobs that closed
    # at least one program iteration
    job_times: tuple = ()
    # False when either lane finished inside its warmup window: the
    # reported means are then last-iteration estimates, not steady state
    warmup_ok: bool = True
    # did-not-finish: a lane completed ZERO iterations within the step
    # budget — times/ratio are NaN and the cell must not be scored
    dnf: bool = False


def victim_label(victim_coll: str, phased: bool) -> str:
    """The reported/cached victim column: the collective kind plus a
    '+phased' marker when the primary job runs its step schedule. The
    single source of truth for result rows AND scenario cache keys."""
    return victim_coll + ("+phased" if phased else "")


def resolve_victim_label(victim_coll: str, phased: bool, jobs=None) -> str:
    """Victim label as build_case resolves it for a (victim, phased,
    jobs) request — scenario cache keys (benchmarks.common) call this so
    the key and the cached row cannot drift apart."""
    if jobs:
        return victim_label(victim_coll or jobs[0].collective,
                            bool(jobs[0].phased))
    return victim_label(victim_coll, phased)


def mean_iter_time(res, lat: float) -> float:
    """Reported per-iteration time of one summarized run: mean simulated
    iteration + analytic per-step latency + mean queueing delay (shared
    by the grid runners and mitigation.search). A run that completed ZERO
    iterations is NaN — an explicit did-not-finish the callers must flag
    (BenchResult.dnf / CellRun.dnf), never a silent ``inf`` that poisons
    downstream ratios and Pareto scores."""
    if len(res.iter_times) == 0:
        return float("nan")
    return float(np.mean(res.iter_times)) + lat + res.mean_qdelay_s


_TOPO_CACHE: dict = {}


def _fn_fingerprint(fn) -> tuple:
    """Identity-relevant fingerprint of a topology builder: bytecode,
    constants (nested code objects repr to a stable per-object string),
    closure values and defaults — so a SystemPreset re-registered under
    the same name with a different builder cannot hit a stale entry."""
    code = getattr(fn, "__code__", None)
    if code is None:
        return (repr(fn),)
    consts = tuple(
        c if isinstance(c, (int, float, str, bytes, bool, type(None)))
        else repr(c) for c in code.co_consts)
    closure = tuple(repr(c.cell_contents)
                    for c in (getattr(fn, "__closure__", None) or ()))
    return (code.co_code, consts, closure, repr(fn.__defaults__))


def _topo_cache_key(system: SystemPreset, n: int) -> tuple:
    return (system.name, system.fabric, system.machine_nodes,
            system.k_max, system.static_routing,
            _fn_fingerprint(system.make_topology), n)


def clear_topology_cache() -> None:
    """Drop every cached machine topology (tests that mutate presets)."""
    _TOPO_CACHE.clear()


def machine_topology(system: SystemPreset, n_nodes: int = 0):
    """Full-machine topology (cached — reused across heatmap cells).
    Testbed systems (``machine_nodes == 0``) are built at the allocation
    size instead, so scale sweeps over them actually scale the fabric.
    The cache keys on the preset's identity-relevant fields plus a
    fingerprint of the builder itself, NOT just the name: two presets
    sharing a name but differing in fabric/size/builder get distinct
    entries."""
    n = system.machine_nodes or (n_nodes or 8)
    key = _topo_cache_key(system, n)
    if key not in _TOPO_CACHE:
        _TOPO_CACHE[key] = system.make_topology(n)
    return _TOPO_CACHE[key]


def allocate(system: SystemPreset, n_nodes: int, seed: int = 7) -> np.ndarray:
    """Model a production batch-scheduler allocation: a scattered sample of
    the machine (the paper: 'we cannot fully control job allocations' —
    busy TOP500 systems hand out fragmented node sets). The interleaved
    victim/aggressor split then alternates within and across switches —
    the paper's maximal-sharing design (§III-A).

    ``seed`` and ``n_nodes`` mix through the pinned splitmix64, so
    distinct (seed, n_nodes) pairs draw unrelated allocations — the old
    additive ``seed + n_nodes`` seeding made (7, 8) and (8, 7) identical
    draws (and neighboring scales near-copies of each other)."""
    machine = system.machine_nodes or n_nodes
    if n_nodes >= machine:
        return np.arange(machine)
    mixed = splitmix64((np.uint64(seed) << np.uint64(32))
                       | np.uint64(np.uint32(n_nodes)))
    rng = np.random.RandomState(int(mixed & np.uint64(0xFFFFFFFF)))
    return np.sort(rng.choice(machine, size=n_nodes, replace=False))


# --------------------------------------------------------------------------
# dt selection
# --------------------------------------------------------------------------

# power-of-two microsecond ladder: neighboring grid cells snap to shared dt
# values, so batched cells stay numerically comparable and JIT caches hit
# across sweeps even when dt were a compile-time constant.
DT_LADDER_S = tuple(2.0 ** k * 1e-6 for k in range(8))  # 1us .. 128us


def quantize_dt(dt_raw: float) -> float:
    """Snap down to the nearest ladder step (finer dt = more accurate)."""
    for dt in reversed(DT_LADDER_S):
        if dt <= dt_raw:
            return dt
    return DT_LADDER_S[0]


def choose_dt(topo, n_victims: int, vector_bytes: float, lat: float,
              n_phases: int = 1) -> float:
    """dt sized so one uncongested iteration spans ~100 steps — and, for
    phased programs, so each of the ``n_phases`` barrier-gated phases
    spans at least ~8 steps (phase advance is quantized to dt, so a
    too-coarse dt would inflate every phase by up to one step)."""
    per_flow = vector_bytes / max(n_victims, 1)
    t_est = max(per_flow / (topo.caps.max()), 2e-6) * 2 + lat
    steps = max(100, 8 * int(n_phases))
    return quantize_dt(float(np.clip(t_est / steps, 1e-6, 200e-6)))


# --------------------------------------------------------------------------
# Case construction: one flow set, reused across a grid of cells
# --------------------------------------------------------------------------


@dataclasses.dataclass
class GridCase:
    """One (system, allocation, traffic program) experiment; the
    unit-vector flow program to be scaled per cell (sweeping jobs' bytes
    are linear in the swept vector size; background jobs keep their own
    fixed volume)."""

    system: SystemPreset
    n_nodes: int
    victim_coll: str
    aggr_coll: str
    topo: object
    geom: FabricGeometry
    unit_bytes: np.ndarray  # (F,) per-flow bytes at vector_bytes == 1.0
    is_victim: np.ndarray  # (F,)
    host_caps: np.ndarray  # (F,)
    n_victims: int
    sweep_mask: np.ndarray = None  # (F,) flows whose bytes sweep
    job_names: List[str] = None
    max_phases: int = 1
    primary_phased: bool = False  # job 0 runs a phased step schedule
    # traced routing-policy id for this case's cells (the system default;
    # mitigation/search overrides it per candidate)
    policy: int = 0

    def __post_init__(self):
        if self.sweep_mask is None:
            self.sweep_mask = np.asarray(self.is_victim, bool)
        if self.job_names is None:
            self.job_names = ["victim", "aggressor"]

    def cell_params(self, vector_bytes: float, profile: cong.Profile,
                    dt: float, n_flows: Optional[int] = None,
                    with_fault_table: bool = False) -> SimParams:
        """Per-cell traced params; ``n_flows`` pads the flow axis to a
        geometry-bucket width (pad flows: 0 bytes — never alive — and a
        positive dummy host cap so no divide ever sees 0).

        ``with_fault_table=True`` forces the inert all-``none`` fault
        table onto lanes whose profile carries no events — stacked lanes
        of one grid must share a pytree structure, and the inert table is
        bit-identical to running without one (DESIGN.md §16)."""
        bpi = np.where(self.sweep_mask, self.unit_bytes * vector_bytes,
                       self.unit_bytes)
        host_caps = self.host_caps
        if n_flows is not None and n_flows > len(bpi):
            bpi = traffic.pad_rows(bpi, n_flows, 0.0)
            host_caps = traffic.pad_rows(host_caps, n_flows, 1.0)
        fault = profile.fault_params()
        if fault is None and with_fault_table:
            fault = cong.no_fault_table()
        # intra-node stage capacity: a fraction of the fastest NIC on the
        # case (inf = stage inert; the geometry flag gates the trace)
        node_cap = np.inf if profile.node_cap_frac <= 0 else \
            float(profile.node_cap_frac) * float(np.max(self.host_caps))
        return make_params(self.system.cc, dt=dt, bytes_per_iter=bpi,
                           host_caps=host_caps, env=profile.params(),
                           policy=self.policy, fault=fault,
                           node_cap=node_cap)

    def lat(self) -> float:
        return cong.latency_model(self.victim_coll, self.n_victims)


def build_case(system: SystemPreset, n_nodes: int, victim_coll: str,
               aggr_coll: str, topo=None,
               nodes: Optional[np.ndarray] = None, *,
               phased: bool = False,
               jobs: Optional[Sequence[traffic.JobSpec]] = None,
               policy_tables: bool = False,
               intra_node: bool = False,
               seed: int = 7) -> GridCase:
    """Build the flow program + geometry once for a whole grid of cells.

    Default: the paper's two-job victim/aggressor split. ``phased=True``
    lowers the victim's step schedule instead of flattening it.
    ``jobs`` replaces the split with an explicit multi-job program — jobs
    without nodes get an interleaved share of the allocation, and jobs
    with ``sweep_bytes`` are compiled at unit vector size and scaled per
    cell. ``policy_tables=True`` additionally computes the ECMP/NSLB
    static tables so traced policies can cross-select them (the
    mitigation search needs this; plain sweeps only dispatch the policy
    matching ``fixed_choice`` and skip the host-side assignment cost).
    """
    if topo is None:
        topo = machine_topology(system, n_nodes)
    if nodes is None:
        nodes = allocate(system, n_nodes, seed=seed)
    if jobs is not None:
        jobs = traffic.split_nodes(nodes, list(jobs))
        jobs = [dataclasses.replace(j, vector_bytes=1.0)
                if j.sweep_bytes and not j.endless else j for j in jobs]
        flows = cong.build_program_flowset(
            topo, jobs, routing_mode=system.static_routing,
            k_max=system.k_max, policy_tables=policy_tables)
        # caller-provided labels win (scenario cache keys); fall back to
        # the program's own names
        victim_coll = victim_coll or jobs[0].collective
        aggr_coll = aggr_coll or "+".join(j.name for j in jobs[1:])
        n_victims = len(jobs[0].nodes)
    else:
        # the paper's §III-A interleaved split (applied even with no
        # aggressor collective, so baseline and congested cells share
        # the victim set)
        vidx, aidx = cong.interleaved_split(n_nodes)
        victims, aggressors = nodes[vidx], nodes[aidx]
        flows = cong.build_flowset(topo, victims, aggressors, victim_coll,
                                   aggr_coll, 1.0,
                                   routing_mode=system.static_routing,
                                   k_max=system.k_max, phased=phased,
                                   policy_tables=policy_tables)
        n_victims = len(victims)
    geom = make_geometry(topo, flows, intra_node=intra_node)
    return GridCase(system=system, n_nodes=n_nodes, victim_coll=victim_coll,
                    aggr_coll=aggr_coll, topo=topo, geom=geom,
                    unit_bytes=flows.bytes_per_iter.copy(),
                    is_victim=flows.is_victim, host_caps=flows.host_caps,
                    n_victims=n_victims,
                    sweep_mask=np.asarray(flows.sweep_mask, bool),
                    job_names=list(flows.job_names),
                    max_phases=int(np.max(flows.n_phases)),
                    primary_phased=bool(jobs[0].phased) if jobs is not None
                    else phased,
                    policy=default_policy(system))


# --------------------------------------------------------------------------
# Batched grid runner (the vmap hot path)
# --------------------------------------------------------------------------


def _job_times(out, case: GridCase, *, n_iters, warmup, cell) -> tuple:
    """Per-job mean iteration times of one cell (jobs that closed at
    least one program iteration; endless aggressors never do). Reads
    only the tiny it/t_done outputs — no trace-buffer transfer."""
    it = np.asarray(out["it"])
    td = np.asarray(out["t_done"])
    if cell is not None:
        it, td = it[cell], td[cell]
    rows = []
    for ji, name in enumerate(case.job_names):
        n_done = min(int(it[ji]), n_iters, TDONE_SLOTS)
        if n_done <= 0:
            continue
        times = np.diff(np.concatenate([[0.0], td[ji][:n_done]]))
        times, _ = _drop_warmup(times, n_done, warmup)
        if len(times):
            rows.append((name, float(np.mean(times)), n_done))
    return tuple(rows)


def _cell_dts(case: GridCase, sizes: Sequence[float], n_profiles: int,
              dt: Optional[float], lat: float) -> List[float]:
    """One dt per sub-cell (size-major, baseline + profiles per size),
    chosen per cell on the shared power-of-two ladder."""
    dts: List[float] = []
    for v in sizes:
        cell_dt = dt if dt is not None else choose_dt(
            case.topo, case.n_victims, float(v), lat,
            n_phases=case.max_phases)
        dts.extend([cell_dt] * (1 + n_profiles))
    return dts


def _grid_results(case: GridCase, out: dict, sizes: Sequence[float],
                  profiles: Sequence[cong.Profile], dts: Sequence[float], *,
                  n_iters: int, warmup: int, chunk: int, stride: int,
                  cell_prefix: tuple = ()) -> List[BenchResult]:
    """Marshal one case's (size x baseline/profile) sub-cells out of a
    batched run. ``cell_prefix`` indexes the leading batch axes in front
    of the sub-cell axis (run_cells_hetero adds a topology-cell axis)."""
    lat = case.lat()
    per_prof = 1 + len(profiles)
    results = []
    for si, v in enumerate(sizes):
        base_i = si * per_prof
        base = summarize(out, n_iters=n_iters, warmup=warmup, dt=dts[base_i],
                         chunk=chunk, stride=stride,
                         cell=cell_prefix + (base_i,))
        t_u = mean_iter_time(base, lat)
        for pi, prof in enumerate(profiles):
            ci = base_i + 1 + pi
            res = summarize(out, n_iters=n_iters, warmup=warmup, dt=dts[ci],
                            chunk=chunk, stride=stride,
                            cell=cell_prefix + (ci,))
            t_c = mean_iter_time(res, lat)
            dnf = base.n_done == 0 or res.n_done == 0
            results.append(BenchResult(
                system=case.system.name, n_nodes=case.n_nodes,
                victim=victim_label(case.victim_coll, case.primary_phased),
                aggressor=case.aggr_coll or "none", profile=prof.label(),
                vector_bytes=float(v), t_uncongested_s=t_u,
                t_congested_s=t_c,
                ratio=float("nan") if dnf
                else (t_u / t_c if t_c > 0 else 0.0),
                victim_goodput_gbps=float(
                    np.mean(res.victim_rate_trace[-200:]) * 8 / 1e9)
                if len(res.victim_rate_trace) else 0.0,
                n_iters=(base.n_done, res.n_done),
                job_times=_job_times(out, case, n_iters=n_iters,
                                     warmup=warmup,
                                     cell=cell_prefix + (ci,)),
                warmup_ok=base.warmup_ok and res.warmup_ok,
                dnf=dnf,
            ))
    return results


def _resolve_launcher(mesh, launcher, shard_axis: str = "cell"):
    """Launcher resolution shared by the grid runners and the mitigation
    search: an explicit ``launcher`` callable wins; a ``mesh`` alone gets
    launch.sweep's per-device dispatcher over ``shard_axis`` (imported
    lazily — core never depends on the launch layer at import time)."""
    if launcher is not None or mesh is None:
        return launcher
    from repro.launch.sweep import device_launcher
    return device_launcher(mesh, shard_axis=shard_axis)


def run_grid(system: Union[SystemPreset, Sequence[ScaleCell]], n_nodes: int,
             victim_coll: str, aggr_coll: str, sizes: Sequence[float],
             profiles: Sequence[cong.Profile], *, n_iters: int = 60,
             warmup: int = 10, dt: Optional[float] = None,
             max_steps: int = 200_000, chunk: int = 2048,
             trace_stride: int = 8, phased: bool = False,
             jobs: Optional[Sequence[traffic.JobSpec]] = None,
             mesh=None, launcher=None,
             ) -> List[BenchResult]:
    """All (vector size x profile) cells of one experiment in a single
    batched call: a per-size baseline (aggressors/background jobs off)
    plus one congested cell per profile, sharing one FlowSet/geometry and
    one compile. ``phased``/``jobs`` select the traffic program (see
    build_case); per-job iteration times ride along in each result.

    ``system`` may also be a list of ``(system, n_nodes)`` cells —
    heterogeneous topologies and scales. Those route through the
    scale-batched engine (:func:`run_scale_grid`): geometries are padded
    to bucket shapes and stacked, so the whole cross-scale sweep costs
    one compile per bucket instead of one per scale. ``n_nodes`` is
    ignored in that mode.

    ``mesh`` (or an explicit ``launcher``) shards the batched call
    across devices via the sharded sweep launcher (launch/sweep.py);
    single-system grids reroute through the scale-batched path, whose
    bucket padding is provably inert, so sharded and plain runs stay
    bit-identical."""
    if not isinstance(system, SystemPreset) or mesh is not None \
            or launcher is not None:
        cells = system if not isinstance(system, SystemPreset) \
            else [(system, n_nodes)]
        return run_scale_grid(cells, victim_coll, aggr_coll, sizes,
                              profiles, n_iters=n_iters, warmup=warmup,
                              dt=dt, max_steps=max_steps, chunk=chunk,
                              trace_stride=trace_stride, phased=phased,
                              jobs=jobs, mesh=mesh, launcher=launcher)
    check_iter_budget(n_iters)
    # fault/intra-node lanes: any faulted lane forces the inert table on
    # its siblings (one pytree structure per stack); any node-capped lane
    # arms the intra-node stage for the whole case (inert at inf)
    with_ft = cong.needs_fault_table(profiles)
    case = build_case(system, n_nodes, victim_coll, aggr_coll,
                      phased=phased, jobs=jobs,
                      intra_node=any(p.node_cap_frac > 0 for p in profiles))
    dts = _cell_dts(case, sizes, len(profiles), dt, case.lat())
    cells = [(float(v), prof) for v in sizes
             for prof in [cong.no_congestion()] + list(profiles)]
    params = stack_params([case.cell_params(v, prof, d,
                                            with_fault_table=with_ft)
                           for (v, prof), d in zip(cells, dts)])
    max_chunks = -(-max_steps // chunk)
    out = run_cells(case.geom, params, jnp.asarray(n_iters, jnp.int32),
                    chunk=chunk, max_chunks=max_chunks, stride=trace_stride)
    return _grid_results(case, out, sizes, profiles, dts, n_iters=n_iters,
                         warmup=warmup, chunk=chunk, stride=trace_stride)


# --------------------------------------------------------------------------
# Scale-batched grids: heterogeneous (system, n_nodes) cells in one vmap
# --------------------------------------------------------------------------


def _round_pow2(x: int) -> int:
    """Bucket-size policy: round every geometry dim up to a power of two
    so different cell sets resolve to the same padded shape and the JIT
    cache hits across sweeps (DESIGN.md §11)."""
    return 1 << max(0, int(x) - 1).bit_length()


def bucket_stack(geoms: Sequence[FabricGeometry]):
    """Pad geometries to their shared power-of-two GeometryDims bucket
    and stack them for run_cells_hetero — THE bucket policy, shared by
    run_scale_grid and mitigation.search.run_candidates (one place, so
    the two paths cannot diverge on which compiles they reuse). Returns
    ``(dims, stacked)``."""
    dims = bucket_dims(geoms, round_up=_round_pow2)
    return dims, stack_geometries([pad_geometry(g, dims) for g in geoms])


@dataclasses.dataclass
class PendingGrid:
    """A dispatched (but not yet marshalled) scale grid. ``launch_scale_
    grid`` returns immediately after the async device dispatch; calling
    :meth:`results` blocks on the outputs and marshals them — so several
    grids can be launched back-to-back and their host-side result
    assembly overlaps the device compute of the grids still in flight
    (the sweep launcher's async pipeline)."""

    cases: List[GridCase]
    out: object  # dict-like of batched run outputs (possibly lazy)
    sizes: tuple
    profiles: tuple
    all_dts: List[List[float]]
    n_iters: int
    warmup: int
    chunk: int
    stride: int

    def results(self) -> List[BenchResult]:
        return [r for k, case in enumerate(self.cases)
                for r in _grid_results(case, self.out, self.sizes,
                                       self.profiles, self.all_dts[k],
                                       n_iters=self.n_iters,
                                       warmup=self.warmup, chunk=self.chunk,
                                       stride=self.stride,
                                       cell_prefix=(k,))]


def launch_scale_grid(cells: Sequence[ScaleCell], victim_coll: str,
                      aggr_coll: str, sizes: Sequence[float],
                      profiles: Sequence[cong.Profile], *, n_iters: int = 60,
                      warmup: int = 10, dt: Optional[float] = None,
                      max_steps: int = 200_000, chunk: int = 2048,
                      trace_stride: int = 8, phased: bool = False,
                      jobs: Optional[Sequence[traffic.JobSpec]] = None,
                      mesh=None, launcher=None) -> PendingGrid:
    """Build + DISPATCH a cross-scale grid and return a
    :class:`PendingGrid` without blocking on device compute (jax
    dispatch is async; the sharded launcher additionally fans the cell
    axis out across devices). ``results()`` marshals."""
    check_iter_budget(n_iters)
    launcher = _resolve_launcher(mesh, launcher)
    with_ft = cong.needs_fault_table(profiles)
    intra = any(p.node_cap_frac > 0 for p in profiles)
    cases = []
    for sysname, n in cells:
        sysp = get_system(sysname) if isinstance(sysname, str) else sysname
        cases.append(build_case(sysp, int(n), victim_coll, aggr_coll,
                                phased=phased, jobs=jobs, intra_node=intra))
    sizes, profiles = tuple(sizes), tuple(profiles)
    if not cases:
        return PendingGrid([], {}, sizes, profiles, [], n_iters, warmup,
                           chunk, trace_stride)

    dims, stacked = bucket_stack([case.geom for case in cases])
    all_dts = [_cell_dts(case, sizes, len(profiles), dt, case.lat())
               for case in cases]
    sub_cells = [(float(v), prof) for v in sizes
                 for prof in [cong.no_congestion()] + list(profiles)]
    params = stack_params([
        stack_params([case.cell_params(v, prof, d, n_flows=dims.n_flows,
                                       with_fault_table=with_ft)
                      for (v, prof), d in zip(sub_cells, all_dts[k])])
        for k, case in enumerate(cases)])
    run = launcher if launcher is not None else run_cells_hetero
    out = run(stacked, params, jnp.asarray(n_iters, jnp.int32),
              chunk=chunk, max_chunks=-(-max_steps // chunk),
              stride=trace_stride)
    return PendingGrid(cases, out, sizes, profiles, all_dts, n_iters,
                       warmup, chunk, trace_stride)


def run_scale_grid(cells: Sequence[ScaleCell], victim_coll: str,
                   aggr_coll: str, sizes: Sequence[float],
                   profiles: Sequence[cong.Profile], *, n_iters: int = 60,
                   warmup: int = 10, dt: Optional[float] = None,
                   max_steps: int = 200_000, chunk: int = 2048,
                   trace_stride: int = 8, phased: bool = False,
                   jobs: Optional[Sequence[traffic.JobSpec]] = None,
                   mesh=None, launcher=None) -> List[BenchResult]:
    """A whole cross-scale experiment — heterogeneous ``(system,
    n_nodes)`` cells x (vector size x profile) — in one batched call per
    geometry *bucket*.

    Routing is traced data (SimParams.policy) since the mitigation lab,
    so mixed-routing cell lists no longer split into per-mode buckets:
    ALL cells pad to one power-of-two GeometryDims bucket (masks keep
    the padding provably inert — a padded run is bit-identical to its
    unpadded equivalent) and stack under a nested ``jit(vmap(vmap(...)))``
    — an EDR/HDR/NDR/Slingshot x {16..512} nodes x collective sweep
    compiles the simulator ONCE per GeometryDims bucket (asserted via
    simulator.TRACE_COUNTS in tests/test_grid.py). Results come back in
    input order: cells major, then sizes, then baseline/profiles
    (matching a sequential per-cell run_grid concatenation).

    ``mesh``/``launcher`` shard the dispatch across devices
    (launch/sweep.py); the default per-device dispatcher is bit-identical
    to the single-device path (asserted in tests and the CI smoke).
    Launch/collect are split in :func:`launch_scale_grid` for callers
    that overlap several grids."""
    return launch_scale_grid(cells, victim_coll, aggr_coll, sizes, profiles,
                             n_iters=n_iters, warmup=warmup, dt=dt,
                             max_steps=max_steps, chunk=chunk,
                             trace_stride=trace_stride, phased=phased,
                             jobs=jobs, mesh=mesh,
                             launcher=launcher).results()


def run_point(system: SystemPreset, n_nodes: int, victim_coll: str,
              aggr_coll: str, vector_bytes: float,
              profile: cong.Profile, *, n_iters: int = 60, warmup: int = 10,
              dt: Optional[float] = None, max_steps: int = 200_000,
              return_traces: bool = False, phased: bool = False,
              jobs: Optional[Sequence[traffic.JobSpec]] = None,
              seed: int = 7):
    """One heatmap cell: baseline (aggressors off) vs congested run.

    Implemented as a 2-cell grid (baseline + congested batched in one
    call). ``seed`` picks the allocation draw (collapse depth under
    incast is placement-dependent; see allocate()).
    """
    check_iter_budget(n_iters)
    with_ft = cong.needs_fault_table([profile])
    case = build_case(system, n_nodes, victim_coll, aggr_coll,
                      phased=phased, jobs=jobs, seed=seed,
                      intra_node=profile.node_cap_frac > 0)
    lat = case.lat()
    if dt is None:
        dt = choose_dt(case.topo, case.n_victims, vector_bytes, lat,
                       n_phases=case.max_phases)
    chunk, stride = 2048, 8
    max_chunks = -(-max_steps // chunk)
    params = stack_params([
        case.cell_params(vector_bytes, cong.no_congestion(), dt,
                         with_fault_table=with_ft),
        case.cell_params(vector_bytes, profile, dt,
                         with_fault_table=with_ft)])
    out = run_cells(case.geom, params, jnp.asarray(n_iters, jnp.int32),
                    chunk=chunk, max_chunks=max_chunks, stride=stride)
    base = summarize(out, n_iters=n_iters, warmup=warmup, dt=dt, chunk=chunk,
                     stride=stride, cell=0)
    cong_res = summarize(out, n_iters=n_iters, warmup=warmup, dt=dt,
                         chunk=chunk, stride=stride, cell=1)
    t_u = mean_iter_time(base, lat)
    t_c = mean_iter_time(cong_res, lat)
    dnf = base.n_done == 0 or cong_res.n_done == 0
    res = BenchResult(
        system=system.name, n_nodes=n_nodes,
        victim=victim_label(case.victim_coll, case.primary_phased),
        aggressor=case.aggr_coll or "none", profile=profile.kind,
        vector_bytes=vector_bytes, t_uncongested_s=t_u, t_congested_s=t_c,
        ratio=float("nan") if dnf else (t_u / t_c if t_c > 0 else 0.0),
        victim_goodput_gbps=float(np.mean(cong_res.victim_rate_trace[-200:])
                                  * 8 / 1e9)
        if len(cong_res.victim_rate_trace) else 0.0,
        n_iters=(base.n_done, cong_res.n_done),
        job_times=_job_times(out, case, n_iters=n_iters, warmup=warmup,
                             cell=1),
        warmup_ok=base.warmup_ok and cong_res.warmup_ok,
        dnf=dnf,
    )
    if return_traces:
        return res, base, cong_res
    return res


# --------------------------------------------------------------------------
# Single-trace helpers
# --------------------------------------------------------------------------


def _run_uncongested(system: SystemPreset, topo, nodes, coll: str,
                     vector_bytes: float, *, dt: float, n_iters: int,
                     warmup: int, max_steps: int = 200_000):
    """One aggressor-free run on an explicit topology/allocation — the
    shared helper behind goodput_trace and straggler_impact."""
    check_iter_budget(n_iters)
    flows = cong.build_flowset(topo, nodes, [], coll, "", vector_bytes,
                               routing_mode=system.static_routing,
                               k_max=system.k_max)
    geom = make_geometry(topo, flows)
    params = make_params(system.cc, dt=dt,
                         bytes_per_iter=flows.bytes_per_iter,
                         host_caps=flows.host_caps,
                         env=cong.no_congestion().params(),
                         policy=default_policy(system))
    chunk, stride = 2048, 8
    out = run_cell(geom, params, jnp.asarray(n_iters, jnp.int32),
                   chunk=chunk, max_chunks=-(-max_steps // chunk),
                   stride=stride)
    return summarize(out, n_iters=n_iters, warmup=warmup, dt=dt, chunk=chunk,
                     stride=stride)


def goodput_trace(system: SystemPreset, n_nodes: int, coll: str,
                  vector_bytes: float, *, n_iters: int = 40,
                  dt: float = 20e-6, max_steps: int = 200_000):
    """Self-congestion run (no aggressors) — Fig. 3 sawtooth experiments."""
    topo = machine_topology(system) if system.machine_nodes \
        else system.make_topology(n_nodes)
    nodes = allocate(system, n_nodes)
    return _run_uncongested(system, topo, nodes, coll, vector_bytes, dt=dt,
                            n_iters=n_iters, warmup=5, max_steps=max_steps)


def straggler_impact(system: SystemPreset, n_nodes: int, coll: str,
                     vector_bytes: float, *, slow_factor: float = 0.1,
                     n_iters: int = 25,
                     straggler: Optional[int] = None) -> dict:
    """Model a straggler as a degraded injection link (DESIGN.md §7):
    one node's NIC runs at ``slow_factor`` of line rate; a synchronous
    collective is gated by its slowest member, so the iteration time
    stretches toward 1/slow_factor. Runtime policy (fault.StepMonitor +
    elastic_plan) uses this as the model for when eviction pays.

    ``straggler`` indexes into the allocation (default: its middle node).
    """
    topo = machine_topology(system) if system.machine_nodes \
        else system.make_topology(n_nodes)
    nodes = allocate(system, n_nodes)
    base = _run_uncongested(system, topo, nodes, coll, vector_bytes,
                            dt=5e-6, n_iters=n_iters, warmup=5)

    if straggler is None:
        straggler = len(nodes) // 2
    victim_node = int(nodes[straggler])
    topo_slow = copy.copy(topo)
    caps = topo.caps.copy()
    for li, (a, b) in enumerate(topo.link_names):
        if a == ("h", victim_node) or b == ("h", victim_node):
            caps[li] = caps[li] * slow_factor
    topo_slow.caps = caps
    slow = _run_uncongested(system, topo_slow, nodes, coll, vector_bytes,
                            dt=5e-6, n_iters=n_iters, warmup=5)
    t_base = float(np.mean(base.iter_times)) if len(base.iter_times) else 0.0
    t_slow = float(np.mean(slow.iter_times)) if len(slow.iter_times) \
        else float("inf")
    return {"t_base_s": t_base, "t_straggler_s": t_slow,
            "slowdown": t_slow / t_base if t_base else float("inf")}
