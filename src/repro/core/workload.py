"""Seeded stochastic fleet workloads (ROADMAP item 3, DESIGN.md §15).

The paper's motivation is congestion driven by "heterogeneous traffic
patterns resulting from diverse workload mixes", and Jha et al.
(PAPERS.md, arXiv:1907.05312) characterize the congestion that matters in
production from *fleet telemetry* — distributions over thousands of
arrival patterns, not single hand-scripted job sets. This module lowers a
stochastic workload model into the batched engine so thousands of seeds
replay as one ``jit(vmap)``:

* **Template (host side, per workload config).** The *structure* of the
  workload is fixed: long-lived training tenants (phased ring / AlltoAll
  programs with compute gaps, via the normal JobSpec compiler) plus
  :attr:`WorkloadSpec.short_slots` short-flow rows appended to the
  program — each slot a (src, dst) pair drawn once from the allocation
  with the pinned splitmix64 template stream. Paths, NIC caps and the
  geometry are bound once (congestion.bind_program) and shared by every
  seed: topology binding cannot be traced, so everything a seed varies
  must be *traced data*, not structure.

* **Per-seed lowering (inside the trace).** :func:`lower_seed` draws,
  through ``jax.random`` from the seed alone: which slots fire this seed
  (Bernoulli thinning at rate ``arrivals_mean / short_slots`` — the
  binomial construction of a Poisson arrival count), their arrival times
  (uniform over the horizon — the order statistics of a Poisson process),
  their sizes (lognormal, optionally mixed with a bounded Pareto tail —
  :attr:`WorkloadSpec.short_pareto_frac`), a per-tenant CC kind from
  :attr:`cc_mix`, and
  a tenant start stagger. All of it lands in existing traced SimParams
  leaves (``bytes_per_iter``, ``flow_start``, ``fct_mask``, per-flow
  ``kind``), so a 1024-seed batch is ``vmap(lower_seed)`` feeding the
  stock engine — one compile per geometry bucket, zero host round-trips.

An idle slot carries 0 bytes -> never ``alive`` -> provably inert, the
same contract as geometry pad flows. The shorts job's phase gap is
:data:`SHORT_GAP_NEVER`, so drained slots never re-arm (one-shot flows,
unlike the tenants' repeating phase programs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core import bench, congestion as cong, traffic
from repro.core.fabric import cc as cc_lib
from repro.core.fabric import simulator as sim
from repro.core.fabric.routing import splitmix64
from repro.core.fabric import systems

# a phase gap no replay horizon ever reaches: short-flow slots are
# one-shot (their job's single phase never advances, so `enter` never
# re-arms a drained slot)
SHORT_GAP_NEVER = 1e9

_CC_KINDS = {"dcqcn": cc_lib.KIND_DCQCN, "ib": cc_lib.KIND_IB,
             "slingshot": cc_lib.KIND_SLINGSHOT,
             "ai_ecn": cc_lib.KIND_AI_ECN}


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One stochastic fleet-workload configuration (the template knobs;
    everything a *seed* varies is drawn inside the trace)."""

    system: str = "lumi"
    n_nodes: int = 32
    # long-lived training tenants: one phased job per collective listed
    tenant_collectives: Tuple[str, ...] = ("ring_allreduce", "alltoall")
    tenant_bytes: float = float(1 << 20)
    tenant_gap_s: float = 100e-6  # compute gap between schedule phases
    tenant_stagger_s: float = 500e-6  # per-seed uniform start offset
    # Poisson short flows: S padded slots, each active with probability
    # arrivals_mean / short_slots (binomial thinning ~ Poisson count)
    short_slots: int = 64
    arrivals_mean: float = 24.0
    horizon_s: float = 0.02  # arrival window (simulated seconds)
    short_bytes_median: float = float(256 << 10)
    short_sigma: float = 1.2  # lognormal shape (natural-log std)
    # heavy-tailed size mix (ROADMAP item 3 follow-up): a fraction of
    # short slots draw from a BOUNDED Pareto (inverse-CDF) instead of the
    # lognormal — datacenter flow-size surveys put most bytes in a
    # power-law tail the lognormal underweights. frac = 0.0 keeps the
    # legacy draws bit-identical (the Pareto keys are never consumed).
    short_pareto_frac: float = 0.0
    short_pareto_alpha: float = 1.3  # tail index (smaller = heavier)
    short_pareto_min: float = float(64 << 10)
    short_pareto_max: float = float(64 << 20)
    # per-tenant CC mix: (name, probability) — each job draws its kind
    cc_mix: Tuple[Tuple[str, float], ...] = (
        ("dcqcn", 0.5), ("ib", 0.25), ("slingshot", 0.25))
    template_seed: int = 0

    def __post_init__(self):
        if self.short_slots < 1:
            raise ValueError("short_slots must be >= 1")
        if not 0.0 <= self.short_pareto_frac <= 1.0:
            raise ValueError("short_pareto_frac must be in [0, 1]")
        if self.short_pareto_frac > 0:
            if self.short_pareto_alpha <= 0:
                raise ValueError("short_pareto_alpha must be > 0")
            if not 0 < self.short_pareto_min < self.short_pareto_max:
                raise ValueError("need 0 < short_pareto_min "
                                 "< short_pareto_max")
        if not self.cc_mix:
            raise ValueError("cc_mix must not be empty")
        for name, _ in self.cc_mix:
            if name not in _CC_KINDS:
                raise KeyError(f"unknown CC kind {name!r}; expected one "
                               f"of {sorted(_CC_KINDS)}")


@dataclasses.dataclass
class ReplayTemplate:
    """Host-built, seed-independent replay structure: the bound geometry
    plus the per-flow base tables :func:`lower_seed` overlays."""

    spec: WorkloadSpec
    geom: sim.FabricGeometry
    dt: float
    policy: int
    cc: cc_lib.CCParams  # scalar CC knobs (kind is drawn per seed)
    env: np.ndarray  # envelope components (steady — tenants self-gate)
    base_bytes: np.ndarray  # (F,) tenant bytes; short/pad rows 0
    host_caps: np.ndarray  # (F,)
    fct_mask: np.ndarray  # (F,) 1.0 on short rows
    flow_job: np.ndarray  # (F,) incl. pad rows
    job_is_tenant: np.ndarray  # (J,)
    short_idx: np.ndarray  # (S,) row indices of the short slots
    n_jobs: int  # incl. pad jobs (grows under pad_template)
    # real jobs (tenants + shorts) — job-level draws use THIS count, so
    # bucket padding cannot perturb a seed's draws (padding inertness)
    n_real_jobs: int
    job_names: Tuple[str, ...]
    # mix lowering: kind id per mix entry + log-probabilities
    mix_kinds: np.ndarray  # (M,) int32
    mix_logp: np.ndarray  # (M,) float32

    @property
    def n_flows(self) -> int:
        return int(self.geom.n_flows)


def _short_endpoints(nodes: np.ndarray, n_slots: int,
                     template_seed: int) -> Tuple[np.ndarray, np.ndarray]:
    """(src, dst) per slot, distinct by construction, drawn from the
    pinned splitmix64 template stream (reproducible across platforms)."""
    n = len(nodes)
    slot = np.arange(n_slots, dtype=np.uint64)
    h1 = splitmix64(slot ^ (np.uint64(template_seed) << np.uint64(32)))
    h2 = splitmix64(h1)
    si = (h1 % np.uint64(n)).astype(np.int64)
    off = 1 + (h2 % np.uint64(max(n - 1, 1))).astype(np.int64)
    return nodes[si], nodes[(si + off) % n]


def build_template(spec: WorkloadSpec,
                   pad_to: Optional[Tuple[int, int, int]] = None
                   ) -> ReplayTemplate:
    """Compile the tenant programs, append the short-flow slots, bind to
    the system topology. ``pad_to=(n_flows, n_jobs, n_phases)`` pads the
    program to bucket dims (inert rows, traffic.pad_program)."""
    sysp = systems.get_system(spec.system)
    topo = bench.machine_topology(sysp, spec.n_nodes)
    nodes = bench.allocate(sysp, spec.n_nodes, seed=7 + spec.template_seed)
    jobs = [traffic.JobSpec(f"tenant{i}_{coll}", coll,
                            vector_bytes=spec.tenant_bytes, phased=True,
                            gap_s=spec.tenant_gap_s, sweep_bytes=False)
            for i, coll in enumerate(spec.tenant_collectives)]
    jobs = traffic.split_nodes(nodes, jobs)
    prog = traffic.compile_programs(jobs, validate=True)

    # ---- append the short-flow job (hand-assembled rows; the JobSpec
    # compiler only knows collectives, and check_program skips jobs
    # without a node assignment) ----
    S = spec.short_slots
    s_src, s_dst = _short_endpoints(np.asarray(nodes), S,
                                    spec.template_seed)
    jt = prog.n_jobs  # shorts job id
    p_max = int(prog.phase_gap.shape[1])
    phase_gap = np.zeros((jt + 1, p_max), np.float32)
    phase_gap[:jt] = prog.phase_gap
    phase_gap[jt, 0] = SHORT_GAP_NEVER
    prog = traffic.TrafficProgram(
        jobs=prog.jobs + (traffic.JobSpec("shorts", "shortflows",
                                          sweep_bytes=False),),
        src=np.concatenate([prog.src, s_src.astype(np.int32)]),
        dst=np.concatenate([prog.dst, s_dst.astype(np.int32)]),
        bytes_per_phase=np.concatenate(
            [prog.bytes_per_phase,
             np.full((S,), spec.short_bytes_median)]),
        flow_job=np.concatenate(
            [prog.flow_job, np.full((S,), jt, np.int32)]),
        flow_phase=np.concatenate([prog.flow_phase,
                                   np.zeros((S,), np.int32)]),
        n_phases=np.concatenate([prog.n_phases, [1]]).astype(np.int32),
        phase_gap=phase_gap,
        env_gated=np.concatenate([prog.env_gated, [False]]),
        sweep_mask=np.concatenate([prog.sweep_mask,
                                   np.zeros((S,), bool)]))
    traffic.check_program(prog)  # tenants still conserve wire bytes
    if pad_to is not None:
        prog = traffic.pad_program(prog, n_flows=pad_to[0],
                                   n_jobs=pad_to[1], n_phases=pad_to[2])

    flows = cong.bind_program(topo, prog,
                              routing_mode=sysp.static_routing,
                              k_max=sysp.k_max, seed=spec.template_seed)
    geom = sim.make_geometry(topo, flows)

    n0 = len(jobs[0].nodes)
    dt = bench.choose_dt(topo, n0, spec.tenant_bytes,
                         cong.latency_model(spec.tenant_collectives[0], n0),
                         int(prog.n_phases.max()))

    fjob = np.asarray(prog.flow_job)
    short_mask = fjob == jt
    base_bytes = np.where(short_mask, 0.0,
                          prog.bytes_per_phase).astype(np.float32)
    n_jobs = len(prog.n_phases)
    job_is_tenant = np.zeros((n_jobs,), np.float32)
    job_is_tenant[:jt] = 1.0
    names = tuple(j.name for j in prog.jobs) + tuple(
        traffic.PAD_JOB_NAME for _ in range(n_jobs - len(prog.jobs)))
    mix_names = [m for m, _ in spec.cc_mix]
    mix_p = np.asarray([p for _, p in spec.cc_mix], np.float64)
    mix_p = mix_p / mix_p.sum()
    return ReplayTemplate(
        spec=spec, geom=geom, dt=float(dt),
        policy=int(systems.default_policy(sysp)),
        cc=sysp.cc, env=cong.steady().params(),
        base_bytes=base_bytes,
        host_caps=np.asarray(flows.host_caps, np.float32),
        fct_mask=short_mask.astype(np.float32),
        flow_job=fjob.astype(np.int32),
        job_is_tenant=job_is_tenant,
        short_idx=np.nonzero(short_mask)[0].astype(np.int32),
        n_jobs=n_jobs, n_real_jobs=jt + 1, job_names=names,
        mix_kinds=np.asarray([_CC_KINDS[m] for m in mix_names], np.int32),
        mix_logp=np.log(mix_p).astype(np.float32))


def pad_template(t: ReplayTemplate,
                 dims: sim.GeometryDims) -> ReplayTemplate:
    """Pad a template to bucket dims so heterogeneous systems stack
    (mirrors bench.bucket_stack + GridCase.cell_params padding)."""
    F, J = dims.n_flows, dims.n_jobs
    pad = traffic.pad_rows
    return dataclasses.replace(
        t, geom=sim.pad_geometry(t.geom, dims),
        base_bytes=pad(t.base_bytes, F, 0.0),
        host_caps=pad(t.host_caps, F, 1.0),
        fct_mask=pad(t.fct_mask, F, 0.0),
        flow_job=pad(t.flow_job, F, J - 1),
        job_is_tenant=pad(t.job_is_tenant, J, 0.0),
        n_jobs=J,
        job_names=t.job_names + tuple(
            traffic.PAD_JOB_NAME for _ in range(J - len(t.job_names))))


# --------------------------------------------------------------------------
# Per-seed lowering (traced: vmap over seeds shares one compile)
# --------------------------------------------------------------------------


def lower_seed(t: ReplayTemplate, seed) -> sim.SimParams:
    """One seed -> SimParams, entirely inside the trace. Vmappable: the
    1024-seed batch is ``vmap(lower_seed)`` and lowers identically to the
    single-seed call (batch invariance, tests/test_workload.py)."""
    import jax
    import jax.numpy as jnp

    spec = t.spec
    key = jax.random.PRNGKey(seed)
    k_act, k_size, k_time, k_cc, k_st = jax.random.split(key, 5)
    S = spec.short_slots
    # Poisson arrivals via slot thinning + order-statistics times
    p_on = min(spec.arrivals_mean / S, 1.0)
    active = jax.random.bernoulli(k_act, p_on, (S,))
    sizes = spec.short_bytes_median * jnp.exp(
        spec.short_sigma * jax.random.normal(k_size, (S,)))
    if spec.short_pareto_frac > 0:
        # bounded Pareto via inverse CDF: x = xm (1 - U (1 - (xm/xM)^a))
        # ^(-1/a), exactly in [xm, xM]. Drawn from keys folded off the
        # seed key, so the legacy 5-way split (and therefore every
        # frac=0 draw: activation, times, CC kinds, staggers) is
        # untouched — only sizes change, and only on the mixed-in slots.
        k_mix, k_par = jax.random.split(jax.random.fold_in(key, 1))
        a = spec.short_pareto_alpha
        ratio = (spec.short_pareto_min / spec.short_pareto_max) ** a
        u = jax.random.uniform(k_par, (S,))
        pareto = spec.short_pareto_min \
            * (1.0 - u * (1.0 - ratio)) ** (-1.0 / a)
        heavy = jax.random.bernoulli(k_mix, spec.short_pareto_frac, (S,))
        sizes = jnp.where(heavy, pareto, sizes)
    starts = jax.random.uniform(k_time, (S,), minval=0.0,
                                maxval=spec.horizon_s)
    short_bytes = jnp.where(active, sizes, 0.0).astype(jnp.float32)
    # per-job CC kind from the mix (shorts draw a fleet-mix kind like any
    # tenant). Draw shapes use the REAL job count so the same seed draws
    # the same values no matter how far the template was bucket-padded;
    # pad jobs get a constant kind / zero stagger (inert either way).
    nr, n_pad = t.n_real_jobs, t.n_jobs - t.n_real_jobs
    mix_idx = jax.random.categorical(
        k_cc, jnp.asarray(t.mix_logp), shape=(nr,))
    job_kind = jnp.concatenate(
        [jnp.asarray(t.mix_kinds)[mix_idx],
         jnp.full((n_pad,), t.mix_kinds[0], jnp.int32)])
    flow_kind = job_kind[jnp.asarray(t.flow_job)]
    # tenant start stagger (phase alignment varies per seed)
    job_start = jax.random.uniform(k_st, (nr,), minval=0.0,
                                   maxval=max(spec.tenant_stagger_s, 1e-12))
    job_start = jnp.concatenate([job_start, jnp.zeros((n_pad,))])
    job_start = job_start * jnp.asarray(t.job_is_tenant)
    sidx = jnp.asarray(t.short_idx)
    flow_start = jnp.asarray(t.job_is_tenant)[jnp.asarray(t.flow_job)] \
        * job_start[jnp.asarray(t.flow_job)]
    flow_start = flow_start.at[sidx].set(starts)
    bpi = jnp.asarray(t.base_bytes).at[sidx].set(short_bytes)
    params = sim.make_params(
        t.cc, dt=t.dt, bytes_per_iter=bpi, host_caps=t.host_caps,
        env=t.env, policy=t.policy, flow_start=flow_start,
        fct_mask=t.fct_mask)
    return dataclasses.replace(params, kind=flow_kind.astype(jnp.int32))


def lower_seeds(t: ReplayTemplate, seeds) -> sim.SimParams:
    """Batched lowering: SimParams with a leading seed axis."""
    import jax
    import jax.numpy as jnp

    seeds = jnp.asarray(np.asarray(seeds), jnp.uint32)
    return jax.vmap(lambda s: lower_seed(t, s))(seeds)


def replay_budget(t: ReplayTemplate, chunk: int = 2048,
                  tail_frac: float = 0.5) -> int:
    """Chunk budget covering the arrival horizon plus a drain tail (late
    arrivals need time to complete)."""
    steps = (1.0 + tail_frac) * t.spec.horizon_s / t.dt
    return max(int(np.ceil(steps / chunk)), 1)


def run_replay(templates: Sequence[ReplayTemplate], seeds, *,
               chunk: int = 2048, metrics: bool = True,
               with_trace: bool = False, launcher=None, mesh=None):
    """Replay ``seeds`` over one or more templates in ONE batched hetero
    call: geometries bucket-pad and stack (bench.bucket_stack policy),
    params get a (template, seed) leading pair, streaming metrics ride
    the scan. Returns ``(out, padded_templates)``."""
    import jax.numpy as jnp

    dims, geoms = bench.bucket_stack([t.geom for t in templates])
    padded = [pad_template(t, dims) for t in templates]
    params = sim.stack_params([lower_seeds(t, seeds) for t in padded])
    max_chunks = max(replay_budget(t, chunk) for t in padded)
    n_iters = jnp.asarray(sim.TDONE_SLOTS, jnp.int32)  # budget-bounded
    kw = dict(chunk=chunk, max_chunks=max_chunks, stride=8,
              metrics=metrics, with_trace=with_trace)
    if launcher is not None:
        out = launcher(geoms, params, n_iters, **kw)
    else:
        out = sim.run_cells_hetero(geoms, params, n_iters, mesh=mesh, **kw)
    return out, padded


# --------------------------------------------------------------------------
# Host-side summary
# --------------------------------------------------------------------------


def tenant_bytes(out_fbytes: np.ndarray, t: ReplayTemplate) -> np.ndarray:
    """Per-job delivered bytes (..., J) from per-flow accumulators."""
    fb = np.asarray(out_fbytes)
    J = t.n_jobs
    res = np.zeros(fb.shape[:-1] + (J,), np.float64)
    for j in range(J):
        m = t.flow_job == j
        if m.any():
            res[..., j] = fb[..., m].sum(-1)
    return res


def summarize_replay(out, padded: Sequence[ReplayTemplate],
                     qs=None) -> list:
    """One summary dict per template: aggregate + per-seed percentiles,
    per-tenant slowdown stats and delivered bytes. Host-side NumPy over
    the O(B x bins) outputs only."""
    from repro.core import metrics as met

    qs = qs or met.QUANTILES
    res = []
    for k, t in enumerate(padded):
        h_qd = np.asarray(out["h_qd"])[k]  # (B, NBINS)
        h_fct = np.asarray(out["h_fct"])[k]
        agg_qd = met.percentiles(h_qd.sum(0), qs)
        agg_fct = met.percentiles(h_fct.sum(0), qs)
        wn, wmean, wstd = met.welford_finalize(
            np.asarray(out["wn"])[k].sum(0),
            # merged mean across seeds: weight per-seed means by counts
            _wmerge_mean(np.asarray(out["wn"])[k],
                         np.asarray(out["wmean"])[k]),
            _wmerge_m2(np.asarray(out["wn"])[k],
                       np.asarray(out["wmean"])[k],
                       np.asarray(out["wm2"])[k]))
        jobs = {}
        tb = tenant_bytes(out["fbytes"], t)
        for j, name in enumerate(t.job_names):
            if name == traffic.PAD_JOB_NAME:
                continue
            jobs[name] = {
                "completions": float(wn[j]),
                "slowdown_mean": float(wmean[j]),
                "slowdown_std": float(wstd[j]),
                "bytes_mean": float(tb[k, :, j].mean()),
            }
        res.append({
            "system": t.spec.system, "n_nodes": t.spec.n_nodes,
            "dt_s": t.dt,
            "qdelay_s": {str(q): float(v) for q, v in agg_qd.items()},
            "fct_s": {str(q): float(v) for q, v in agg_fct.items()},
            "fct_samples": float(h_fct.sum()),
            "qdelay_samples": float(h_qd.sum()),
            "jobs": jobs,
        })
    return res


def _wmerge_mean(wn, wmean):
    tot = np.maximum(wn.sum(0), 1.0)
    return (wn * wmean).sum(0) / tot


def _wmerge_m2(wn, wmean, wm2):
    """Chan merge of per-seed accumulators into one (host side, exact)."""
    tot = np.maximum(wn.sum(0), 1.0)
    gmean = (wn * wmean).sum(0) / tot
    return wm2.sum(0) + (wn * (wmean - gmean) ** 2).sum(0)
