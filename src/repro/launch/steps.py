"""Step builders shared by the dry-run, the trainer, and the server."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.api import (batch_shapes, batch_specs, build_model,
                              decode_inputs, to_shardings)
from repro.optim.adamw import OptConfig, get_optimizer


def make_train_step(model, optimizer):
    def train_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state["params"], batch)
        new_params, new_opt, gnorm = optimizer.update(
            grads, state["opt"], state["params"], state["step"])
        metrics = dict(metrics, grad_norm=gnorm, total_loss=loss)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def train_state_specs(model, optimizer):
    return {"params": model.param_specs,
            "opt": optimizer.state_specs(model.param_specs),
            "step": P()}


def train_state_shapes(model, optimizer):
    return {"params": model.param_shapes,
            "opt": optimizer.state_shapes(model.param_shapes),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def init_train_state(model, optimizer, rng):
    params = model.init(rng)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


# --------------------------------------------------------------------------
# Lowering helpers (used by dryrun.py and the launchers)
# --------------------------------------------------------------------------


def lower_cell(cfg, shape, mesh, rules, *, opt_overrides=None, donate=True):
    """Lower one (arch x shape) cell on ``mesh``. Returns jax.stages.Lowered."""
    model = build_model(cfg, rules, mesh)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        optimizer = get_optimizer(cfg.optimizer, opt_overrides or OptConfig())
        step_fn = make_train_step(model, optimizer)
        state_sh = to_shardings(mesh, train_state_specs(model, optimizer))
        batch_sh = to_shardings(mesh, batch_specs(cfg, rules,
                                                  shape.global_batch))
        state_shapes = train_state_shapes(model, optimizer)
        b_shapes = batch_shapes(cfg, shape)
        metrics_sh = {"loss": repl, "aux_loss": repl, "grad_norm": repl,
                      "total_loss": repl}
        fn = jax.jit(step_fn,
                     in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, metrics_sh),
                     donate_argnums=(0,) if donate else ())
        return fn.lower(state_shapes, b_shapes)

    model_sh = to_shardings(mesh, model.param_specs)
    if shape.kind == "prefill":
        batch_sh = to_shardings(mesh, batch_specs(cfg, rules,
                                                  shape.global_batch))
        b_shapes = batch_shapes(cfg, shape)
        cache_sh = to_shardings(mesh, model.cache_specs(shape.global_batch))
        fn = jax.jit(model.prefill,
                     in_shardings=(model_sh, batch_sh),
                     out_shardings=(repl, cache_sh))
        return fn.lower(model.param_shapes, b_shapes)

    if shape.kind == "decode":
        (cache, tokens, pos), (cache_specs, tok_spec, pos_spec) = \
            decode_inputs(cfg, shape, model)
        cache_sh = to_shardings(mesh, cache_specs)
        fn = jax.jit(model.decode,
                     in_shardings=(model_sh, cache_sh,
                                   NamedSharding(mesh, tok_spec),
                                   NamedSharding(mesh, pos_spec)),
                     out_shardings=(repl, cache_sh),
                     donate_argnums=(1,) if donate else ())
        return fn.lower(model.param_shapes, cache, tokens, pos)

    raise ValueError(shape.kind)
