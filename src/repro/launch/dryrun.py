import os

from repro.jax_compat import force_host_device_count

# APPEND the device-count flag (replacing only a previous device-count
# entry): user-set XLA_FLAGS must survive a dryrun import.
force_host_device_count(512)

# Everything below runs with 512 placeholder host devices (dry-run ONLY —
# smoke tests and benches see the real single device; see the brief).
import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

# REPRO_DRYRUN_DIR overrides the artifact directory (test fixtures
# generate minimal artifacts into a tmpdir this way).
ARTIFACTS = os.environ.get("REPRO_DRYRUN_DIR") or os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

# Target-hardware constants (TPU v5e-class, per the brief)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link ICI


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             variant: str = "", smoke: bool = False) -> dict:
    """Lower + compile one (arch x shape x mesh) cell and write its
    roofline/HLO artifact. ``smoke=True`` swaps in the reduced config, a
    shrunken shape, and the real host mesh — a seconds-scale cell with
    the identical artifact layout, used by the test fixture that needs a
    real dryrun artifact without the full 512-device sweep."""
    import dataclasses as _dc

    from repro.core.fabric.simulator import ensure_compile_cache

    ensure_compile_cache(os.path.join(ARTIFACTS, "..", "xla_cache"),
                         min_compile_secs=10.0)

    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.launch.hlo_stats import analyze
    from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                                   rules_for)
    from repro.launch.steps import lower_cell

    cfg = get_config(arch)
    if variant:
        from repro.configs.opt_variants import apply_variant
        cfg = apply_variant(cfg, variant)
    shape = SHAPES[shape_name]
    if shape_name not in [s.name for s in cfg.shapes()]:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skip",
                "reason": "long_500k inapplicable: pure full-attention arch "
                          "(DESIGN.md §6)"}

    if smoke:
        cfg = cfg.reduced()
        shape = _dc.replace(shape, seq_len=min(shape.seq_len, 512),
                            global_batch=min(shape.global_batch, 8))
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    rules = rules_for(cfg, mesh)

    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, rules)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    out = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "n_devices": n_dev, "status": "ok",
           "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2)}
    if smoke:
        out["smoke"] = True

    try:
        ma = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_per_device_bytes": int(ma.argument_size_in_bytes
                                         + ma.output_size_in_bytes
                                         + ma.temp_size_in_bytes
                                         - ma.alias_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        out["memory"] = {"error": str(e)}

    try:
        ca = compiled.cost_analysis()
        out["xla_cost_analysis"] = {
            "flops_single_visit": float(ca.get("flops", -1.0)),
            "bytes_accessed_single_visit": float(ca.get("bytes accessed", -1.0)),
        }
    except Exception as e:  # pragma: no cover
        out["xla_cost_analysis"] = {"error": str(e)}

    stats = analyze(compiled.as_text(), n_dev)
    out["hlo"] = {
        "flops_per_device": stats["flops"],
        "hbm_bytes_per_device": stats["hbm_bytes"],
        "collectives": stats["collectives"],
        "top_dots": stats["top_dots"][:8],
        "top_collectives": stats["top_collectives"][:8],
        "top_bytes": stats["top_bytes"][:12],
    }

    # --- roofline terms (seconds), single-chip denominators ---
    wire = stats["collectives"]["total"]["wire_bytes"]
    operand = stats["collectives"]["total"]["operand_bytes"]
    terms = {
        "compute_s": stats["flops"] / PEAK_FLOPS,
        "memory_s": stats["hbm_bytes"] / HBM_BW,
        "collective_s": wire / LINK_BW,
        "collective_s_simple_recipe": operand / LINK_BW,
    }
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    out["roofline"] = terms

    # MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) — training cells only
    from repro.configs.base import SHAPES as _S
    if shape.kind == "train":
        n_active = cfg.active_param_count()
        tokens = shape.global_batch * shape.seq_len
        model_flops_global = 6.0 * n_active * tokens
        out["model_flops"] = {
            "n_params": cfg.param_count(),
            "n_active_params": n_active,
            "model_flops_global": model_flops_global,
            "model_flops_per_device": model_flops_global / n_dev,
            "useful_fraction": (model_flops_global / n_dev)
            / max(stats["flops"], 1.0),
        }
    return out


def cell_path(arch, shape, mesh_kind, variant="", smoke=False):
    base = ARTIFACTS if not variant else ARTIFACTS + "_" + variant
    os.makedirs(base, exist_ok=True)
    # smoke cells get their own filename so they can never shadow (or be
    # resumed as) a real production artifact of the same cell
    suffix = "__smoke" if smoke else ""
    return os.path.join(base, f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--mesh", default="single", choices=["single", "multi"])
    p.add_argument("--variant", default="",
                   help="optimization variant from configs/opt_variants.py; "
                        "results go to artifacts/dryrun_<variant>/")
    p.add_argument("--smoke", action="store_true",
                   help="reduced config + shrunken shape on the host "
                        "mesh: a seconds-scale cell with the same "
                        "artifact layout (test fixtures)")
    p.add_argument("--all", action="store_true",
                   help="sweep all (arch x shape x mesh) cells in "
                        "subprocesses (resumable)")
    p.add_argument("--force", action="store_true")
    p.add_argument("--timeout", type=int, default=3600)
    args = p.parse_args()
    os.makedirs(ARTIFACTS, exist_ok=True)

    if args.all:
        from repro.configs import all_arch_names
        from repro.configs.base import SHAPES
        cells = [(a, s, m) for m in ("single", "multi")
                 for a in all_arch_names() for s in SHAPES]
        done = failed = 0
        for arch, shape, mesh_kind in cells:
            path = cell_path(arch, shape, mesh_kind, args.variant)
            if os.path.exists(path) and not args.force:
                done += 1
                continue
            print(f"[dryrun] {arch} x {shape} x {mesh_kind} ...", flush=True)
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                   "--shape", shape, "--mesh", mesh_kind]
            if args.variant:
                cmd += ["--variant", args.variant]
            try:
                r = subprocess.run(cmd, timeout=args.timeout,
                                   env=dict(os.environ),
                                   capture_output=True, text=True)
                if r.returncode != 0:
                    failed += 1
                    with open(path + ".err", "w") as f:
                        f.write(r.stdout[-4000:] + "\n" + r.stderr[-8000:])
                    print(f"  FAILED (see {path}.err)", flush=True)
                else:
                    done += 1
                    print("  ok", flush=True)
            except subprocess.TimeoutExpired:
                failed += 1
                with open(path + ".err", "w") as f:
                    f.write("timeout")
                print("  TIMEOUT", flush=True)
        print(f"[dryrun] complete: {done} ok, {failed} failed", flush=True)
        return

    assert args.arch and args.shape
    try:
        out = run_cell(args.arch, args.shape, args.mesh, args.variant,
                       smoke=args.smoke)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    path = cell_path(args.arch, args.shape, args.mesh, args.variant,
                     smoke=args.smoke)
    with open(path, "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(json.dumps({k: out[k] for k in ("arch", "shape", "mesh", "status")
                      if k in out}))
    if out["status"] == "ok":
        print("memory:", out["memory"])
        print("roofline:", out["roofline"])


if __name__ == "__main__":
    main()
