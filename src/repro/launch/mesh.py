"""Mesh construction and axis-rule derivation.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any import.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on mesh construction
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: meshes are implicitly Auto
    AxisType = None

from repro.models.layers import AxisRules


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(data: int = 0, model: int = 1):
    """Mesh over whatever devices exist (tests / examples / smoke runs)."""
    n = len(jax.devices())
    data = data or max(1, n // model)
    return compat_make_mesh((data, model), ("data", "model"))


def make_sweep_mesh(n_devices: int = 0):
    """1-D ``('cells',)`` mesh for the sharded sweep launcher
    (launch/sweep.py): experiment batches shard along one axis — topology
    cells or candidate lanes — so the mesh is flat over however many
    (host) devices exist, or the first ``n_devices`` of them."""
    import numpy as np

    devs = jax.devices()
    if n_devices:
        devs = devs[:int(n_devices)]
    return jax.sharding.Mesh(np.array(devs), ("cells",))


def rules_for(cfg, mesh) -> AxisRules:
    """Derive AxisRules from an arch config and a mesh (DESIGN.md §4)."""
    names = tuple(mesh.axis_names)
    sizes = dict(zip(names, mesh.devices.shape))
    has_pod = "pod" in names
    dp = ("pod", "data") if has_pod else ("data",)
    if cfg is not None and cfg.pod_param_sharding == "fsdp" and has_pod:
        fsdp = ("pod", "data")
    else:
        fsdp = ("data",)
    return AxisRules(dp=dp, fsdp=fsdp, tp="model", ep=fsdp,
                     kv_seq="model", sizes=sizes)
