"""While-trip-count-aware analysis of optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` visits each computation once, so anything
inside a ``lax.scan`` (our layer stacks, attention chunk loops, SSM chunk
loops) is counted for a SINGLE iteration. The dry-run roofline instead uses
this module, which parses the HLO text, resolves ``while`` trip counts from
their condition computations, and multiplies per-computation statistics by
the product of enclosing loop trip counts:

  * dot/convolution FLOPs  (compute roofline term)
  * per-op operand+result bytes at fusion boundaries (memory term proxy)
  * collective operand/result/wire bytes (collective term), with per-chip
    wire bytes from standard ring-algorithm formulas.

Per-op ``metadata op_name`` attribution is kept for the top contributors so
§Perf iterations can tell WHICH einsum/collective dominates.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute", "ragged-all-to-all")

_TYPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_NAME_REF_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_METADATA_RE = re.compile(r'op_name="([^"]+)"')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "while", "conditional",
    "call", "optimization-barrier", "domain", "add-dependency",
}


def _shape_dims(type_str: str):
    """All (dtype, dims) groups in a type string (handles tuples)."""
    return [(d, tuple(int(x) for x in dims.split(",") if x))
            for d, dims in _TYPE_RE.findall(type_str)]


def _nbytes_of(groups) -> int:
    total = 0
    for dtype, dims in groups:
        n = _DTYPE_BYTES.get(dtype, 4)
        for d in dims:
            n *= d
        total += n
    return total


class _Op:
    __slots__ = ("name", "kind", "result_groups", "operands", "attrs",
                 "metadata")

    def __init__(self, name, kind, result_groups, operands, attrs, metadata):
        self.name = name
        self.kind = kind
        self.result_groups = result_groups
        self.operands = operands
        self.attrs = attrs
        self.metadata = metadata


_KIND_RE = re.compile(
    r"^\s*(?:\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[\d,]*\})?)\s+([\w\-]+)(?:-start)?\(")


def _parse_computation_ops(lines):
    ops = []
    symbols = {}
    for line in lines:
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        km = _KIND_RE.match(" " + rest)
        if not km:
            continue
        kind = km.group(1)
        type_part = rest[: rest.find(kind + "(") if kind + "(" in rest
                         else rest.find("(")]
        result_groups = _shape_dims(type_part)
        symbols[name] = result_groups
        paren = rest.find("(", rest.find(kind))
        depth, end = 0, len(rest)
        for i in range(paren, len(rest)):
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = rest[paren + 1: end]
        operands = _NAME_REF_RE.findall(operand_str)
        attrs = rest[end + 1:]
        md = _METADATA_RE.search(rest)
        ops.append(_Op(name, kind, result_groups, operands, attrs,
                       md.group(1) if md else ""))
    return ops, symbols


def parse_hlo(text: str):
    """Split module text into computations -> (ops, symbols, is_entry)."""
    comps = {}
    entry = None
    cur_name, cur_lines = None, []
    for line in text.splitlines():
        hm = _COMP_HEADER_RE.match(line)
        if hm and line.rstrip().endswith("{"):
            cur_name = hm.group(2)
            cur_lines = []
            comps[cur_name] = cur_lines
            if hm.group(1):
                entry = cur_name
            # header params double as symbols
            cur_lines.append("  " + _param_line(hm.group(3)))
            continue
        if line.strip() == "}":
            cur_name = None
            continue
        if cur_name is not None:
            cur_lines.append(line)
    parsed = {}
    for name, lines in comps.items():
        ops, symbols = _parse_computation_ops(lines)
        parsed[name] = (ops, symbols)
    return parsed, entry


def _param_line(params: str) -> str:
    # turn "x.1: bf16[4,128], w: f32[2]" into synthetic parameter ops
    out = []
    for part in re.findall(r"([\w.\-]+)\s*:\s*((?:\([^)]*\))|[a-z0-9]+\[[\d,]*\])",
                           params):
        out.append(f"%{part[0]} = {part[1]} parameter(0)")
    return "\n".join(out)


_CONST_VAL_RE = re.compile(r"constant\((\d+)\)")


def compute_multipliers(parsed, entry, raw_text: str):
    """mult[comp] = expected executions. Resolves while trip counts from the
    largest integer constant in the condition computation."""
    # constants per computation from raw text (value lives in the op line)
    const_by_comp = defaultdict(list)
    cur = None
    for line in raw_text.splitlines():
        hm = _COMP_HEADER_RE.match(line)
        if hm and line.rstrip().endswith("{"):
            cur = hm.group(2)
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur:
            for v in _CONST_VAL_RE.findall(line):
                const_by_comp[cur].append(int(v))

    whiles = []  # (parent, body, cond)
    calls = []  # (parent, target)
    for cname, (ops, _) in parsed.items():
        for op in ops:
            if op.kind == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.attrs)
                cm = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                if bm and cm:
                    whiles.append((cname, bm.group(1), cm.group(1)))
            elif op.kind in ("call", "conditional"):
                for t in re.findall(
                        r"(?:to_apply|branch_computations=\{|true_computation|"
                        r"false_computation)=?%?([\w.\-]+)", op.attrs):
                    calls.append((cname, t))

    mult = defaultdict(float)
    mult[entry] = 1.0
    for _ in range(8):  # shallow nesting; fixpoint
        new = defaultdict(float)
        new[entry] = 1.0
        for parent, body, cond in whiles:
            trip = max(const_by_comp.get(cond, [1]) or [1])
            new[body] += mult[parent] * trip
            new[cond] += mult[parent] * (trip + 1)
        for parent, target in calls:
            new[target] += mult[parent]
        if dict(new) == dict(mult):
            break
        mult = new
    return mult


def _group_size(attrs: str, n_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def wire_bytes(kind: str, operand: float, result: float, g: int) -> float:
    """Per-chip bytes moved over links, ring-algorithm model."""
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if kind == "all-reduce":
        return 2 * frac * result
    if kind == "all-gather":
        return frac * result
    if kind == "reduce-scatter":
        return frac * operand
    if kind in ("all-to-all", "ragged-all-to-all"):
        return frac * operand
    if kind == "collective-permute":
        return float(result)
    return 0.0


def _bf16_wire_factor(op, ops_by_name, consumers) -> float:
    """XLA's CPU backend has no native bf16 collectives and float-normalizes
    them to f32 (verified with a minimal shard_map repro — a pure-bf16
    all_to_all lowers to f32 on CPU). The dry-run targets TPU, where bf16
    stays bf16 on the wire, so collectives that are provably bf16-primal
    (operand produced by a convert-from-bf16, or every consumer converting
    back to bf16) are counted at 2 bytes/element."""
    def is_down_convert_producer(name):
        p = ops_by_name.get(name)
        if p is None:
            return False
        if p.kind == "convert":
            src = p.operands[0] if p.operands else None
            sp = ops_by_name.get(src)
            return bool(sp and sp.result_groups
                        and sp.result_groups[0][0] == "bf16")
        return p.kind == "fusion" and "convert" in p.name

    def is_up_convert_consumer(name):
        cs = consumers.get(name, [])
        if not cs:
            return False
        return all((c.kind == "convert"
                    and c.result_groups
                    and c.result_groups[0][0] == "bf16")
                   or (c.kind == "fusion" and "convert" in c.name)
                   or c.kind == "get-tuple-element"
                   and is_up_convert_consumer(c.name)
                   for c in cs)

    if any(is_down_convert_producer(o) for o in op.operands):
        return 0.5
    if is_up_convert_consumer(op.name):
        return 0.5
    return 1.0


def analyze(text: str, n_devices: int) -> dict:
    """Full module analysis. Returns flops / memory bytes / collective stats,
    all per-device (the module is the per-partition SPMD program)."""
    parsed, entry = parse_hlo(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    mult = compute_multipliers(parsed, entry, text)

    # fusion-called computations must not be double counted: only comps with
    # mult > 0 (entry + while bodies/conds + call targets) are "executed".
    flops = 0.0
    hbm_bytes = 0.0
    coll = defaultdict(lambda: {"count": 0.0, "operand_bytes": 0.0,
                                "result_bytes": 0.0, "wire_bytes": 0.0})
    top_dots = []
    top_colls = []
    bytes_by_op = defaultdict(float)  # metadata op_name -> HBM bytes
    # fusion ops carry no metadata of their own; attribute them to their
    # called computation's root-op metadata
    _CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
    comp_md = {}
    for cname, (ops, _) in parsed.items():
        md = ""
        for o in ops:
            if o.metadata:
                md = o.metadata
        comp_md[cname] = md

    def op_label(op):
        if op.metadata:
            return op.metadata
        if op.kind == "fusion":
            cm = _CALLS_RE.search(op.attrs)
            if cm and comp_md.get(cm.group(1)):
                return comp_md[cm.group(1)]
        return op.kind

    # The CPU backend decomposes shard_map collectives into a tuple form
    # with slice/concat/copy/convert scaffolding, every piece tagged with
    # the collective's op_name. None of that scaffolding exists on the TPU
    # target (native collectives), so its bytes are excluded; the
    # collective op itself is counted once (operands + results).
    _COLL_TAILS = ("all_to_all", "all_gather", "reduce_scatter", "psum",
                   "psum_scatter", "ppermute", "all_gather_invariant")

    def is_scaffolding(op):
        if op.kind in _COLLECTIVE_KINDS:
            return False
        label = op_label(op)
        if label == op.kind:
            return False
        tail = label.rsplit("/", 1)[-1]
        return any(tail == t or tail.startswith(t + "[") for t in _COLL_TAILS)

    for cname, (ops, symbols) in parsed.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        ops_by_name = {op.name: op for op in ops}
        consumers = defaultdict(list)
        for op in ops:
            for o in op.operands:
                consumers[o].append(op)
        # collectives whose scaffolding (same op_name tag) includes a bf16
        # convert are bf16-primal: the f32 on the wire is CPU promotion
        md_has_bf16 = defaultdict(bool)
        for op in ops:
            if op.metadata and (op.kind == "convert"
                                or (op.kind == "fusion"
                                    and "convert" in op.name)):
                groups = op.result_groups
                src = (ops_by_name.get(op.operands[0])
                       if op.operands else None)
                if (groups and groups[0][0] == "bf16") or \
                        (src and src.result_groups
                         and src.result_groups[0][0] == "bf16"):
                    md_has_bf16[op.metadata] = True
        for op in ops:
            if is_scaffolding(op):
                continue
            rbytes = _nbytes_of(op.result_groups)
            label = op_label(op)
            ltail = label.rsplit("/", 1)[-1]
            if op.kind == "dynamic-update-slice" \
                    or (op.kind == "fusion"
                        and ltail.startswith("dynamic_update_slice")):
                # in-place on TPU (donated/aliased buffers): traffic is the
                # updated region, not the whole buffer. The fused form on
                # CPU copies the full tensor — count operands minus the
                # pass-through buffer instead (== the update bytes).
                obytes = sum(_nbytes_of(symbols.get(o, []))
                             for o in op.operands if o in symbols)
                biggest = max((_nbytes_of(symbols.get(o, []))
                               for o in op.operands if o in symbols),
                              default=0)
                upd = max(obytes + rbytes - 2 * biggest, 0)
                hbm_bytes += m * upd
                bytes_by_op[label] += m * upd
            elif op.kind in ("slice", "dynamic-slice", "gather") \
                    or (op.kind == "fusion"
                        and ltail.startswith(("dynamic_slice", "gather["))):
                # slicing/gathering reads only the addressed region — the
                # stacked scan-parameter tensor is NOT re-read whole every
                # layer iteration
                hbm_bytes += m * 2 * rbytes
                bytes_by_op[label] += m * 2 * rbytes
            elif op.kind not in _SKIP_BYTES_OPS:
                obytes = sum(_nbytes_of(symbols.get(o, [])) for o in op.operands
                             if o in symbols)
                hbm_bytes += m * (rbytes + obytes)
                bytes_by_op[label] += m * (rbytes + obytes)
            if op.kind in ("dot", "convolution"):
                cm = _CONTRACT_RE.search(op.attrs)
                k = 1
                if cm and op.operands and op.operands[0] in symbols:
                    lhs = symbols[op.operands[0]]
                    if lhs and lhs[0][1]:
                        dims = lhs[0][1]
                        for ci in cm.group(1).split(","):
                            if ci:
                                k *= dims[int(ci)]
                relems = sum(_prod(d) for _, d in op.result_groups)
                f = 2.0 * relems * k
                flops += m * f
                top_dots.append((m * f, op.metadata or op.name))
            base = op.kind
            if base.endswith("-start"):
                base = base[:-6]
            if base in _COLLECTIVE_KINDS:
                g = _group_size(op.attrs, n_devices)
                obytes = sum(_nbytes_of(symbols.get(o, [])) for o in op.operands
                             if o in symbols)
                if obytes == 0:  # fallback when operand type unknown
                    if base == "all-gather":
                        obytes = rbytes / max(g, 1)
                    elif base == "reduce-scatter":
                        obytes = rbytes * g
                    else:
                        obytes = rbytes
                dtf = _bf16_wire_factor(op, ops_by_name, consumers)
                if dtf == 1.0 and op.metadata and md_has_bf16[op.metadata]:
                    dtf = 0.5
                obytes *= dtf
                rb_eff = rbytes * dtf
                w = wire_bytes(base, obytes, rb_eff, g)
                d = coll[base]
                d["count"] += m
                d["operand_bytes"] += m * obytes
                d["result_bytes"] += m * rb_eff
                d["wire_bytes"] += m * w
                top_colls.append((m * w, base, g, op.metadata or op.name))

    top_dots.sort(reverse=True)
    top_colls.sort(reverse=True)
    top_bytes = sorted(bytes_by_op.items(), key=lambda kv: -kv[1])
    total = {k: sum(d[k] for d in coll.values())
             for k in ("count", "operand_bytes", "result_bytes", "wire_bytes")}
    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collectives": {"per_kind": {k: dict(v) for k, v in coll.items()},
                        "total": total},
        "top_dots": [(f, n) for f, n in top_dots[:12]],
        "top_collectives": [(w, k, g, n) for w, k, g, n in top_colls[:12]],
        "top_bytes": [(b, n) for n, b in top_bytes[:16]],
    }


def _prod(dims) -> int:
    out = 1
    for d in dims:
        out *= d
    return out
