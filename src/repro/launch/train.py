"""Training launcher CLI.

Single-host (this container):
    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
        --steps 50 --ckpt-dir /tmp/ck

Multi-host production launch (one process per host; the mesh spans all
processes — jax.distributed wires them together):
    python -m repro.launch.train --arch kimi-k2-1t-a32b --variant opt \
        --coordinator <host0>:1234 --num-hosts 64 --host-id $SLURM_PROCID

The full assigned configs only fit the production mesh; ``--reduced`` runs
the same driver with the smoke-scale config on whatever devices exist.
"""
from __future__ import annotations

import argparse
import dataclasses


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--variant", default="")
    p.add_argument("--reduced", action="store_true",
                   help="smoke-scale same-family config (CPU-sized)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--lr", type=float, default=3e-4)
    # multi-host wiring
    p.add_argument("--coordinator", default="")
    p.add_argument("--num-hosts", type=int, default=1)
    p.add_argument("--host-id", type=int, default=0)
    args = p.parse_args()

    if args.coordinator:
        import jax

        jax.distributed.initialize(coordinator_address=args.coordinator,
                                   num_processes=args.num_hosts,
                                   process_id=args.host_id)

    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.optim.adamw import OptConfig
    from repro.runtime.train_loop import TrainConfig, Trainer

    cfg = get_config(args.arch)
    if args.variant:
        from repro.configs.opt_variants import apply_variant

        cfg = apply_variant(cfg, args.variant)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), capacity_factor=8.0)

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch,
        n_hosts=args.num_hosts, host_id=args.host_id))
    tc = TrainConfig(
        total_steps=args.steps, microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir or None, ckpt_every=args.ckpt_every,
        opt=OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                      decay_steps=args.steps))
    trainer = Trainer(cfg, tc, dataset=data)
    out = trainer.run()
    print(f"[train] arch={cfg.name} steps={out['steps_run']} "
          f"loss {out['first_loss']:.4f} -> {out['final_loss']:.4f} "
          f"restarts={out['restarts']}")


if __name__ == "__main__":
    main()
