"""Sharded experiment sweep launcher (ROADMAP item 2, DESIGN.md §14).

One batched engine call (``run_cells_hetero``) saturates a single
device; this layer partitions the batch across a 1-D device mesh and
overlaps host-side result marshalling with device compute:

* **per-device dispatch** (default) — the cell (or candidate-lane) axis
  is split into contiguous shards, each ``device_put`` onto its own
  device and dispatched through the SAME single-device jit executable
  the plain path uses. jax dispatch is async, so all shards run
  concurrently and the launcher returns a lazy output view
  (:class:`ShardedOut`) that concatenates per shard on first access —
  marshalling shard 0 overlaps compute of shards 1..N. Because the
  per-shard executables are the unpartitioned single-device program and
  vmapped ``while_loop`` lanes are independent (finished lanes freeze),
  this path is BIT-IDENTICAL to the single-device run — asserted by the
  ``--smoke`` orchestration and CI.
* **shard_map dispatch** (``dispatch='shard_map'``) — one jitted
  ``jax.shard_map`` call over the mesh (simulator.run_cells_hetero's
  ``mesh=`` entry, via the jax_compat polyfill). On a multi-device mesh
  XLA's *partitioned* compile reassociates the step's float accumulators
  by ~1 ulp vs the unpartitioned executable (deterministic; measured in
  DESIGN.md §14), so this mode is exact only on 1-device meshes and
  ulp-close otherwise.

The launcher also owns the persistent-compile-cache promotion: children
and drivers call :func:`simulator.ensure_compile_cache` (or set
``$REPRO_COMPILE_CACHE_DIR``) so a relaunched sweep skips XLA
compilation entirely — the ``--smoke`` mode demonstrates the cold/warm
delta across fresh processes.

CLI:
  PYTHONPATH=src python -m repro.launch.sweep --smoke --host-devices 8
      # orchestrates single-device vs sharded children (fresh processes),
      # asserts bit-identity and a persistent-cache compile-time cut
  PYTHONPATH=src python -m repro.launch.sweep --child ...
      # one measured workload process (used by --smoke / engine_bench)
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import subprocess
import sys
import tempfile
import time
from collections.abc import Mapping

import numpy as np

# NOTE: jax / repro.core imports stay function-local so ``--child`` can
# amend XLA_FLAGS (device count) before the backend initializes.


def _shard_bounds(n: int, n_shards: int):
    """Contiguous balanced split of ``n`` items into at most ``n_shards``
    non-empty (lo, hi) ranges."""
    base, extra = divmod(n, n_shards)
    bounds, lo = [], 0
    for i in range(n_shards):
        width = base + (1 if i < extra else 0)
        if width == 0:
            break
        bounds.append((lo, lo + width))
        lo += width
    return bounds


def _tree_slice(tree, lo: int, hi: int, axis: int):
    import jax

    def cut(x):
        idx = [slice(None)] * np.ndim(x)
        idx[axis] = slice(lo, hi)
        return x[tuple(idx)]

    return jax.tree_util.tree_map(cut, tree)


class ShardedOut(Mapping):
    """Lazy view over per-shard run outputs: concatenates one key across
    shards on first access (np.asarray blocks per shard, so assembling
    early shards overlaps compute of later ones)."""

    def __init__(self, outs, axis: int):
        self._outs = outs
        self._axis = axis
        self._cache = {}

    def __getitem__(self, key):
        if key not in self._cache:
            self._cache[key] = np.concatenate(
                [np.asarray(o[key]) for o in self._outs], axis=self._axis)
        return self._cache[key]

    def __iter__(self):
        return iter(self._outs[0])

    def __len__(self):
        return len(self._outs[0])


def dispatch_hetero(geoms, params, n_iters, *, mesh, shard_axis="cell",
                    chunk=2048, max_chunks=98, stride=8,
                    **engine_kw) -> ShardedOut:
    """Per-device async dispatch of a run_cells_hetero batch: shard the
    requested axis across ``mesh``'s devices, dispatch every shard
    through the standard single-device jit (bit-identical executables),
    return without blocking."""
    import jax

    from repro.core.fabric import simulator as sim

    if shard_axis not in ("cell", "lane"):
        raise ValueError(f"shard_axis must be 'cell' or 'lane', "
                         f"got {shard_axis!r}")
    axis = 0 if shard_axis == "cell" else 1
    devices = list(mesh.devices.flat)
    n = int(jax.tree_util.tree_leaves(params)[0].shape[axis])
    outs = []
    for (lo, hi), dev in zip(_shard_bounds(n, len(devices)), devices):
        g = geoms if axis == 1 else _tree_slice(geoms, lo, hi, 0)
        outs.append(sim.run_cells_hetero(
            jax.device_put(g, dev),
            jax.device_put(_tree_slice(params, lo, hi, axis), dev),
            jax.device_put(n_iters, dev),
            chunk=chunk, max_chunks=max_chunks, stride=stride,
            **engine_kw))
    return ShardedOut(outs, axis)


def device_launcher(mesh, *, shard_axis: str = "cell",
                    dispatch: str = "devices", donate: bool = False):
    """A launcher callable with run_cells_hetero's calling convention,
    bound to ``mesh`` — what bench.run_scale_grid / search.run_candidates
    plug in via their ``mesh=``/``launcher=`` kwargs."""
    if dispatch not in ("devices", "shard_map"):
        raise ValueError(f"dispatch must be 'devices' or 'shard_map', "
                         f"got {dispatch!r}")

    def launcher(geoms, params, n_iters, *, chunk=2048, max_chunks=98,
                 stride=8, **engine_kw):
        if dispatch == "shard_map":
            from repro.core.fabric import simulator as sim

            return sim.run_cells_hetero(geoms, params, n_iters,
                                        chunk=chunk, max_chunks=max_chunks,
                                        stride=stride, mesh=mesh,
                                        shard_axis=shard_axis,
                                        donate=donate, **engine_kw)
        return dispatch_hetero(geoms, params, n_iters, mesh=mesh,
                               shard_axis=shard_axis, chunk=chunk,
                               max_chunks=max_chunks, stride=stride,
                               **engine_kw)

    return launcher


def whatif_launcher(mesh, *, dispatch: str = "devices"):
    """Lane-sharded launcher for the what-if serving layer
    (runtime.whatif.WhatIfServer): a coalesced wave stacks queries on
    the cell axis and candidate generations on the lane axis, so
    sharding the lane axis spreads each wave's candidate lanes across
    the mesh while keeping the per-device executables (and hence the
    results) bit-identical to the single-device path."""
    return device_launcher(mesh, shard_axis="lane", dispatch=dispatch)


# --------------------------------------------------------------------------
# Measured child workload: quick scale sweep + mitigation panel
# --------------------------------------------------------------------------

TINY_CELLS = (("cresco8", 8), ("cresco8", 12))
QUICK_CELLS = (("cresco8", 16), ("cresco8", 64),
               ("lumi", 16), ("lumi", 64))
MiB = float(2 ** 20)


def _workload(tiny: bool):
    """The measured sweep: the quick ``scale_sweep`` grid (2 scales x
    2 systems) plus the quick mitigation panel x a small candidate
    space. ``tiny`` shrinks both for the tier-1 subprocess test."""
    from repro.core import congestion as cong
    from repro.core.fabric.routing import POLICY_ECMP, POLICY_NSLB
    from repro.core.mitigation import score as mscore
    from repro.core.mitigation import search as msearch

    cells = TINY_CELLS if tiny else QUICK_CELLS
    sizes = (MiB / 4,) if tiny else (2 * MiB,)
    grid = dict(cells=list(cells), victim_coll="ring_allgather",
                aggr_coll="alltoall", sizes=sizes,
                profiles=(cong.steady(),),
                n_iters=6 if tiny else 15, warmup=2 if tiny else 3)
    panel = mscore.panel_from_scenario("mitigation_panel", quick=True)
    candidates = [msearch.default_candidate(),
                  msearch.Candidate(policy=POLICY_ECMP),
                  msearch.Candidate(policy=POLICY_NSLB)]
    if tiny:
        panel = panel[:1]
        candidates = candidates[:2]
    return grid, panel, candidates


def _result_rows(objs):
    rows = [dataclasses.asdict(r) for r in objs]
    for row in rows:  # canonical float types for the digest
        for k, v in row.items():
            if isinstance(v, (np.floating, np.integer)):
                row[k] = float(v)
    return rows


def _digest(rows) -> str:
    """Canonical bit-level digest of marshalled results: full-precision
    float repr, sorted keys — equal digests mean bit-identical runs."""
    blob = json.dumps(rows, sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()


def run_workload(mesh, *, tiny: bool, dispatch: str = "devices") -> dict:
    """Run the measured sweep once (launch both phases, then collect —
    the scale grid's host marshalling overlaps the panel's device
    compute) and return rows + digests."""
    import jax

    from repro.core import bench
    from repro.core.mitigation import search as msearch

    grid, panel, candidates = _workload(tiny)
    t0 = time.perf_counter()
    scale_launcher = panel_launcher = None
    if mesh is not None:
        scale_launcher = device_launcher(mesh, shard_axis="cell",
                                         dispatch=dispatch)
        panel_launcher = device_launcher(mesh, shard_axis="lane",
                                         dispatch=dispatch)
    pending = bench.launch_scale_grid(
        grid["cells"], grid["victim_coll"], grid["aggr_coll"],
        grid["sizes"], grid["profiles"], n_iters=grid["n_iters"],
        warmup=grid["warmup"], launcher=scale_launcher)
    t_launch = time.perf_counter() - t0
    runs = msearch.run_candidates(panel, candidates,
                                  launcher=panel_launcher)
    scale_results = pending.results()
    wall = time.perf_counter() - t0
    scale_rows = _result_rows(scale_results)
    panel_rows = _result_rows(runs)
    return {
        "n_devices": len(jax.devices()),
        "dispatch": "single" if mesh is None else dispatch,
        "launch_s": round(t_launch, 4),
        "wall_s": round(wall, 3),
        "digest_scale": _digest(scale_rows),
        "digest_panel": _digest(panel_rows),
        "results_scale": scale_rows,
        "runs_panel": panel_rows,
    }


def _compile_meter():
    """Tap jax's own monitoring events for a noise-free compile
    measurement. ``/jax/core/compile/backend_compile_duration`` wraps
    ``compile_or_get_cached``: on a persistent-cache miss it times the
    real XLA compile, on a hit only the cache retrieval — so its sum is
    exactly "seconds spent compiling (or loading) executables",
    untouched by device-compute wall noise. Hit/miss counts and jax's
    ``compile_time_saved_sec`` (stored compile time minus retrieval
    cost) ride along."""
    import jax.monitoring as jmon

    meter = {"backend_compile_s": 0.0, "compile_saved_s": 0.0,
             "cache_hits": 0, "cache_misses": 0}

    def on_event(event, **kw):
        if event == "/jax/compilation_cache/cache_hits":
            meter["cache_hits"] += 1
        elif event == "/jax/compilation_cache/cache_misses":
            meter["cache_misses"] += 1

    def on_duration(event, duration, **kw):
        if event == "/jax/core/compile/backend_compile_duration":
            meter["backend_compile_s"] += duration
        elif event == "/jax/compilation_cache/compile_time_saved_sec":
            meter["compile_saved_s"] += duration

    jmon.register_event_listener(on_event)
    jmon.register_event_duration_secs_listener(on_duration)
    return meter


def child_main(args) -> dict:
    """One measured process: optional forced host-device count +
    persistent compile cache, workload run twice (rerun digest must
    match — determinism assert). Compile cost is read from jax's
    monitoring events (see ``_compile_meter``), not inferred from wall
    clock, so host-core contention between shards never enters the
    measurement."""
    from repro.core.fabric import simulator as sim
    from repro.launch.mesh import make_sweep_mesh

    meter = _compile_meter()
    if args.cache_dir:
        sim.ensure_compile_cache(args.cache_dir)
    mesh = None if args.single else make_sweep_mesh()
    first = run_workload(mesh, tiny=args.tiny, dispatch=args.dispatch)
    first_meter = dict(meter)
    second = run_workload(mesh, tiny=args.tiny, dispatch=args.dispatch)
    assert first["digest_scale"] == second["digest_scale"], \
        "non-deterministic rerun (scale grid)"
    assert first["digest_panel"] == second["digest_panel"], \
        "non-deterministic rerun (panel)"
    out = dict(first)
    out["wall_first_s"] = first["wall_s"]
    out["wall_second_s"] = second["wall_s"]
    out["launch_first_s"] = first["launch_s"]
    out["launch_second_s"] = second["launch_s"]
    # all executables are built during the first workload run (the
    # second hits the in-process jit cache — asserted via hit/miss
    # deltas staying flat), so the first run's meter IS the process's
    # compile bill: real XLA compiles when the persistent cache misses,
    # retrieval cost when it hits
    out["compile_s"] = round(first_meter["backend_compile_s"], 3)
    out["compile_saved_s"] = round(first_meter["compile_saved_s"], 3)
    out["cache_hits"] = first_meter["cache_hits"]
    out["cache_misses"] = first_meter["cache_misses"]
    out["trace_counts"] = dict(sim.TRACE_COUNTS)
    out["cache_dir"] = args.cache_dir or ""
    out["cache_entries"] = (len(os.listdir(args.cache_dir))
                            if args.cache_dir
                            and os.path.isdir(args.cache_dir) else 0)
    return out


# --------------------------------------------------------------------------
# Smoke orchestration: single vs sharded-cold vs sharded-warm children
# --------------------------------------------------------------------------


def _spawn_child(*, host_devices, cache_dir, out_path, tiny, dispatch,
                 single=False):
    cmd = [sys.executable, "-m", "repro.launch.sweep", "--child",
           "--out", out_path, "--dispatch", dispatch]
    if single:
        cmd.append("--single")
    if host_devices and not single:
        cmd += ["--host-devices", str(host_devices)]
    if cache_dir:
        cmd += ["--cache-dir", cache_dir]
    if tiny:
        cmd.append("--tiny")
    env = dict(os.environ)
    env.setdefault("PYTHONPATH",
                   os.path.join(os.path.dirname(__file__), "..", ".."))
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"sweep child failed ({' '.join(cmd)}):\n"
                           f"{r.stdout[-2000:]}\n{r.stderr[-4000:]}")
    with open(out_path) as f:
        return json.load(f)


def run_smoke(host_devices: int = 8, *, tiny: bool = False,
              dispatch: str = "devices", workdir=None) -> dict:
    """The acceptance harness (CI + engine_bench --sharded): fresh
    children run the same workload (1) on a single device, (2) sharded
    cold (empty persistent cache), (3) sharded warm (same cache dir).
    Asserts the sharded results are bit-identical to the single-device
    run and that the warm relaunch cuts compile time."""
    tmp = workdir or tempfile.mkdtemp(prefix="repro_sweep_smoke_")
    cache_dir = os.path.join(tmp, "xla_cache")
    single = _spawn_child(host_devices=0, cache_dir=None,
                          out_path=os.path.join(tmp, "single.json"),
                          tiny=tiny, dispatch=dispatch, single=True)
    cold = _spawn_child(host_devices=host_devices, cache_dir=cache_dir,
                        out_path=os.path.join(tmp, "cold.json"),
                        tiny=tiny, dispatch=dispatch)
    warm = _spawn_child(host_devices=host_devices, cache_dir=cache_dir,
                        out_path=os.path.join(tmp, "warm.json"),
                        tiny=tiny, dispatch=dispatch)

    checks = {
        "devices_forced": cold["n_devices"] >= max(2, host_devices),
        "bit_identical_scale":
            single["digest_scale"] == cold["digest_scale"]
            == warm["digest_scale"],
        "bit_identical_panel":
            single["digest_panel"] == cold["digest_panel"]
            == warm["digest_panel"],
        "cache_populated": warm["cache_entries"] > 0,
        # the cold child starts on an empty dir (every compile a miss);
        # the warm relaunch must find those entries
        "cache_hit_on_relaunch":
            cold["cache_hits"] == 0 and warm["cache_hits"] > 0
            and warm["cache_misses"] < cold["cache_misses"],
        # compile_s is metered from jax's backend_compile events (real
        # XLA compiles on a miss, retrieval cost on a hit) — the warm
        # child must spend well under the cold child's compile bill
        "cache_cuts_compile":
            warm["compile_s"] < max(0.6 * cold["compile_s"], 0.05),
    }
    child_keys = ("n_devices", "wall_first_s", "wall_second_s",
                  "launch_first_s", "launch_second_s", "compile_s",
                  "compile_saved_s", "cache_hits", "cache_misses")
    report = {
        "host_devices": host_devices,
        "tiny": tiny,
        "dispatch": dispatch,
        "single": {k: single[k] for k in child_keys},
        "sharded_cold": {k: cold[k] for k in
                         child_keys + ("cache_entries",)},
        "sharded_warm": {k: warm[k] for k in
                         child_keys + ("cache_entries",)},
        "checks": checks,
        "ok": all(checks.values()),
    }
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--child", action="store_true",
                    help="run one measured workload process")
    ap.add_argument("--smoke", action="store_true",
                    help="orchestrate single/cold/warm children and "
                         "assert bit-identity + cache compile cut")
    ap.add_argument("--single", action="store_true",
                    help="(child) run the plain single-device path")
    ap.add_argument("--host-devices", type=int, default=8,
                    help="forced CPU host device count for sharded runs")
    ap.add_argument("--dispatch", default="devices",
                    choices=["devices", "shard_map"],
                    help="sharded execution mode (devices = bit-exact "
                         "per-device dispatch; shard_map = one "
                         "partitioned jit, ulp-close on multi-device)")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent XLA compile cache directory")
    ap.add_argument("--tiny", action="store_true",
                    help="shrunken workload (tier-1 subprocess test)")
    ap.add_argument("--out", default=None, help="write the JSON report")
    args = ap.parse_args(argv)

    if args.child:
        if args.host_devices and not args.single:
            # must happen before the jax backend initializes
            from repro.jax_compat import force_host_device_count

            force_host_device_count(args.host_devices)
        report = child_main(args)
    elif args.smoke:
        report = run_smoke(args.host_devices, tiny=args.tiny,
                           dispatch=args.dispatch)
        ok = report["ok"]
        summary = {k: report[k] for k in
                   ("single", "sharded_cold", "sharded_warm", "checks")}
        print(json.dumps(summary, indent=1))
        if not ok:
            print("sweep smoke FAILED", file=sys.stderr)
            return 1
        print("sweep smoke OK: sharded launch bit-identical to "
              "single-device; persistent cache cut compile "
              f"{report['sharded_cold']['compile_s']}s -> "
              f"{report['sharded_warm']['compile_s']}s")
    else:
        print("choose --child or --smoke", file=sys.stderr)
        return 2

    if args.out:
        slim = {k: v for k, v in report.items()
                if k not in ("results_scale", "runs_panel")} \
            if args.smoke else report
        with open(args.out, "w") as f:
            json.dump(slim, f, indent=1, default=repr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
