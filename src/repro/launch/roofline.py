"""§Roofline aggregation: read every dry-run artifact and emit the
per-(arch x shape) three-term roofline table, bottleneck attribution,
MODEL_FLOPS ratio, and an actionable one-liner per cell.

    PYTHONPATH=src python -m repro.launch.roofline [--mesh single] [--json]

Terms (seconds per step, single-chip denominators — the SPMD module is the
per-partition program):
    compute_s    = HLO_FLOPs / 197e12        (bf16 peak / chip)
    memory_s     = HLO_bytes / 819e9         (HBM bw / chip)
    collective_s = wire_bytes / 50e9         (ICI link bw / chip)

``roofline_fraction`` (training cells) = model_flops_time / bound_s where
model_flops_time = 6*N_active*D / n_chips / peak — the score §Perf pushes
up. Serving cells report the bound and bottleneck (their useful work is
bandwidth, not FLOPs).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_PER_CHIP = 16e9  # v5e

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


def load_cells(mesh: str = "single", variant: str = "") -> List[Dict]:
    from repro.configs import all_arch_names
    from repro.configs.base import SHAPES

    base = ARTIFACTS if not variant else ARTIFACTS + "_" + variant
    cells = []
    for arch in all_arch_names():
        for shape in SHAPES:
            path = os.path.join(base, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(path):
                continue
            with open(path) as f:
                cells.append(json.load(f))
    return cells


def _advice(cell: Dict) -> str:
    r = cell["roofline"]
    bn = r["bottleneck"]
    coll = cell["hlo"]["collectives"]["per_kind"]
    top_kind = max(coll, key=lambda k: coll[k]["wire_bytes"]) if coll else ""
    if bn == "collective_s":
        return (f"dominant wire bytes are {top_kind}; cut by resharding "
                "(fewer per-layer weight gathers), fusing RS+AG into the "
                "step, or compressing the slow-axis payload")
    if bn == "memory_s":
        return ("HBM traffic dominated by remat re-reads / attention "
                "intermediates; relax the checkpoint policy or chunk "
                "attention to keep the working set in VMEM")
    return ("MXU-bound: increase arithmetic intensity per pass (fused "
            "kernels) or accept — compute-bound is the roofline target")


def analyze_cell(cell: Dict) -> Optional[Dict]:
    if cell.get("status") != "ok":
        return None
    r = cell["roofline"]
    bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
    out = {
        "arch": cell["arch"], "shape": cell["shape"],
        "n_devices": cell["n_devices"],
        "compute_s": r["compute_s"], "memory_s": r["memory_s"],
        "collective_s": r["collective_s"], "bottleneck": r["bottleneck"],
        "bound_s": bound,
        "peak_gb": cell["memory"].get("peak_per_device_bytes", 0) / 1e9,
        "fits_hbm": cell["memory"].get("peak_per_device_bytes", 1e30)
        <= HBM_PER_CHIP,
        "advice": _advice(cell),
    }
    mf = cell.get("model_flops")
    if mf:
        t_useful = mf["model_flops_per_device"] / PEAK_FLOPS
        out["useful_flops_fraction"] = mf["useful_fraction"]
        out["roofline_fraction"] = t_useful / bound if bound else 0.0
    else:
        # serving is bandwidth work: the floor is streaming the sharded
        # params once (+ the KV cache once for decode); RL-frac = that
        # floor over the achieved bound
        t_useful = _serving_useful_s(cell)
        out["roofline_fraction"] = (t_useful / bound) if bound else 0.0
        out["useful_flops_fraction"] = None
    return out


def _serving_useful_s(cell: Dict) -> float:
    """Minimal HBM seconds for a serving step: sharded params read once,
    plus (decode) the KV/state cache read once."""
    from repro.configs import get_config
    from repro.configs.base import SHAPES

    cfg = get_config(cell["arch"])
    shape = SHAPES[cell["shape"]]
    n_dev = cell["n_devices"]
    param_bytes = cfg.param_count() * 2 / n_dev  # bf16, fully sharded
    kv_bytes = 0.0
    if shape.kind == "decode":
        B, S = shape.global_batch, shape.seq_len
        if cfg.n_kv_heads:
            s_eff = min(S, cfg.sliding_window) if cfg.sliding_window else S
            kv_bytes += (cfg.n_layers * B * s_eff * cfg.n_kv_heads
                         * cfg.resolved_head_dim * 2 * 2)
        if cfg.ssm_state:
            kv_bytes += (cfg.n_layers * B * cfg.resolved_d_inner
                         * cfg.ssm_state * 4)
        kv_bytes /= n_dev
        return (param_bytes + kv_bytes) / HBM_BW
    # prefill is forward compute: useful = 2*N_active*tokens FLOPs, floored
    # by streaming the params once
    B, S = shape.global_batch, shape.seq_len
    t_flops = 2.0 * cfg.active_param_count() * B * S / n_dev / PEAK_FLOPS
    return max(t_flops, param_bytes / HBM_BW)


def table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':<18} {'shape':<12} {'compute_s':>10} {'memory_s':>10} "
           f"{'collect_s':>10} {'bound_s':>9} {'bottleneck':>12} "
           f"{'RL-frac':>8} {'useful':>7} {'GB/dev':>7} {'fits':>5}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        uf = r["useful_flops_fraction"]
        lines.append(
            f"{r['arch']:<18} {r['shape']:<12} {r['compute_s']:>10.3f} "
            f"{r['memory_s']:>10.3f} {r['collective_s']:>10.3f} "
            f"{r['bound_s']:>9.3f} {r['bottleneck'][:-2]:>12} "
            f"{r['roofline_fraction']:>8.3f} "
            f"{uf if uf is not None else float('nan'):>7.3f} "
            f"{r['peak_gb']:>7.1f} {'y' if r['fits_hbm'] else 'N':>5}")
    return "\n".join(lines)


def pick_hillclimb_cells(rows: List[Dict]) -> Dict[str, Dict]:
    """The three §Perf cells: worst roofline fraction (train), most
    collective-bound, most representative of the paper's technique.
    The cells are kept distinct: kimi train_4k is both the largest
    collective term AND the paper-representative cell (EP all-to-all MoE
    dispatch == the paper's AlltoAll congestion pattern), so the
    collective slot takes the runner-up."""
    # the paper's technique == congestion-aware collectives; its pattern is
    # the EP all-to-all MoE dispatch (kimi) on the training shape
    rep = next(r for r in rows
               if r["arch"] == "kimi-k2-1t-a32b" and r["shape"] == "train_4k")
    train = [r for r in rows if r["shape"] == "train_4k"]
    worst = min((r for r in train if r is not rep),
                key=lambda r: r["roofline_fraction"])
    coll = max((r for r in rows if r is not rep and r is not worst),
               key=lambda r: r["collective_s"]
               * (r["bottleneck"] == "collective_s"))
    return {"worst_roofline": worst, "most_collective_bound": coll,
            "paper_representative": rep}


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--mesh", default="single", choices=["single", "multi"])
    p.add_argument("--variant", default="",
                   help="read artifacts/dryrun_<variant>/ instead")
    p.add_argument("--json", action="store_true")
    args = p.parse_args()
    rows = [a for a in (analyze_cell(c)
                        for c in load_cells(args.mesh, args.variant)) if a]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    if args.json:
        print(json.dumps(rows, indent=1))
    else:
        print(table(rows))
        picks = pick_hillclimb_cells(rows)
        print("\n# hillclimb cells (§Perf):")
        for why, r in picks.items():
            print(f"#  {why:<24} {r['arch']} x {r['shape']} "
                  f"(RL-frac {r['roofline_fraction']:.3f}, "
                  f"{r['bottleneck']})")
    tag = f"_{args.variant}" if args.variant else ""
    out = os.path.join(ARTIFACTS, "..", f"roofline{tag}_{args.mesh}.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
