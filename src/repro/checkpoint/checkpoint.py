"""Sharded, elastic, asynchronous checkpointing.

Layout (one directory per step)::

    <root>/step_00000420/
        index.json        # tree structure, shapes, dtypes, logical specs
        <leaf-id>.npy     # one array per pytree leaf
        COMMIT            # written last; a directory without it is ignored

Design points for 1000+-node deployments:

* **Atomic commit** — writers target ``step_X.tmp`` and rename into place
  after the COMMIT marker is written; a crashed writer never corrupts the
  latest checkpoint, and ``latest_step`` simply skips uncommitted dirs.
* **Async save** — the train loop snapshots device arrays to host memory
  (cheap) and a background thread does the file I/O; ``AsyncCheckpointer.
  wait()`` joins before the next save or at exit.
* **Elastic restore** — the index stores the *logical* PartitionSpec tree,
  not device placements. ``restore`` lays the arrays out on whatever mesh
  the restarted job has (fewer/more hosts, different axis sizes), so a
  512-chip job can restart as a 256-chip job after losing a pod.
* **Multi-host** — each host writes only the leaves it owns under a
  ``shard<k>`` suffix in a real deployment; in this single-host container
  every leaf is fully addressable, which the index records as shard 0 of 1.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_STEP_RE = re.compile(r"^step_(\d{8})$")


# --------------------------------------------------------------------------
# pytree <-> flat leaves
# --------------------------------------------------------------------------


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, jax.tree_util.tree_structure(tree)


def _spec_to_json(spec: P):
    return [list(x) if isinstance(x, tuple) else x for x in spec]


def _spec_from_json(parts) -> P:
    return P(*[tuple(x) if isinstance(x, list) else x for x in parts])


# --------------------------------------------------------------------------
# save
# --------------------------------------------------------------------------


def save(root: str, step: int, state: Any, specs: Optional[Any] = None,
         extra_meta: Optional[dict] = None) -> str:
    """Synchronous checkpoint write with atomic commit. Returns the path."""
    flat, _ = _flatten(state)
    spec_flat = {}
    if specs is not None:
        spec_flat, _ = _flatten(specs)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    index = {"step": int(step), "n_shards": 1, "shard": 0,
             "meta": extra_meta or {}, "leaves": {}}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        entry = {"file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)}
        if key in spec_flat:
            entry["spec"] = _spec_to_json(spec_flat[key])
        index["leaves"][key] = entry
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(root: str) -> Optional[int]:
    """Highest committed step under ``root`` (ignores partial writes)."""
    if not os.path.isdir(root):
        return None
    best = None
    for name in os.listdir(root):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(root, name, "COMMIT")):
            best = max(best or 0, int(m.group(1)))
    return best


def restore(root: str, like: Any, *, step: Optional[int] = None,
            mesh=None, specs: Optional[Any] = None) -> Any:
    """Load a checkpoint into the structure of ``like``.

    With ``mesh`` + ``specs`` the leaves are placed with NamedSharding on
    the *current* mesh — the elastic-rescale path. Otherwise plain arrays.
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "index.json")) as f:
        index = json.load(f)
    flat_like, _ = _flatten(like)
    spec_flat = {}
    if specs is not None:
        spec_flat, _ = _flatten(specs)
    out_flat = {}
    for key, ref in flat_like.items():
        if key not in index["leaves"]:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        entry = index["leaves"][key]
        arr = np.load(os.path.join(d, entry["file"]))
        want_shape = tuple(ref.shape) if hasattr(ref, "shape") else None
        if want_shape is not None and tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != {want_shape}")
        if mesh is not None and key in spec_flat:
            sh = NamedSharding(mesh, spec_flat[key])
            out_flat[key] = jax.device_put(arr.astype(entry["dtype"]), sh)
        else:
            out_flat[key] = jax.numpy.asarray(arr.astype(entry["dtype"]))
    # unflatten by reconstructing in `like`'s structure
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = []
    for path, _ in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        ordered.append(out_flat[key])
    return jax.tree_util.tree_unflatten(treedef, ordered)


def checkpoint_step_meta(root: str, step: int) -> dict:
    with open(os.path.join(root, f"step_{step:08d}", "index.json")) as f:
        return json.load(f)["meta"]


def cleanup(root: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` committed checkpoints."""
    if not os.path.isdir(root):
        return
    steps = sorted(s for s in (
        int(m.group(1)) for m in (_STEP_RE.match(n) for n in os.listdir(root))
        if m) if os.path.exists(os.path.join(root, f"step_{s:08d}", "COMMIT")))
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)


# --------------------------------------------------------------------------
# async writer
# --------------------------------------------------------------------------


class AsyncCheckpointer:
    """Snapshot-to-host on the caller thread, file I/O on a worker thread.

    The device->host copy happens synchronously (so the training step can
    donate/overwrite device buffers immediately); only the serialization
    overlaps with compute — the standard async-checkpoint split.
    """

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, state: Any, specs=None, extra_meta=None):
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def _write():
            self.last_path = save(self.root, step, host_state, specs,
                                  extra_meta)
            cleanup(self.root, self.keep)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
