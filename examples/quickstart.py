"""Quickstart: build an assigned architecture, run a forward/train step,
ask the congestion layer a question, and lower a production cell.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import all_arch_names, get_config
from repro.core import autotune
from repro.launch.mesh import make_host_mesh, rules_for
from repro.launch.steps import init_train_state, make_train_step
from repro.models.api import build_model
from repro.optim.adamw import OptConfig, get_optimizer


def main():
    print("assigned architectures:", ", ".join(all_arch_names()))

    # -- 1. a reduced config on the host mesh (full configs are dry-run only)
    cfg = dataclasses.replace(get_config("yi-6b").reduced(),
                              capacity_factor=8.0)
    mesh = make_host_mesh()
    rules = rules_for(cfg, mesh)
    model = build_model(cfg, rules, mesh)
    opt = get_optimizer(cfg.optimizer, OptConfig(lr=1e-3, warmup_steps=2))
    step = jax.jit(make_train_step(model, opt))

    rng = jax.random.PRNGKey(0)
    state = init_train_state(model, opt, rng)
    tok = jax.random.randint(rng, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    with jax.set_mesh(mesh):
        for i in range(5):
            state, metrics = step(state, batch)
            print(f"step {i}: loss={float(metrics['total_loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}")

    # -- 2. the paper's layer: which collective schedule under congestion?
    pick = autotune.choose_schedule("all_gather", n=16, vector_bytes=512.0)
    print(f"\n512B AllGather over 16 ranks -> {pick.algo} "
          f"({pick.steps} steps, predicted {pick.time_s * 1e6:.1f}us)")
    pick = autotune.choose_schedule("all_gather", n=16,
                                    vector_bytes=64 * 2 ** 20)
    print(f"64MiB AllGather over 16 ranks -> {pick.algo} "
          f"(predicted {pick.time_s * 1e3:.2f}ms)")

    # -- 3. pod-axis strategy for a 7B model from the roofline model
    strat = autotune.choose_pod_strategy(grad_bytes_per_device=14e9 / 256,
                                         n_pods=2)
    print(f"\n2-pod 7B gradient all-reduce: compress_grads="
          f"{strat.compress_grads} "
          f"(collective term {strat.predicted_collective_s * 1e3:.2f}ms vs "
          f"baseline {strat.predicted_baseline_s * 1e3:.2f}ms)")
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
