"""End-to-end training driver: train a ~100M-parameter LM on the synthetic
pipeline with checkpoint/restart, straggler monitoring, and (optionally)
injected failures to demonstrate recovery.

Default is a CPU-sized model so the example finishes in minutes; pass
``--full-100m`` for the 100M-parameter configuration (the driver is the
same — only the config scales).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 300 --full-100m
    PYTHONPATH=src python examples/train_lm.py --fail-at 40 --steps 80
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim.adamw import OptConfig
from repro.runtime import fault
from repro.runtime.train_loop import TrainConfig, Trainer


def model_100m() -> ArchConfig:
    """~100M-param llama-style config (12L x 768d, 32k vocab)."""
    return dataclasses.replace(
        get_config("yi-6b"),
        name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32_000,
        param_dtype="float32", compute_dtype="float32", remat="none",
        attn_chunk=128)


def model_tiny() -> ArchConfig:
    return dataclasses.replace(
        get_config("yi-6b"),
        name="lm-tiny", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
        head_dim=32, d_ff=512, vocab_size=2048,
        param_dtype="float32", compute_dtype="float32", remat="none",
        attn_chunk=128)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--full-100m", action="store_true")
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    p.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = p.parse_args()

    cfg = model_100m() if args.full_100m else model_tiny()
    n_params = cfg.param_count()
    print(f"model: {cfg.name} ({n_params / 1e6:.1f}M params)")

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch, seed=0))
    tc = TrainConfig(
        total_steps=args.steps, microbatches=args.microbatches,
        ckpt_every=max(args.steps // 4, 10), ckpt_dir=args.ckpt_dir,
        opt=OptConfig(lr=3e-4, warmup_steps=20, decay_steps=args.steps))
    injector = fault.FailureInjector(fail_at=tuple(args.fail_at))
    trainer = Trainer(cfg, tc, dataset=data, failure_injector=injector)

    out = trainer.run()
    print(f"\nsteps={out['steps_run']} restarts={out['restarts']} "
          f"stragglers={out['stragglers']}")
    print(f"loss: {out['first_loss']:.4f} -> {out['final_loss']:.4f}")
    log = out["log"]
    toks = args.batch * args.seq_len
    avg_s = sum(m["step_s"] for m in log[2:]) / max(len(log) - 2, 1)
    print(f"throughput: {toks / avg_s:,.0f} tokens/s ({avg_s * 1e3:.0f} ms/step)")
    assert out["final_loss"] < out["first_loss"], "training must make progress"
    print("train_lm OK")


if __name__ == "__main__":
    main()
