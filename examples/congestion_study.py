"""The paper's methodology end-to-end on one fabric: inject steady and
bursty congestion against a victim AllGather on the Leonardo model and
print the resulting slowdown matrix — a miniature of Fig. 5/6.

    PYTHONPATH=src python examples/congestion_study.py [--system lumi]
"""
import argparse

from repro.core import bench, congestion as cong
from repro.core.fabric import systems


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--system", default="leonardo",
                   choices=sorted(systems.PRESETS))
    p.add_argument("--nodes", type=int, default=32)
    p.add_argument("--vector-kib", type=int, default=2048)
    args = p.parse_args()

    sysp = systems.get_system(args.system)
    v = args.vector_kib * 1024
    print(f"system={sysp.name} ({sysp.fabric}), {args.nodes} nodes "
          f"(interleaved victims/aggressors), victim=ring AllGather "
          f"{args.vector_kib}KiB\n")

    print(f"{'aggressor':>10} {'profile':>16} {'ratio':>7}   (higher=better)")
    for aggr in ("alltoall", "incast"):
        r = bench.run_point(sysp, args.nodes, "ring_allgather", aggr, v,
                            cong.steady(), n_iters=25, warmup=5)
        print(f"{aggr:>10} {'steady':>16} {r.ratio:>7.3f}")
        for burst_ms, pause_ms in ((2.0, 0.2), (2.0, 8.0)):
            prof = cong.bursty(burst_ms * 1e-3, pause_ms * 1e-3)
            r = bench.run_point(sysp, args.nodes, "ring_allgather", aggr, v,
                                prof, n_iters=25, warmup=5)
            print(f"{aggr:>10} {f'burst {burst_ms}/{pause_ms}ms':>16} "
                  f"{r.ratio:>7.3f}")
    print("\npaper Obs.3: short pauses leave no drain time -> lower ratio;")
    print("paper Obs.4: slingshot (lumi) stays near 1.0 everywhere.")


if __name__ == "__main__":
    main()
