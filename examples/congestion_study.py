"""The paper's methodology end-to-end on one fabric: inject steady, bursty,
ramp, and multi-tenant congestion against a victim AllGather and print the
resulting slowdown matrix — a miniature of Fig. 5/6 plus the extended
envelope families.

All profiles for one aggressor run as a SINGLE batched grid
(bench.run_grid): one flow set, one compile, every cell vmapped.

    PYTHONPATH=src python examples/congestion_study.py [--system lumi]
"""
import argparse

from repro.core import bench, congestion as cong
from repro.core.fabric import systems


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--system", default="leonardo",
                   choices=sorted(systems.PRESETS))
    p.add_argument("--nodes", type=int, default=32)
    p.add_argument("--vector-kib", type=int, default=2048)
    args = p.parse_args()

    sysp = systems.get_system(args.system)
    v = args.vector_kib * 1024
    print(f"system={sysp.name} ({sysp.fabric}), {args.nodes} nodes "
          f"(interleaved victims/aggressors), victim=ring AllGather "
          f"{args.vector_kib}KiB\n")

    profiles = [
        cong.steady(),
        cong.bursty(2e-3, 0.2e-3),
        cong.bursty(2e-3, 8e-3),
        cong.ramp(8e-3),
        cong.random_onoff(2e-3, 2e-3),
        cong.multi_tenant((cong.bursty(0.5e-3, 0.5e-3), 0.5),
                          (cong.bursty(4e-3, 4e-3), 0.5)),
    ]
    print(f"{'aggressor':>10} {'profile':>26} {'ratio':>7}   (higher=better)")
    for aggr in ("alltoall", "incast"):
        results = bench.run_grid(sysp, args.nodes, "ring_allgather", aggr,
                                 [v], profiles, n_iters=25, warmup=5)
        for r in results:
            print(f"{aggr:>10} {r.profile:>26} {r.ratio:>7.3f}")
    print("\npaper Obs.3: short pauses leave no drain time -> lower ratio;")
    print("paper Obs.4: slingshot (lumi) stays near 1.0 everywhere.")


if __name__ == "__main__":
    main()
