"""Batched serving example: submit a mixed queue of requests to the wave
scheduler and report latency/throughput — the serving-side shape of the
paper's fan-in (Incast) pattern.

    PYTHONPATH=src python examples/serve_batch.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, rules_for
from repro.models.api import build_model
from repro.runtime.serve import BatchedServer


def main():
    cfg = dataclasses.replace(get_config("phi3-mini-3.8b").reduced(),
                              capacity_factor=8.0)
    mesh = make_host_mesh()
    rules = rules_for(cfg, mesh)
    model = build_model(cfg, rules, mesh)
    params = model.init(jax.random.PRNGKey(0))
    server = BatchedServer(model, params, max_batch=4, max_seq=96)

    rng = np.random.RandomState(0)
    for i in range(10):
        prompt = rng.randint(1, cfg.vocab_size, size=8 + (i % 3) * 4)
        server.submit(prompt, max_new_tokens=12,
                      temperature=0.0 if i % 2 == 0 else 0.7)
    stats = server.run_until_drained()

    print(f"requests: {stats.requests_done}  waves: {stats.waves}  "
          f"decode steps: {stats.decode_steps}")
    print(f"tokens generated: {stats.tokens_generated}  "
          f"({stats.tokens_per_s:,.0f} tok/s)")
    lat = [r.latency_s for r in server.done]
    print(f"latency p50={np.percentile(lat, 50) * 1e3:.0f}ms "
          f"p99={np.percentile(lat, 99) * 1e3:.0f}ms")
    for r in server.done[:3]:
        print(f"  req {r.uid}: prompt[:4]={r.prompt[:4].tolist()} -> "
              f"{r.tokens[:6].tolist()}... ({r.finish_reason})")
    print("serve_batch OK")


if __name__ == "__main__":
    main()
